"""Unit tests for repro.dist.sharding (single process, no subprocess).

The 8-device integration counterpart lives in test_distributed.py; this
file covers the pure resolution logic: fit_pspec's divisibility fallback,
the per-mode rule tables, init determinism, and shard_act's no-op contract
outside a sharding_ctx.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    MODES,
    ParamSpec,
    ShardingRules,
    abstract_params,
    current_ctx,
    fit_pspec,
    init_params,
    logical_to_pspec,
    rules_for_mode,
    shard_act,
    sharding_ctx,
    specs_to_shardings,
)

KEY = jax.random.PRNGKey(0)


class FakeMesh:
    """Duck-typed stand-in: axis_names + devices.shape, no real devices."""

    def __init__(self, shape=(4, 8), axes=("data", "model")):
        self.axis_names = axes
        self.devices = type("D", (), {"shape": shape})()


# ---------------------------------------------------------------------------
# fit_pspec
# ---------------------------------------------------------------------------


def test_fit_pspec_drops_indivisible_dims():
    m = FakeMesh((4, 8))
    assert fit_pspec((3, 16), P("data", "model"), m) == P(None, "model")
    assert fit_pspec((12, 24), P("data", "model"), m) == P("data", "model")
    # nothing fits -> fully replicated
    assert fit_pspec((3, 5), P("data", "model"), m) == P(None, None)


def test_fit_pspec_composite_keeps_divisible_prefix():
    m = FakeMesh((4, 8))
    assert fit_pspec((8,), P(("data", "model"),), m) == P(("data",))
    assert fit_pspec((32,), P(("data", "model"),), m) == P(("data", "model"))
    assert fit_pspec((2,), P(("data", "model"),), m) == P(None)


def test_fit_pspec_deduplicates_first_dim_wins():
    m = FakeMesh((4, 8))
    assert fit_pspec((32, 32), P("model", "model"), m) == P("model", None)
    # the seq-parallel case: seq takes model, act_heads loses it
    assert fit_pspec((16, 8), P("model", "model"), m) == P("model", None)


def test_fit_pspec_ignores_axes_missing_from_mesh():
    m = FakeMesh((4, 8))
    assert fit_pspec((16, 16), P("pod", "model"), m) == P(None, "model")


def test_fit_pspec_pads_short_pspec_with_replication():
    m = FakeMesh((4, 8))
    assert fit_pspec((4, 8, 16), P("data"), m) == P("data", None, None)
    assert fit_pspec((), P(), m) == P()


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------


def test_rules_for_mode_megatron_table():
    r = rules_for_mode("megatron")
    assert isinstance(r, ShardingRules) and r.mode == "megatron"
    assert r["col_out"] == "model"
    assert r["row_in"] == "model"
    assert r["vocab"] == "model"
    assert r["act_heads"] == "model"
    assert r["batch"] == ("pod", "data")
    assert r["fsdp"] == ("pod", "data")
    assert r["seq"] is None          # no sequence parallelism
    assert r["act_embed"] is None    # activations replicated on model
    assert r["layers"] is None       # scan dim never sharded
    assert r["experts"] == "model"
    assert r["expert_cap"] == "data"


def test_rules_for_mode_cascade_table():
    r = rules_for_mode("cascade")
    # contraction dim on model = the west->east cascade psum
    assert r["cascade_in"] == "model"
    # output features FSDP across (pod, data)
    assert r["cascade_out"] == ("pod", "data")
    # activations keep their feature dim on model to match cascade_in
    assert r["act_embed"] == "model"
    assert r["batch"] == ("pod", "data")


def test_rules_for_mode_megatron_sp_and_unknown():
    r = rules_for_mode("megatron_sp")
    assert r["seq"] == "model"       # the only delta vs megatron
    assert r["col_out"] == "model"
    with pytest.raises(ValueError):
        rules_for_mode("zigzag")
    assert set(MODES) == {"cascade", "megatron", "megatron_sp"}


def test_logical_to_pspec_resolves_through_rules():
    r = rules_for_mode("megatron")
    m2 = FakeMesh((4, 8))
    assert logical_to_pspec(("batch", "seq", "act_heads"), m2, r) == \
        P(("data",), None, "model")
    m3 = FakeMesh((2, 4, 8), ("pod", "data", "model"))
    assert logical_to_pspec(("batch", None, "vocab"), m3, r) == \
        P(("pod", "data"), None, "model")
    # unknown logical names replicate rather than raise
    assert logical_to_pspec(("no_such_axis",), m2, r) == P(None)


# ---------------------------------------------------------------------------
# ParamSpec / init_params / abstract_params
# ---------------------------------------------------------------------------


def test_param_spec_defaults_and_rank_check():
    s = ParamSpec((16, 8), ("row_in", "fsdp"))
    assert s.dtype == jnp.bfloat16 and s.init == "normal" and s.scale is None
    with pytest.raises(ValueError):
        ParamSpec((16, 8), ("row_in",))


SPECS = {
    "w": ParamSpec((8, 4), ("row_in", "fsdp")),
    "b": ParamSpec((4,), (None,), jnp.float32, init="zeros"),
    "g": ParamSpec((4,), (None,), jnp.float32, init="ones"),
    "emb": ParamSpec((16, 8), ("vocab", "embed"), jnp.float32, init="embed"),
}


def test_init_params_deterministic_per_key():
    a = init_params(jax.random.PRNGKey(7), SPECS)
    b = init_params(jax.random.PRNGKey(7), SPECS)
    c = init_params(jax.random.PRNGKey(8), SPECS)
    for k in SPECS:
        np.testing.assert_array_equal(np.asarray(a[k], np.float32),
                                      np.asarray(b[k], np.float32))
    assert not np.array_equal(np.asarray(a["w"], np.float32),
                              np.asarray(c["w"], np.float32))


def test_init_params_splits_rng_per_leaf():
    p = init_params(KEY, SPECS)
    assert p["w"].shape == (8, 4) and p["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(p["b"]), 0.0)
    np.testing.assert_array_equal(np.asarray(p["g"]), 1.0)
    # distinct leaves get distinct keys
    assert not np.array_equal(np.asarray(p["emb"][:8], np.float32),
                              np.asarray(p["w"], np.float32))


def test_abstract_params_shapes_and_dtypes():
    av = abstract_params(SPECS)
    assert av["w"] == jax.ShapeDtypeStruct((8, 4), jnp.bfloat16)
    assert av["b"] == jax.ShapeDtypeStruct((4,), jnp.float32)


# ---------------------------------------------------------------------------
# sharding_ctx / shard_act
# ---------------------------------------------------------------------------


def test_shard_act_noop_outside_ctx():
    assert current_ctx() is None
    x = jnp.ones((4, 8, 16), jnp.float32)
    y = shard_act(x, "batch", "seq", "act_embed")
    assert y is x  # literally the identity, not just equal


def test_sharding_ctx_installs_and_restores():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    rules = rules_for_mode("megatron")
    assert current_ctx() is None
    with sharding_ctx(mesh, rules) as (m, r):
        assert current_ctx() == (mesh, rules) and m is mesh and r is rules
        x = jnp.ones((4, 8), jnp.float32)
        y = shard_act(x, "batch", "act_heads")   # constraint applies on 1x1
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert current_ctx() is None


def test_specs_to_shardings_real_mesh():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    sh = specs_to_shardings(SPECS, mesh, rules_for_mode("megatron"))
    assert sh["w"].spec == P("model", ("data",))
    assert sh["b"].spec == P(None)
    params = jax.device_put(init_params(KEY, SPECS), sh)
    assert params["w"].shape == (8, 4)
