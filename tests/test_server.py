"""Async streaming front-end: parity, streaming, disconnect, shedding.

The contracts pinned here (all on the real model, debug mesh):

* **async == blocking** — requests driven concurrently through
  :class:`AsyncServeServer` produce token-for-token the results of the
  blocking ``ServeBatcher.run()`` path, with ZERO new lowerings once the
  bucket's masked-decode executable is warm (streaming is a host fetch
  per micro-run, never a new program);
* **streamed deltas ARE the result** — concatenating a request's
  per-micro-run stream yields exactly its final token list;
* **disconnect cancels at the boundary** — a consumer that abandons its
  stream after the first token triggers a boundary cancellation: the
  slot is freed mid-prefill or mid-decode, its state lanes are wiped
  (``StatePool.reset_slots``), and the slot's next tenant decodes
  exactly as if the canceled request never ran. Made deterministic by
  gating the scheduler's ``on_tokens`` hook on a threading.Event so the
  worker cannot reach the next boundary until the client has
  disconnected;
* **deadline shedding surfaces as** :class:`RequestShed` — an EDF-shed
  request raises in its waiting coroutine instead of hanging, and
  feasible requests on the same server still complete.
"""

import asyncio
import threading
import time

import pytest

from repro.configs import reduced_config
from repro.plan import MeshSpec, build_plan
from repro.serve import (
    AsyncServeServer,
    Bucket,
    BucketPolicy,
    DecodeRequest,
    RequestShed,
    ServeBatcher,
    make_policy,
)

K = 2          # steps_per_dispatch shared by every batcher in this module

# gap-robust prompts (top-2 logit gap clears float noise at any admission
# offset) — the same trace test_scheduler.py pins fifo/continuous parity on
_TRACE = [
    ("p0", [63, 51, 50], 7),
    ("p1", [33, 17, 32], 5),
    ("p2", [63, 1], 2),
    ("p3", [30, 52], 4),
    ("p4", [39, 53], 7),
    ("p5", [55, 44, 23], 7),
]


@pytest.fixture(scope="module")
def plan(test_seed):
    """One ExecutionPlan (shared executable cache) for the module,
    pre-warmed so every test can assert zero new lowerings."""
    cfg = reduced_config("yi_6b").with_(n_layers=2, vocab=64)
    p = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    with p.activate():
        b = p.make_batcher(policy=BucketPolicy([Bucket(64, 2)]),
                           schedule="continuous", steps_per_dispatch=K)
        b.init_demo_params(seed=0)
        b.submit(DecodeRequest("warmup", [1, 2], max_new_tokens=2))
        b.run()
    return p


def make_batcher(plan, test_seed, admission=None):
    with plan.activate():
        b = plan.make_batcher(policy=BucketPolicy([Bucket(64, 2)]),
                              schedule="continuous",
                              steps_per_dispatch=K, admission=admission)
        b.init_demo_params(seed=test_seed)
    return b


# ---------------------------------------------------------------------------
# ACCEPTANCE: async concurrent submission == blocking run(), zero lowerings
# ---------------------------------------------------------------------------


def test_async_generate_matches_blocking_run(plan, test_seed):
    bb = make_batcher(plan, test_seed)
    with plan.activate():
        for rid, p, n in _TRACE:
            bb.submit(DecodeRequest(rid, p, max_new_tokens=n))
        ref = bb.run()

    ba = make_batcher(plan, test_seed)
    warm_lowerings = ba.cache.stats()["lowerings"]

    async def drive():
        async with AsyncServeServer(ba) as server:
            return await asyncio.gather(*[
                server.generate(DecodeRequest(rid, p, max_new_tokens=n))
                for rid, p, n in _TRACE])

    with plan.activate():
        results = asyncio.run(drive())

    assert len(results) == len(_TRACE)
    for res in results:
        assert res.tokens == ref[res.request_id].tokens, res.request_id
    # streaming + concurrent arrival churn lowered NOTHING new
    assert ba.cache.stats()["lowerings"] == warm_lowerings
    assert ba.scheduler.refills > 0      # parity held across slot reuse


def test_streamed_deltas_equal_result_tokens(plan, test_seed):
    """For every request, the concatenation of its per-micro-run stream
    is exactly the blocking path's token list — no token is dropped,
    duplicated, or delivered out of order, and prompt-echo steps never
    leak into a stream."""
    bb = make_batcher(plan, test_seed)
    with plan.activate():
        for rid, p, n in _TRACE:
            bb.submit(DecodeRequest(rid, p, max_new_tokens=n))
        ref = bb.run()

    ba = make_batcher(plan, test_seed)

    async def consume(server, rid, p, n):
        toks = []
        async for t in server.stream(DecodeRequest(rid, p,
                                                   max_new_tokens=n)):
            toks.append(t)
        return rid, toks

    async def drive():
        async with AsyncServeServer(ba) as server:
            return await asyncio.gather(*[consume(server, rid, p, n)
                                          for rid, p, n in _TRACE])

    with plan.activate():
        streamed = dict(asyncio.run(drive()))
    for rid, _, n in _TRACE:
        assert streamed[rid] == ref[rid].tokens, rid
        assert len(streamed[rid]) == n


# ---------------------------------------------------------------------------
# disconnect -> boundary cancellation (deterministic via on_tokens gate)
# ---------------------------------------------------------------------------


def test_disconnect_cancels_and_slot_state_is_wiped(plan, test_seed):
    ref_b = make_batcher(plan, test_seed)
    with plan.activate():
        ref_b.submit(DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
        ref = ref_b.run()["late"].tokens

    b = make_batcher(plan, test_seed)
    sched = b.scheduler
    warm_lowerings = b.cache.stats()["lowerings"]
    gate = threading.Event()

    async def drive():
        async with AsyncServeServer(b) as server:
            # gate the worker: after it emits the victim's first delta it
            # blocks until the client has disconnected, so the cancel is
            # GUARANTEED to land while the victim is still in flight
            orig = sched.on_tokens

            def gated(deltas):
                orig(deltas)
                if "victim" in deltas:
                    gate.wait(timeout=30)

            sched.on_tokens = gated
            gen = server.stream(DecodeRequest("victim", [5, 9],
                                              max_new_tokens=30))
            first = await gen.__anext__()
            await gen.aclose()           # disconnect: cancel hits intake
            gate.set()                   # NOW let the worker reach the
            #                              boundary that applies it
            late = await server.generate(
                DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
            return first, late, server.stats()

    with plan.activate():
        first, late, stats = asyncio.run(drive())

    assert isinstance(first, int)
    assert sched.cancellations == 1      # boundary cancel actually ran
    assert b.pool.slot_resets >= 1       # ... and wiped the state lanes
    assert stats["outcomes"].get("cancelled") == 1
    assert stats["outcomes"].get("done") == 1
    # the canceled slot's successor decodes as if victim never existed
    assert late.tokens == ref
    assert b.cache.stats()["lowerings"] == warm_lowerings


def test_abandoned_stream_mid_prefill_cancels(plan, test_seed):
    """Disconnect while the victim's long prompt is still being chunk-fed
    (no tokens streamed yet): the cancel must still free the slot and
    wipe the partial prefill; a later request reusing the server decodes
    correctly."""
    ref_b = make_batcher(plan, test_seed)
    with plan.activate():
        ref_b.submit(DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
        ref = ref_b.run()["late"].tokens

    b = make_batcher(plan, test_seed)
    sched = b.scheduler
    long_prompt = [1 + (i * 7) % 61 for i in range(24)]   # 12 k=2 chunks
    mid_prefill = threading.Event()      # set when victim is mid-feed
    gate = threading.Event()
    fed_seen = []

    async def drive():
        async with AsyncServeServer(b) as server:
            orig_boundary = sched.on_boundary

            def hooked(pos, slots):
                for s in slots:
                    if s is not None and s.req.request_id == "victim" \
                            and 0 < s.fed < len(long_prompt) \
                            and not mid_prefill.is_set():
                        fed_seen.append(s.fed)
                        mid_prefill.set()
                        gate.wait(timeout=30)
                orig_boundary(pos, slots)

            sched.on_boundary = hooked
            gen = server.stream(DecodeRequest("victim", long_prompt,
                                              max_new_tokens=8))
            task = asyncio.ensure_future(gen.__anext__())
            # wait (off-thread) until the prompt is partially fed
            await asyncio.get_running_loop().run_in_executor(
                None, mid_prefill.wait, 30)
            task.cancel()                # client hangs up mid-prefill
            try:
                await task
            except asyncio.CancelledError:
                pass
            await gen.aclose()
            gate.set()
            late = await server.generate(
                DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
            return late

    with plan.activate():
        late = asyncio.run(drive())

    assert fed_seen and 0 < fed_seen[0] < len(long_prompt)
    assert sched.cancellations == 1
    assert b.pool.slot_resets >= 1
    assert late.tokens == ref


# ---------------------------------------------------------------------------
# deadline shedding -> RequestShed; submission errors propagate
# ---------------------------------------------------------------------------


def test_shed_raises_request_shed_and_server_survives(plan, test_seed):
    b = make_batcher(plan, test_seed, admission=make_policy("edf"))

    async def drive():
        async with AsyncServeServer(b) as server:
            with pytest.raises(RequestShed):
                # monotonic clock is far past 0.001 — expired on arrival
                await server.generate(DecodeRequest(
                    "doomed", [1, 2], max_new_tokens=4, deadline=0.001))
            ok = await server.generate(DecodeRequest(
                "ok", [5, 9], max_new_tokens=3,
                deadline=time.monotonic() + 300.0))
            return ok, server.stats()

    with plan.activate():
        ok, stats = asyncio.run(drive())
    assert len(ok.tokens) == 3
    assert b.scheduler.sheds == 1
    assert stats["outcomes"] == {"shed": 1, "done": 1}
    assert "doomed" not in b._pending_ids    # id freed, reusable


def test_duplicate_id_and_unservable_shape_raise(plan, test_seed):
    b = make_batcher(plan, test_seed)

    async def drive():
        async with AsyncServeServer(b) as server:
            t1 = asyncio.ensure_future(server.generate(
                DecodeRequest("dup", [5, 9], max_new_tokens=3)))
            await asyncio.sleep(0)       # let t1 register its stream
            with pytest.raises(ValueError, match="duplicate"):
                await server.generate(
                    DecodeRequest("dup", [1, 2], max_new_tokens=2))
            # shape no bucket can hold: error posted back to the stream
            with pytest.raises(ValueError, match="positions"):
                await server.generate(
                    DecodeRequest("huge", list(range(1, 60)),
                                  max_new_tokens=60))
            return await t1

    with plan.activate():
        res = asyncio.run(drive())
    assert len(res.tokens) == 3


def test_server_requires_continuous_schedule_and_start(plan, test_seed):
    with plan.activate():
        fifo_b = plan.make_batcher(policy=BucketPolicy([Bucket(64, 2)]))
    with pytest.raises(ValueError, match="continuous"):
        AsyncServeServer(fifo_b)

    b = make_batcher(plan, test_seed)
    server = AsyncServeServer(b)

    async def unstarted():
        with pytest.raises(RuntimeError, match="not started"):
            await server.generate(DecodeRequest("r", [1], max_new_tokens=1))

    asyncio.run(unstarted())


def test_quantile_nearest_rank_small_samples():
    """Regression for the stats() percentile helper: the old
    ``int(p * n)`` index overshot on small samples (p50 of two TTFTs
    reported the slower one; p50 of three skipped the median by luck of
    truncation). The shared nearest-rank definition — index
    ``ceil(p * n) - 1``, clamped — is pinned across n in {1, 2, 3, 100}
    and is what server TTFT, bucket latency, and benchmark tick
    percentiles all use now."""
    from repro.serve.batcher import quantile

    assert quantile([], 0.5) == 0.0
    # n=1: the only sample answers every quantile
    assert quantile([7.0], 0.5) == 7.0
    assert quantile([7.0], 0.99) == 7.0
    # n=2: p50 is the FIRST (rank ceil(1.0) = 1), p99 the second
    assert quantile([2.0, 1.0], 0.5) == 1.0
    assert quantile([1.0, 2.0], 0.99) == 2.0
    # n=3: p50 is the true median
    assert quantile([3.0, 1.0, 2.0], 0.5) == 2.0
    assert quantile([3.0, 1.0, 2.0], 0.99) == 3.0
    # n=100: classic nearest-rank ranks (p50 -> 50th, p99 -> 99th)
    v = [float(i) for i in range(1, 101)]
    assert quantile(v, 0.50) == 50.0
    assert quantile(v, 0.99) == 99.0
    assert quantile(v, 1.00) == 100.0
    assert quantile(v, 0.0) == 1.0
