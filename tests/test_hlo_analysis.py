"""HLO analyzer: parsing, trip-count scaling, collective accounting."""

import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    ModuleAnalysis,
    _group_size,
    _shape_bytes,
    _wire_bytes,
    analyze_hlo,
)

HLO = """\
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  ROOT %lt = pred[] compare(%p, %p), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[8,16]{1,0}) tuple()
  %w1 = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[32,16]{1,0} all-gather(%w1), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %r = f32[] constant(0)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[4]") == 8
    assert _shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert _shape_bytes("pred[]") == 1


def test_group_size_formats():
    assert _group_size("replica_groups=[2,4]<=[8]") == 4
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("no groups here", default=3) == 3


def test_wire_bytes_factors():
    assert _wire_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert _wire_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert _wire_bytes("reduce-scatter", 100, 4) == pytest.approx(300.0)
    assert _wire_bytes("all-to-all", 100, 4) == pytest.approx(75.0)
    assert _wire_bytes("collective-permute", 100, 4) == pytest.approx(100.0)
    assert _wire_bytes("all-reduce", 100, 1) == 0.0


def test_trip_count_scaling_and_collectives():
    st = analyze_hlo(HLO)
    # dot: 2 * (8*16) * 16 = 4096 flops per iter, x10 trips
    assert st.flops == pytest.approx(40960.0)
    # all-reduce in body: 512B * 2*(4-1)/4 = 768 per iter x10 = 7680
    # all-gather in entry: 2048B * 3/4 = 1536
    assert st.per_collective["all-reduce"] == pytest.approx(7680.0)
    assert st.per_collective["all-gather"] == pytest.approx(1536.0)
    assert st.collective_bytes == pytest.approx(7680.0 + 1536.0)
    assert st.collective_ops == {"all-reduce": 10, "all-gather": 1}


def test_comment_stripping():
    hlo = HLO.replace("f32[8,16]{1,0} get-tuple-element(%p), index=1",
                      "f32[8,16]{1,0} get-tuple-element(%p), /*index=5*/ index=1")
    st = analyze_hlo(hlo)
    assert st.flops == pytest.approx(40960.0)


def test_fusion_bodies_contribute_flops_not_bytes():
    hlo = """\
HloModule t, entry_computation_layout={()->f32[]}

%fc (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  ROOT %dot.9 = f32[4,4]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main () -> f32[] {
  %x = f32[4,4]{1,0} constant({...})
  %f = f32[4,4]{1,0} fusion(%x), kind=kLoop, calls=%fc
  ROOT %r = f32[] constant(0)
}
"""
    st = analyze_hlo(hlo)
    assert st.flops == pytest.approx(2 * 16 * 4)
    # bytes counted only at the fusion call site (operand+result), not inside
    assert st.bytes == pytest.approx(2 * 64)
