"""Known-bad fixture for RA301 (donation-safety). Never imported."""

import jax
import numpy as np


def reads_donated_after_dispatch(exe, params, state, feed):
    toks, new_state = exe.compiled(params, state, feed)
    stale = np.asarray(state)    # RA301: donated buffer read after dispatch
    return toks, new_state, stale


def loop_never_rebinds(exe, params, state, feeds):
    outs = []
    for feed in feeds:
        toks, _ = exe.compiled(params, state, feed)  # RA301: next iter
        outs.append(toks)                            # re-reads donated state
    return outs


def local_jit_donation(x):
    reset = jax.jit(lambda s: s * 0, donate_argnums=0)
    y = reset(x)
    return x + y                 # RA301: x was donated to `reset`
