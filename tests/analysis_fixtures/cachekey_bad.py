"""Known-bad fixture for RA201 (cachekey-completeness). Never imported.

`fusion` shapes the compiled computation (it reaches the builder) but
never reaches the cache key: two plans differing only in `fusion` would
share one executable. The key method also passes a keyword that is not
a CacheKey field.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class CacheKey:
    arch: str
    batch: int
    steps: int = 1


def make_fake_step(arch, batch, fusion):
    return (arch, batch, fusion)


class MiniPlan:
    def __init__(self, arch, cache):
        self.arch = arch
        self.cache = cache

    def _key(self, batch, steps=1, fusion=1):
        # BUG: `fusion` is a parameter but never reaches CacheKey;
        # BUG: `flavor` is not a CacheKey field.
        return CacheKey(arch=self.arch, batch=batch, flavor=steps)

    def serve_executable(self, batch, steps=1, fusion=1):
        build = lambda: make_fake_step(self.arch, batch, fusion)  # noqa: E731
        key = self._key(batch, steps=steps)  # BUG: fusion unkeyed
        return self.cache.get_or_build(key, build)
