"""Known-bad fixture for RA201: the speculative-decode regression.

Never imported. This is the exact mistake ISSUE 9 guards against:
``spec_k``/``draft_layers`` change the compiled computation (the fused
draft+verify scan has a different program for every draft signature) but
the cache key only carries batch geometry. Two plans differing only in
the draft signature would silently share one executable — the second one
would run the wrong program with zero error.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class CacheKey:
    arch: str
    batch: int
    max_len: int
    steps: int = 1


def make_fake_spec_step(arch, batch, max_len, spec_k, draft_layers):
    return (arch, batch, max_len, spec_k, draft_layers)


class MiniSpecPlan:
    def __init__(self, arch, cache):
        self.arch = arch
        self.cache = cache

    def _key(self, batch, max_len, steps=1, spec_k=0, draft_layers=0):
        # BUG: spec_k and draft_layers shape the executable (they pick
        # the draft prefix and the lane count of the fused scan) but
        # never reach CacheKey.
        return CacheKey(arch=self.arch, batch=batch, max_len=max_len,
                        steps=steps)

    def serve_executable(self, batch, max_len, steps=1, spec_k=0,
                         draft_layers=0):
        build = lambda: make_fake_spec_step(  # noqa: E731
            self.arch, batch, max_len, spec_k, draft_layers)
        key = self._key(batch, max_len, steps=steps)  # BUG: spec unkeyed
        return self.cache.get_or_build(key, build)
