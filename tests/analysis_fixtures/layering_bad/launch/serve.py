"""Known-bad fixture for RA501 (layering). Never imported.

A launcher doing the plan's job: banned low-level imports (one
laundered through the `wrappers` shim to prove re-export resolution),
direct lowering, and out-of-plan compilation.
"""

import jax
from repro.launch.steps import make_serve_step       # RA501: step builder
from repro.dist.sharding import specs_to_shardings   # RA501: sharding wiring
from wrappers import mode_rules                      # RA501: laundered

from repro.models import SHAPES


def main(cfg, mesh):
    rules = mode_rules("cascade")
    shardings = specs_to_shardings(SHAPES, mesh, rules)
    bundle = make_serve_step(cfg, SHAPES["decode"], mesh, rules=rules)
    exe = bundle.lower().compile()                   # RA501: direct lowering
    argmax = jax.jit(lambda l: l.argmax(-1))         # RA501: out-of-plan jit
    return exe, argmax, shardings
