"""Shim that launders a plan-internal symbol: importing `mode_rules`
from here is still importing `rules_for_mode` from the banned
`repro.dist.sharding` — RA501 resolves the re-export chain."""

from repro.dist.sharding import rules_for_mode as mode_rules

__all__ = ["mode_rules"]
