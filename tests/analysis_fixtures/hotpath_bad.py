"""Known-bad fixture for RA401 (hot-path-purity). Never imported.

Class names mirror the real hot scopes (AdmissionPolicy subclass,
ContinuousScheduler boundary method, AsyncServeServer worker method,
boundary hook target) with a banned device op in each.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np


class AdmissionPolicy:
    def select(self, pending, fits, now):
        raise NotImplementedError


class SyncingPolicy(AdmissionPolicy):
    def select(self, pending, fits, now):
        jax.block_until_ready(pending[0])   # RA401: sync per boundary
        return pending[0]


class ContinuousScheduler:
    def _admit(self, pending, freed):
        mask = jnp.zeros((len(freed),))     # RA401: device allocation
        return mask


class AsyncServeServer:
    def _worker(self):
        time.sleep(0.01)                    # RA401: blocks dispatch thread
        return np.asarray(self._last)       # RA401: device fetch

    def _install(self, sched):
        sched.on_boundary = self._hook

    def _hook(self, boundary):
        jax.device_get(boundary)            # RA401: hook target transfer
