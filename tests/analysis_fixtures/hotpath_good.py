"""Known-good twin for RA401: the same scopes doing host-only
bookkeeping. Never imported."""

import collections


class AdmissionPolicy:
    def select(self, pending, fits, now):
        raise NotImplementedError


class FifoLikePolicy(AdmissionPolicy):
    def select(self, pending, fits, now):
        return [r for r in pending if fits(r)]


class ContinuousScheduler:
    def _admit(self, pending, freed):
        taken = collections.deque()
        for slot in freed:
            if pending:
                taken.append((slot, pending.pop(0)))
        return taken


class AsyncServeServer:
    def _worker(self):
        while self._live:
            self._drain_intake()

    def _drain_intake(self):
        self._queue.extend(self._intake)
        self._intake.clear()
