"""Known-bad fixture for RA101 (retrace-hazard). Never imported."""

import jax
import numpy as np


def make_bad_step():
    stats = []  # mutable host state the traced body will capture

    def step(x, limit):
        if x > limit:                 # RA101 branch: python `if` on traced
            x = x - limit
        for i in range(int(x[0])):    # RA101 loop + concretize
            x = x + i
        return x + np.asarray(limit)  # RA101 host-roundtrip

    stats.append("warm")              # mutation in the enclosing scope
    return jax.jit(step), stats


def scan_branch(xs):
    def body(carry, x):
        if x > 0:                     # RA101 branch inside a scan body
            carry = carry + x
        return carry, x

    return jax.lax.scan(body, 0.0, xs)


def uses_mutable_closure():
    table = {}

    def kernel(v):
        return v * len(table)         # RA101 mutable-closure capture

    table["k"] = 1
    return jax.jit(kernel)


sized = jax.jit(lambda v, cfg: v * len(cfg), static_argnums=1)


def call_with_unhashable(v):
    return sized(v, [1, 2, 3])        # RA101 unhashable static argument
