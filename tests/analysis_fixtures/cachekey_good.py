"""Known-good twin for RA201: every compile-affecting parameter flows
through the key method into a CacheKey field. Never imported."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class CacheKey:
    arch: str
    batch: int
    steps: int = 1
    fusion: int = 1


def make_fake_step(arch, batch, fusion):
    return (arch, batch, fusion)


class MiniPlan:
    def __init__(self, arch, cache):
        self.arch = arch
        self.cache = cache

    def _key(self, batch, steps=1, fusion=1):
        return CacheKey(arch=self.arch, batch=batch, steps=steps,
                        fusion=fusion)

    def serve_executable(self, batch, steps=1, fusion=1):
        build = lambda: make_fake_step(self.arch, batch, fusion)  # noqa: E731
        key = self._key(batch, steps=steps, fusion=fusion)
        return self.cache.get_or_build(key, build)
