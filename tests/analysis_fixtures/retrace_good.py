"""Known-good twin for RA101: the same shapes, expressed trace-safely.
Never imported."""

import jax
import jax.numpy as jnp


def make_good_step(n_inner: int, paged=None):
    def step(x, limit):
        x = jnp.where(x > limit, x - limit, x)   # data-dependence in-graph
        if paged is not None:                    # trace-static closure config
            x = x + 1
        if x.ndim == 2:                          # shape branching is static
            x = x.sum(-1)
        for i in range(n_inner):                 # static trip count
            x = x + i
        return x

    return jax.jit(step)


def scan_where(xs):
    def body(carry, x):
        return carry + jnp.where(x > 0, x, 0.0), x

    return jax.lax.scan(body, 0.0, xs)


sized = jax.jit(lambda v, cfg: v * len(cfg), static_argnums=1)


def call_with_hashable(v):
    return sized(v, (1, 2, 3))                   # tuple statics hash fine
