"""Known-good twin for RA501: a genuinely thin plan client. Never
imported. Mirrors the real launcher's shape — config in, plan out,
executables only via the plan."""

from repro.configs import get_config
from repro.models import SHAPES
from repro.plan import ExecutionPlan


def main(arch: str, bucket_batch: int, bucket_len: int):
    cfg = get_config(arch)
    plan = ExecutionPlan.for_serve(cfg, mode="cascade")
    exe = plan.serve_executable(
        "masked_decode", batch=bucket_batch, max_len=bucket_len)
    return plan, exe, SHAPES
