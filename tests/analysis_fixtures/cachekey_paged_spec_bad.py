"""Known-bad fixture for RA201: the paged-speculative regression.

Never imported. The ISSUE-10 composition (speculative lanes over the
paged KV pool) threads TWO compile-affecting parameters through the
serve path: the draft signature AND the page geometry (the page table
becomes a ninth executable input whose width is ``max_len //
page_size``). This fixture keys the draft signature but DROPS ``paged``
on the floor — exactly the half-lifted bug a future edit could
reintroduce now that the two features share one code path: a dense-spec
plan and a paged-spec plan would silently share one executable, and the
paged one would run without its page-table input.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class CacheKey:
    arch: str
    batch: int
    max_len: int
    steps: int = 1
    spec: tuple = ()


def make_fake_paged_spec_step(arch, batch, max_len, spec, paged):
    return (arch, batch, max_len, spec, paged)


class MiniPagedSpecPlan:
    def __init__(self, arch, cache):
        self.arch = arch
        self.cache = cache

    def _key(self, batch, max_len, steps=1, spec=(), paged=()):
        # BUG: ``paged`` picks the page-table width of the compiled
        # program (and whether the draft KV twins live in the pool) but
        # never reaches CacheKey.
        return CacheKey(arch=self.arch, batch=batch, max_len=max_len,
                        steps=steps, spec=spec)

    def serve_executable(self, batch, max_len, steps=1, spec=(),
                         paged=()):
        build = lambda: make_fake_paged_spec_step(  # noqa: E731
            self.arch, batch, max_len, spec, paged)
        key = self._key(batch, max_len, steps=steps,
                        spec=spec)  # BUG: paged unkeyed
        return self.cache.get_or_build(key, build)
