"""Known-good twin for RA301: donated names are rebound in the dispatch
assignment, per the repo convention. Never imported."""

import jax


def rebinds_donated(exe, params, state, feed):
    toks, state = exe.compiled(params, state, feed)
    return toks, state


def loop_rebinds(exe, params, state, feeds):
    outs = []
    for feed in feeds:
        toks, state = exe.compiled(params, state, feed)
        outs.append(toks)
    return outs, state


def local_jit_rebind(x):
    reset = jax.jit(lambda s: s * 0, donate_argnums=0)
    x = reset(x)
    return x
