"""End-to-end system behaviour: the paper's toolflow from model-in to
firmware-out, on the paper's own evaluation workloads."""

import numpy as np
import pytest

from repro.core import (
    CompileConfig,
    DenseSpec,
    build_mlp_graph,
    compile_graph,
)

RNG = np.random.default_rng(0)


def _paper_7layer_mlp(batch=8):
    """The 7-layer 512x512 MLP used in paper Tables III and V."""
    layers = [DenseSpec(512, activation="relu",
                        bias=RNG.standard_normal(512) * 0.05)
              for _ in range(7)]
    return build_mlp_graph(batch=batch, f_in=512, layers=layers, seed=11)


def test_paper_7layer_mlp_compiles_and_runs():
    g = _paper_7layer_mlp()
    x = RNG.uniform(-1, 1, (8, 512)).astype(np.float32)
    m = compile_graph(g, CompileConfig(calib=x))
    y86 = m.predict(x, mode="x86")
    yai = m.predict(x, mode="aie")
    np.testing.assert_array_equal(y86, yai)          # bit-exact toolflow
    assert y86.shape == (8, 512)
    assert m.tiles_used <= 304                        # fits the VEK280 array
    assert m.placement_cost >= 0


def test_token_mlp_mixer_block():
    """Token-mixing MLP from Table III: [B*C, T] = [512, 196], 196->256->196."""
    layers = [DenseSpec(256, activation="relu"),
              DenseSpec(196, activation="relu")]
    g = build_mlp_graph(batch=64, f_in=196, layers=layers, seed=2)
    x = RNG.uniform(-1, 1, (64, 196)).astype(np.float32)
    m = compile_graph(g, CompileConfig(calib=x))
    np.testing.assert_array_equal(m.predict(x, "x86"), m.predict(x, "aie"))
    # non-divisible dims (196) forced zero padding in the packing pass
    d0 = m.graph["dense_0"]
    assert d0.packed["pad_in"] > 0 or d0.packed["pad_out"] > 0


def test_quantized_model_accuracy_reasonable():
    g = _paper_7layer_mlp()
    x = RNG.uniform(-1, 1, (8, 512)).astype(np.float32)
    m = compile_graph(g, CompileConfig(calib=x))
    h = x
    for n in g.compute_nodes():
        h = h @ n.params["weight"] + n.params["bias"]
        if n.params.get("relu"):
            h = np.maximum(h, 0)
    rel = np.abs(h - m.predict(x, "x86")).max() / (np.abs(h).max() + 1e-9)
    assert rel < 0.15, rel  # 7 chained int8 layers: error accumulates


def test_predict_quantized_io_modes():
    g = _paper_7layer_mlp()
    x = RNG.uniform(-1, 1, (8, 512)).astype(np.float32)
    m = compile_graph(g, CompileConfig(calib=x))
    y_float = m.predict(x, "x86")
    y_raw = m.predict(x, "x86", dequantize_output=False)
    np.testing.assert_allclose(
        y_float, y_raw.astype(np.float32) * 2.0 ** (-m.out_shift))


def test_throughput_model_produces_cycles():
    g = _paper_7layer_mlp(batch=128)
    m = compile_graph(g, CompileConfig())
    cyc = m.estimated_cycles(batch=128)
    assert cyc > 0
    interval_us = cyc / 1.25e9 / 128 * 1e6
    assert interval_us < 100  # sanity: sub-100us per sample (paper: 0.03us)
