"""Admission-policy properties on the host-level scheduler stand-ins.

Every test here drives the REAL :class:`ContinuousScheduler` (via the
``_serve_stubs`` fakes — positional-receipt tokens, null state pool), so
these are properties of the shipped admission seam, not of a model:

* **FIFO is byte-identical to the pre-policy scheduler** — the default
  :class:`FifoPolicy` produces the exact admit-event sequence (id, step,
  slot) of a frozen reimplementation of the old inline admission loop,
  over random streams;
* **strict priority + fairness + aging is starvation-free** — a class-2
  request under a sustained class-0 flood is admitted within a bounded
  number of steps (and WITHOUT aging it demonstrably starves: pure
  strict priority is the documented trade);
* **per-tenant fairness alternates tenants inside a class** — one chatty
  tenant cannot monopolize a priority class;
* **EDF never admits an expired request** — deadline <= now means shed
  at the boundary, reported through the shed channel, zero slot steps;
* **conservation survives every policy** — under boundary cancellation
  and shedding alike, every submitted id completes exactly once, or
  zero times if canceled/shed, with exact positional receipts.

Hypothesis variants widen the seeded streams when the dev dependency is
installed; the seeded twins always run.
"""

import collections

import pytest
from _serve_stubs import check_invariants, make_host_scheduler, run_host_trace
from conftest import hypothesis_or_skip_stub

import numpy as np

from repro.serve import DecodeRequest
from repro.serve.policy import (
    DeadlinePolicy,
    FifoPolicy,
    PriorityPolicy,
    make_policy,
)

given, settings, st = hypothesis_or_skip_stub()


# ---------------------------------------------------------------------------
# FIFO == the pre-policy scheduler, byte for byte
# ---------------------------------------------------------------------------


class _LegacyFifoOracle(FifoPolicy):
    """Frozen reimplementation of the scheduler's ORIGINAL inline
    admission loop (pop, scan for the first fit, splice the skipped
    prefix back). If :class:`FifoPolicy` ever drifts from this, the
    "fifo is the old behavior" guarantee is broken."""

    name = "legacy-oracle"

    def select(self, pending, fits, now):
        kept = collections.deque()
        chosen = None
        while pending:
            req = pending.popleft()
            if fits(req):
                chosen = req
                break
            kept.append(req)
        pending.extendleft(reversed(kept))
        return chosen


def _admit_trace(sched):
    return [(e.request_id, e.step, e.slot) for e in sched.events
            if e.kind == "admit"]


def _assert_fifo_matches_oracle(lengths, k, batch, max_len=64):
    new = run_host_trace(lengths, k, batch, max_len=max_len)
    old = run_host_trace(lengths, k, batch, max_len=max_len,
                         admission=_LegacyFifoOracle())
    assert _admit_trace(new[0]) == _admit_trace(old[0])
    assert {r: v.tokens for r, v in new[2].items()} == \
        {r: v.tokens for r, v in old[2].items()}
    check_invariants(*new[:3], k)


@pytest.mark.parametrize("seed", range(10))
def test_fifo_policy_matches_legacy_admission_seeded(seed):
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.integers(1, 7)), int(rng.integers(1, 13)))
               for _ in range(int(rng.integers(1, 32)))]
    _assert_fifo_matches_oracle(lengths, k=int(rng.choice([1, 2, 4])),
                                batch=int(rng.integers(1, 4)))


@given(st.lists(st.tuples(st.integers(min_value=1, max_value=6),
                          st.integers(min_value=1, max_value=12)),
                min_size=1, max_size=32),
       st.sampled_from([1, 2, 4]),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=80, deadline=None)
def test_fifo_policy_matches_legacy_admission_property(lengths, k, batch):
    _assert_fifo_matches_oracle(lengths, k, batch)


# ---------------------------------------------------------------------------
# strict priority: starvation-freedom (with aging) and its absence (without)
# ---------------------------------------------------------------------------


def _run_flood(aging_steps, flood_len=48):
    """One class-2 victim queued behind a sustained class-0 flood.

    batch=1, every request is one live step, and the ``on_boundary``
    hook keeps two class-0 requests queued until ``flood_len`` of them
    have been injected — the queue never runs dry on high-priority work
    while the victim waits. Returns (victim admit step or None, sched).
    """
    sched = make_host_scheduler(
        batch=1, max_len=256,
        admission=PriorityPolicy(aging_steps=aging_steps))
    victim = DecodeRequest("victim", [1], max_new_tokens=1, priority=2)
    pending = collections.deque([victim])
    injected = [0]

    def hook(pos, slots):
        while injected[0] < flood_len and sum(
                r.priority == 0 for r in pending) < 2:
            pending.append(DecodeRequest(f"flood{injected[0]}", [1],
                                         max_new_tokens=1, priority=0))
            injected[0] += 1

    sched.on_boundary = hook
    hook(0, [])                          # flood is already there at t=0
    results = sched.run(pending, None, {})
    admit = {e.request_id: e.step for e in sched.events
             if e.kind == "admit"}
    assert set(results) == set(admit)    # conservation under the flood
    return admit.get("victim"), sched


def test_priority_aging_prevents_starvation():
    """With aging, the victim is promoted one class per ``aging_steps``
    of wait: admitted within 2 * aging_steps + a slot turnover, long
    before the flood (48 single-step requests) would have drained."""
    aging = 8
    admit_step, sched = _run_flood(aging_steps=aging)
    assert admit_step is not None, "class-2 request starved despite aging"
    assert admit_step <= 2 * aging + 2, admit_step
    assert sched.admissions == 49        # victim + the whole flood


def test_priority_without_aging_starves():
    """aging_steps=0 is pure strict priority: the same flood starves the
    victim until the flood runs out — the documented trade, pinned so
    the starvation-freedom above is visibly aging's doing."""
    admit_step, _ = _run_flood(aging_steps=0)
    assert admit_step is not None        # flood is finite, victim eventually
    assert admit_step > 40               # ... but only after ~the whole flood


@pytest.mark.parametrize("seed", range(6))
def test_priority_starvation_bound_seeded(seed):
    """Randomized flood shapes: victim wait stays <= 2*aging + slack."""
    rng = np.random.default_rng(seed)
    aging = int(rng.integers(2, 12))
    admit_step, _ = _run_flood(aging_steps=aging,
                               flood_len=int(rng.integers(30, 64)))
    assert admit_step is not None
    assert admit_step <= 2 * aging + 2, (aging, admit_step)


def test_tenant_fairness_alternates_within_class():
    """Same class, tenant A floods, tenant B queues behind: with
    fairness the least-recently-admitted tenant wins each boundary, so
    admits alternate A,B,A,B while both have work — without it, strict
    queue order lets A drain first."""
    def reqs():
        a = [DecodeRequest(f"a{i}", [1], max_new_tokens=1, tenant="A")
             for i in range(6)]
        b = [DecodeRequest(f"b{i}", [1], max_new_tokens=1, tenant="B")
             for i in range(3)]
        return collections.deque(a + b)

    fair = make_host_scheduler(batch=1, admission=PriorityPolicy())
    fair.run(reqs(), None, {})
    assert [e.request_id for e in fair.events if e.kind == "admit"] == \
        ["a0", "b0", "a1", "b1", "a2", "b2", "a3", "a4", "a5"]

    unfair = make_host_scheduler(
        batch=1, admission=PriorityPolicy(fairness=False))
    unfair.run(reqs(), None, {})
    assert [e.request_id for e in unfair.events if e.kind == "admit"] == \
        ["a0", "a1", "a2", "a3", "a4", "a5", "b0", "b1", "b2"]


# ---------------------------------------------------------------------------
# EDF: deadline order, expired never admitted, shed channel
# ---------------------------------------------------------------------------


def test_edf_admits_in_deadline_order():
    reqs = [DecodeRequest("slack", [1], max_new_tokens=1, deadline=900.0),
            DecodeRequest("none", [1], max_new_tokens=1),
            DecodeRequest("tight", [1], max_new_tokens=1, deadline=50.0),
            DecodeRequest("mid", [1], max_new_tokens=1, deadline=400.0)]
    sched = make_host_scheduler(batch=1, admission=DeadlinePolicy())
    results = sched.run(collections.deque(reqs), None, {})
    admits = [e.request_id for e in sched.events if e.kind == "admit"]
    assert admits == ["tight", "mid", "slack", "none"]
    assert set(results) == {r.request_id for r in reqs}


def _edf_stream(rng, n):
    """Random deadlined stream: ~1/4 already expired at submission."""
    reqs = []
    for i in range(n):
        roll = rng.random()
        deadline = None
        if roll < 0.25:
            deadline = float(rng.uniform(-5, 0))     # expired before t=0
        elif roll < 0.75:
            deadline = float(rng.uniform(500, 900))  # comfortably feasible
        reqs.append(DecodeRequest(
            f"e{i}", [1 + (i + j) % 7
                      for j in range(int(rng.integers(1, 6)))],
            max_new_tokens=int(rng.integers(1, 10)), deadline=deadline))
    return reqs


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("k", [1, 4])
def test_edf_never_admits_expired_seeded(seed, k):
    """Every request expired at submission is shed (never admitted,
    reported through the shed channel + event); every admitted deadlined
    request still had time on the clock at its admit boundary."""
    rng = np.random.default_rng(seed)
    reqs = _edf_stream(rng, int(rng.integers(2, 24)))
    sched, reqs, results, _ = run_host_trace(
        None, k, batch=2, max_len=128, admission=DeadlinePolicy(),
        reqs=reqs)
    shed = sched.drain_shed()
    expired = {r.request_id for r in reqs
               if r.deadline is not None and r.deadline <= 0}
    assert expired <= shed               # everything pre-expired was shed
    assert sched.sheds == len(shed)
    shed_events = {e.request_id for e in sched.events if e.kind == "shed"}
    assert shed_events == shed
    by_id = {r.request_id: r for r in reqs}
    for e in sched.events:
        if e.kind == "admit" and by_id[e.request_id].deadline is not None:
            # the admit event's step is dispatch-local and the clock is
            # the global counter, so re-derive: admitted => not expired
            # at that boundary => deadline strictly ahead of SOME step
            # the request ran; the receipt proves it ran
            assert by_id[e.request_id].deadline > 0
    check_invariants(sched, reqs, results, k, shed=shed)


@given(st.integers(min_value=0, max_value=2 ** 32 - 1),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=60, deadline=None)
def test_edf_never_admits_expired_property(seed, k):
    rng = np.random.default_rng(seed)
    reqs = _edf_stream(rng, int(rng.integers(2, 24)))
    sched, reqs, results, _ = run_host_trace(
        None, k, batch=2, max_len=128, admission=DeadlinePolicy(),
        reqs=reqs)
    shed = sched.drain_shed()
    expired = {r.request_id for r in reqs
               if r.deadline is not None and r.deadline <= 0}
    assert expired <= shed
    check_invariants(sched, reqs, results, k, shed=shed)


def test_edf_all_expired_sheds_everything_without_livelock():
    reqs = [DecodeRequest(f"x{i}", [1], max_new_tokens=2, deadline=-1.0)
            for i in range(5)]
    sched = make_host_scheduler(batch=2, admission=DeadlinePolicy())
    results = sched.run(collections.deque(reqs), None, {})
    assert results == {}
    assert sched.drain_shed() == {r.request_id for r in reqs}
    assert sched.admissions == 0 and sched.micro_runs == 0


# ---------------------------------------------------------------------------
# conservation under cancellation, through every policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy_name", ["fifo", "priority", "edf"])
@pytest.mark.parametrize("seed", range(6))
def test_conservation_under_cancellation_all_policies(policy_name, seed):
    """Boundary cancellation (the async server's disconnect path) never
    breaks conservation regardless of admission policy: canceled ids
    complete zero times, shed ids zero times, everyone else exactly once
    with an exact positional receipt."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 28))
    reqs = []
    for i in range(n):
        deadline = None
        if policy_name == "edf" and rng.random() < 0.2:
            deadline = float(rng.uniform(-5, 0))     # some shed too
        elif policy_name == "edf":
            deadline = float(rng.uniform(500, 900))
        reqs.append(DecodeRequest(
            f"c{i}", [1 + (i + j) % 7
                      for j in range(int(rng.integers(1, 6)))],
            max_new_tokens=int(rng.integers(1, 10)),
            priority=int(rng.integers(0, 3)),
            tenant=f"t{int(rng.integers(0, 3))}", deadline=deadline))
    k = int(rng.choice([1, 2, 4]))
    sched, reqs, results, canceled = run_host_trace(
        None, k, batch=int(rng.integers(1, 4)), max_len=128,
        admission=make_policy(policy_name), reqs=reqs,
        cancel_at=(int(rng.integers(0, 24)), int(rng.integers(0, n))))
    shed = sched.drain_shed()
    check_invariants(sched, reqs, results, k, canceled=canceled,
                     shed=shed)
    assert sched.cancellations == len(canceled)


@given(st.integers(min_value=0, max_value=2 ** 32 - 1),
       st.sampled_from(["fifo", "priority", "edf"]))
@settings(max_examples=60, deadline=None)
def test_conservation_under_cancellation_property(seed, policy_name):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 20))
    reqs = [DecodeRequest(
        f"p{i}", [1 + (i + j) % 7 for j in range(int(rng.integers(1, 5)))],
        max_new_tokens=int(rng.integers(1, 8)),
        priority=int(rng.integers(0, 3)),
        tenant=f"t{int(rng.integers(0, 2))}",
        deadline=float(rng.uniform(500, 900))
        if policy_name == "edf" else None) for i in range(n)]
    k = int(rng.choice([1, 2, 4]))
    sched, reqs, results, canceled = run_host_trace(
        None, k, batch=2, max_len=128,
        admission=make_policy(policy_name), reqs=reqs,
        cancel_at=(int(rng.integers(0, 16)), int(rng.integers(0, n))))
    check_invariants(sched, reqs, results, k, canceled=canceled,
                     shed=sched.drain_shed())
