"""Continuous-batching scheduler: slot reuse inside in-flight dispatches.

The three acceptance properties this file pins down:

* **slot reuse is immediate** — under a staggered-finish trace with a
  deep queue, every freed slot is refilled on the very next dispatch
  step (refill gap == 1), and the newcomer's state lanes are reset so
  its tokens are exactly what a fresh decode would produce;
* **argmax parity with the FIFO path** — the same request set produces
  token-for-token identical greedy output under ``schedule="fifo"`` and
  ``schedule="continuous"``, float and ``--quantized`` alike (slot
  windows + RoPE's relative-position property make a request admitted at
  position 37 decode exactly as it would from 0);
* **zero new lowerings after warmup under churn** — a continuously
  churning request mix (new admissions mid-dispatch, multiple
  dispatches, fresh length mixes) drives exactly ONE masked-decode
  executable per bucket; after the first dispatch only the cache's hit
  counter moves.
"""

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.dist.sharding import init_params
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.serve import Bucket, BucketPolicy, DecodeRequest, ServeBatcher


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("yi_6b").with_(n_layers=2, vocab=64)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.PRNGKey(0),
                       build_model(cfg).param_specs())


def _staggered(tag, lengths, prompt_len=2):
    return [DecodeRequest(f"{tag}{i}", [1 + (i + j) % 7
                                        for j in range(prompt_len)],
                          max_new_tokens=n)
            for i, n in enumerate(lengths)]


# ---------------------------------------------------------------------------
# slot reuse: freed slots refill on the next step
# ---------------------------------------------------------------------------


def test_freed_slots_refill_within_one_step(cfg, mesh, params):
    """Staggered finish lengths with a deep queue: the scheduler must
    admit a waiting request into every freed slot on the very next
    dispatch step — the utilization contract continuous batching makes."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         ).load_params(params)
        for r in _staggered("r", [2, 8, 2, 8, 2, 2]):
            b.submit(r)
        out = b.run()
    sched = b.scheduler
    assert len(out) == 6
    assert sched.dispatches == 1            # everything fit in-flight
    assert sched.admissions == 6
    assert sched.refills == 4               # 2 initial + 4 slot reuses
    assert sched.max_refill_gap == 1        # refilled on the NEXT step

    # the event trace agrees: every free (except the trace tail) is
    # followed by an admit of the same slot one step later
    frees = {(e.slot, e.step) for e in sched.events if e.kind == "free"}
    admits = {(e.slot, e.step) for e in sched.events if e.kind == "admit"}
    refilled = [(s, t) for (s, t) in frees if (s, t + 1) in admits]
    assert len(refilled) == 4


def test_capacity_exhaustion_rolls_into_new_dispatch(cfg, mesh, params):
    """When a bucket's positions run out mid-queue, the dispatch drains
    and the remainder is served by a fresh dispatch at position 0 on
    reset pooled state — with correct tokens throughout."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(16, 2)]),
                         ).load_params(params)
        reqs = _staggered("c", [8, 8, 8, 8])   # need 11 positions each
        for r in reqs:
            b.submit(r)
        out = b.run()
    assert b.scheduler.dispatches == 2      # 2 requests per 16-pos dispatch
    assert all(len(out[r.request_id].tokens) == 8 for r in reqs)
    pool = b.pool.stats()["2x16"]
    assert pool["in_use"] == 0 and pool["created"] == 1
    assert pool["reused"] == 1              # second dispatch reused state


# ---------------------------------------------------------------------------
# ACCEPTANCE: token-for-token argmax parity with the FIFO path
# ---------------------------------------------------------------------------


# staggered finish lengths (forces mid-dispatch slot reuse), prompts
# chosen so every decode step's top-2 logit gap clears ~0.08 at ANY
# admission offset — RoPE rotates by the absolute angle, so a slot
# reused at position 37 computes the same scores as from 0 only up to
# float rounding; gaps below that noise may flip (the same contract the
# int8 parity test documents), so near-tie prompts don't belong here
_PARITY_TRACE = [
    ("p0", [63, 51, 50], 7),
    ("p1", [33, 17, 32], 5),
    ("p2", [63, 1], 2),
    ("p3", [30, 52], 4),
    ("p4", [39, 53], 7),
    ("p5", [55, 44, 23], 7),
]


@pytest.mark.parametrize("quantized", [False, True],
                         ids=["float", "quantized"])
def test_continuous_matches_fifo_argmax(cfg, mesh, params, quantized):
    """Identical request sets through both schedulers produce identical
    greedy tokens: reused slots never see a predecessor's KV, and the
    position offset of a mid-dispatch admission is invisible to RoPE
    attention. Float and int8-quantized decode alike."""
    with mesh:
        bf = ServeBatcher(cfg, mesh, quantized=quantized,
                          ).load_params(params)
        bc = ServeBatcher(cfg, mesh, quantized=quantized,
                          schedule="continuous").load_params(params)
        for rid, p, n in _PARITY_TRACE:
            bf.submit(DecodeRequest(rid, p, max_new_tokens=n))
            bc.submit(DecodeRequest(rid, p, max_new_tokens=n))
        rf, rc = bf.run(), bc.run()
    assert bc.scheduler.refills > 0         # parity held ACROSS slot reuse
    for rid, _, n in _PARITY_TRACE:
        assert rf[rid].tokens == rc[rid].tokens, rid
        assert len(rc[rid].tokens) == n
    if quantized:
        assert bc.cfg.quantized and bc.cfg.quantized_mlp
        assert all(k.quantized for k in bc.cache._entries)


def test_continuous_matches_fifo_on_hybrid_ssm(mesh):
    """The hybrid (Mamba2 + shared attention) family exercises the fresh
    lane hardest: a reused slot's SSM/conv state is pure recurrence — no
    window can hide a stale value, only the in-step per-slot reset."""
    cfg = reduced_config("zamba2_2_7b")
    params = init_params(jax.random.PRNGKey(0),
                         build_model(cfg).param_specs())
    res = {}
    for schedule in ("fifo", "continuous"):
        with mesh:
            b = ServeBatcher(cfg, mesh, schedule=schedule,
                             policy=BucketPolicy([Bucket(64, 2)]),
                             ).load_params(params)
            for rid, p, n in _PARITY_TRACE:
                b.submit(DecodeRequest(rid, p, max_new_tokens=n))
            res[schedule] = {k: v.tokens for k, v in b.run().items()}
    assert b.scheduler.refills > 0
    for rid, _, _ in _PARITY_TRACE:
        assert res["fifo"][rid] == res["continuous"][rid], rid


# ---------------------------------------------------------------------------
# ACCEPTANCE: zero new lowerings after warmup under churn
# ---------------------------------------------------------------------------


def test_continuous_zero_new_lowerings_under_churn(cfg, mesh, params):
    """A churning request mix — staggered lengths, mid-dispatch
    admissions, multiple dispatches, a length mix never seen during
    warmup — runs entirely on the one warm masked-decode executable."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         ).load_params(params)
        for r in _staggered("warm", [2, 6, 3]):
            b.submit(r)
        b.run()
        warm = dict(b.cache.stats())
        assert warm["compiles"] == 1        # ONE executable for the bucket

        for wave, lengths in enumerate([[8, 2, 5, 2], [3, 9, 2],
                                        [12, 2, 2, 4, 2]]):
            for r in _staggered(f"churn{wave}-", lengths, prompt_len=3):
                b.submit(r)
            out = b.run()
            assert len(out) == len(lengths)
        after = b.cache.stats()

    assert after["lowerings"] == warm["lowerings"]    # zero new lowerings
    assert after["compiles"] == warm["compiles"]
    assert after["misses"] == warm["misses"]
    assert after["hits"] > warm["hits"]
    assert b.scheduler.refills > 0


# ---------------------------------------------------------------------------
# scheduler bookkeeping
# ---------------------------------------------------------------------------


def test_scheduler_stats_and_metrics_shape(cfg, mesh, params):
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         ).load_params(params)
        for r in _staggered("s", [2, 5]):
            b.submit(r)
        b.run()
    stats = b.stats()
    assert 0 < stats["scheduler"]["busy_slot_fraction"] <= 1
    (label, bucket_stats), = stats["buckets"].items()
    assert bucket_stats["requests"] == 2
    assert bucket_stats["slot_steps"] > 0
    assert 0 < bucket_stats["busy_slot_fraction"] <= 1
    # fifo-only concepts stay zeroed on the continuous path
    assert bucket_stats["prefill_seconds"] == 0.0


def test_fifo_batcher_rejects_unknown_schedule(cfg, mesh):
    with pytest.raises(ValueError, match="schedule"):
        ServeBatcher(cfg, mesh, schedule="lifo")
