"""Continuous-batching scheduler: slot reuse, micro-runs, cancellation.

The acceptance properties this file pins down:

* **slot reuse is immediate** — under a staggered-finish trace with a
  deep queue, every freed slot is refilled at the very next micro-run
  boundary (refill gap == 1 for k=1, <= k in general), and the
  newcomer's state lanes are reset so its tokens are exactly what a
  fresh decode would produce;
* **argmax parity with the FIFO path across k** — the same request set
  produces token-for-token identical greedy output under
  ``schedule="fifo"`` and ``schedule="continuous"`` for
  ``steps_per_dispatch`` in {1, 2, 4}, float, ``--quantized``, and
  hybrid-SSM alike (slot windows + RoPE's relative-position property
  make a request admitted at position 37 decode exactly as it would
  from 0, whether the steps run one per dispatch or scanned k at a
  time);
* **chunked prefill == eager prefill** — a long prompt admitted as
  successive k-token feed-lane chunks across micro-runs produces the
  same tokens as the one-token-per-step eager path, in ~1/k the
  dispatches;
* **zero new lowerings after warmup under churn** — a continuously
  churning request mix drives exactly ONE masked-decode executable per
  (bucket, k); after the first dispatch only the cache's hit counter
  moves;
* **cancellation** — ``ServeBatcher.cancel`` frees an in-flight slot at
  the next micro-run boundary, wipes its state lanes, and the slot's
  next tenant decodes exactly as if the canceled request never ran;
* **scheduler invariants** (property-tested on a host-level executable
  stand-in, hypothesis + seeded streams): slot non-overlap, FIFO
  admission order within a bucket, refill gap <= k, and conservation —
  every submitted id completes exactly once (canceled ids: zero times).
"""

import jax
import numpy as np
import pytest
from _serve_stubs import check_invariants, run_host_trace
from conftest import hypothesis_or_skip_stub

from repro.configs import reduced_config
from repro.dist.sharding import init_params
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.serve import Bucket, BucketPolicy, DecodeRequest, ServeBatcher

given, settings, st = hypothesis_or_skip_stub()


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("yi_6b").with_(n_layers=2, vocab=64)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


@pytest.fixture(scope="module")
def params(cfg, test_seed):
    return init_params(jax.random.PRNGKey(test_seed),
                       build_model(cfg).param_specs())


def _staggered(tag, lengths, prompt_len=2):
    return [DecodeRequest(f"{tag}{i}", [1 + (i + j) % 7
                                        for j in range(prompt_len)],
                          max_new_tokens=n)
            for i, n in enumerate(lengths)]


# ---------------------------------------------------------------------------
# slot reuse: freed slots refill at the next micro-run boundary
# ---------------------------------------------------------------------------


def test_freed_slots_refill_within_one_step(cfg, mesh, params):
    """Staggered finish lengths with a deep queue: the scheduler must
    admit a waiting request into every freed slot at the very next
    dispatch step — the utilization contract continuous batching makes."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         ).load_params(params)
        for r in _staggered("r", [2, 8, 2, 8, 2, 2]):
            b.submit(r)
        out = b.run()
    sched = b.scheduler
    assert len(out) == 6
    assert sched.dispatches == 1            # everything fit in-flight
    assert sched.admissions == 6
    assert sched.refills == 4               # 2 initial + 4 slot reuses
    assert sched.max_refill_gap == 1        # refilled on the NEXT step

    # the event trace agrees: every free (except the trace tail) is
    # followed by an admit of the same slot one step later
    frees = {(e.slot, e.step) for e in sched.events if e.kind == "free"}
    admits = {(e.slot, e.step) for e in sched.events if e.kind == "admit"}
    refilled = [(s, t) for (s, t) in frees if (s, t + 1) in admits]
    assert len(refilled) == 4


def test_refill_gap_bounded_by_k_on_model(cfg, mesh, params):
    """With k=4 micro-runs, a freed slot waits at most until the next
    boundary: every refill gap is in [1, k]."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         steps_per_dispatch=4).load_params(params)
        for r in _staggered("g", [2, 8, 2, 8, 2, 2]):
            b.submit(r)
        out = b.run()
    sched = b.scheduler
    assert len(out) == 6
    assert sched.refills > 0
    assert 1 <= sched.max_refill_gap <= 4


def test_capacity_exhaustion_rolls_into_new_dispatch(cfg, mesh, params):
    """When a bucket's positions run out mid-queue, the dispatch drains
    and the remainder is served by a fresh dispatch at position 0 on
    reset pooled state — with correct tokens throughout."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(16, 2)]),
                         ).load_params(params)
        reqs = _staggered("c", [8, 8, 8, 8])   # need 11 positions each
        for r in reqs:
            b.submit(r)
        out = b.run()
    assert b.scheduler.dispatches == 2      # 2 requests per 16-pos dispatch
    assert all(len(out[r.request_id].tokens) == 8 for r in reqs)
    pool = b.pool.stats()["2x16"]
    assert pool["in_use"] == 0 and pool["created"] == 1
    assert pool["reused"] == 1              # second dispatch reused state


# ---------------------------------------------------------------------------
# ACCEPTANCE: token-for-token argmax parity with the FIFO path, k in {1,2,4}
# ---------------------------------------------------------------------------


# staggered finish lengths (forces mid-dispatch slot reuse), prompts
# chosen so every decode step's top-2 logit gap clears ~0.08 at ANY
# admission offset — RoPE rotates by the absolute angle, so a slot
# reused at position 37 computes the same scores as from 0 only up to
# float rounding; gaps below that noise may flip (the same contract the
# int8 parity test documents), so near-tie prompts don't belong here
_PARITY_TRACE = [
    ("p0", [63, 51, 50], 7),
    ("p1", [33, 17, 32], 5),
    ("p2", [63, 1], 2),
    ("p3", [30, 52], 4),
    ("p4", [39, 53], 7),
    ("p5", [55, 44, 23], 7),
]


@pytest.fixture(scope="module")
def hybrid_setup(test_seed):
    """One zamba2 (cfg, params) build shared by the whole k matrix."""
    hcfg = reduced_config("zamba2_2_7b")
    return hcfg, init_params(jax.random.PRNGKey(test_seed),
                             build_model(hcfg).param_specs())


@pytest.fixture(scope="module")
def fifo_reference(cfg, mesh, params, hybrid_setup):
    """Lazy per-variant fifo token reference shared across the k matrix."""
    cache = {}

    def get(variant):
        if variant in cache:
            return cache[variant]
        with mesh:
            if variant == "hybrid":
                hcfg, hparams = hybrid_setup
                b = ServeBatcher(hcfg, mesh,
                                 policy=BucketPolicy([Bucket(64, 2)]),
                                 ).load_params(hparams)
            else:
                b = ServeBatcher(cfg, mesh,
                                 quantized=(variant == "quantized"),
                                 ).load_params(params)
            for rid, p, n in _PARITY_TRACE:
                b.submit(DecodeRequest(rid, p, max_new_tokens=n))
            cache[variant] = {k: v.tokens for k, v in b.run().items()}
        return cache[variant]

    return get


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("quantized", [False, True],
                         ids=["float", "quantized"])
def test_continuous_matches_fifo_argmax(cfg, mesh, params, quantized, k,
                                        fifo_reference):
    """Identical request sets through both schedulers produce identical
    greedy tokens at every micro-run length: reused slots never see a
    predecessor's KV, and neither the position offset of a mid-dispatch
    admission nor the k-step scan is visible to RoPE attention. Float
    and int8-quantized decode alike."""
    ref = fifo_reference("quantized" if quantized else "float")
    with mesh:
        bc = ServeBatcher(cfg, mesh, quantized=quantized,
                          schedule="continuous",
                          steps_per_dispatch=k).load_params(params)
        for rid, p, n in _PARITY_TRACE:
            bc.submit(DecodeRequest(rid, p, max_new_tokens=n))
        rc = bc.run()
    assert bc.scheduler.refills > 0         # parity held ACROSS slot reuse
    for rid, _, n in _PARITY_TRACE:
        assert ref[rid] == rc[rid].tokens, (k, rid)
        assert len(rc[rid].tokens) == n
    if quantized:
        assert bc.cfg.quantized and bc.cfg.quantized_mlp
        assert all(key.quantized for key in bc.cache._entries)
    assert all(key.steps == k for key in bc.cache._entries
               if key.kind == "masked_decode")


@pytest.mark.parametrize("k", [1, 2, 4])
def test_continuous_matches_fifo_on_hybrid_ssm(mesh, k, fifo_reference,
                                               hybrid_setup):
    """The hybrid (Mamba2 + shared attention) family exercises the fresh
    lane hardest: a reused slot's SSM/conv state is pure recurrence — no
    window can hide a stale value, only the per-slot fresh reset the
    micro-run applies ahead of its scanned steps."""
    ref = fifo_reference("hybrid")
    hcfg, hparams = hybrid_setup
    with mesh:
        b = ServeBatcher(hcfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         steps_per_dispatch=k).load_params(hparams)
        for rid, p, n in _PARITY_TRACE:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        res = {r: v.tokens for r, v in b.run().items()}
    assert b.scheduler.refills > 0
    for rid, _, _ in _PARITY_TRACE:
        assert ref[rid] == res[rid], (k, rid)


# ---------------------------------------------------------------------------
# chunked prefill: k-token feed chunks == eager one-token-per-step
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_eager_on_long_prompt(cfg, mesh, params):
    """A prompt ~10 chunks long (3x anything the eager path ingests per
    boundary event) admitted chunk-by-chunk across micro-runs produces
    the same tokens as eager k=1 prefill, in ~1/k the dispatches."""
    long_prompt = [1 + (i * 7) % 61 for i in range(40)]
    res, micro_runs = {}, {}
    for k in (1, 4):
        with mesh:
            b = ServeBatcher(cfg, mesh, schedule="continuous",
                             policy=BucketPolicy([Bucket(128, 2)]),
                             steps_per_dispatch=k).load_params(params)
            b.submit(DecodeRequest("long", long_prompt, max_new_tokens=4))
            b.submit(DecodeRequest("rider", [9, 5], max_new_tokens=3))
            res[k] = {r: v.tokens for r, v in b.run().items()}
        micro_runs[k] = b.scheduler.micro_runs
    assert res[1]["long"] == res[4]["long"]
    assert res[1]["rider"] == res[4]["rider"]
    assert len(res[4]["long"]) == 4
    # 43 live steps: 43 micro-runs eagerly, ceil(43/4)=11 chunked
    assert micro_runs[4] <= (micro_runs[1] + 3) // 4 + 1


# ---------------------------------------------------------------------------
# ACCEPTANCE: zero new lowerings after warmup under churn (k in {1, 4})
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4])
def test_continuous_zero_new_lowerings_under_churn(cfg, mesh, params, k):
    """A churning request mix — staggered lengths, mid-dispatch
    admissions, multiple dispatches, a length mix never seen during
    warmup — runs entirely on the one warm masked-decode executable for
    this (bucket, k)."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         steps_per_dispatch=k).load_params(params)
        for r in _staggered("warm", [2, 6, 3]):
            b.submit(r)
        b.run()
        warm = dict(b.cache.stats())
        assert warm["compiles"] == 1        # ONE executable for the bucket

        for wave, lengths in enumerate([[8, 2, 5, 2], [3, 9, 2],
                                        [12, 2, 2, 4, 2]]):
            for r in _staggered(f"churn{wave}-", lengths, prompt_len=3):
                b.submit(r)
            out = b.run()
            assert len(out) == len(lengths)
        after = b.cache.stats()

    assert after["lowerings"] == warm["lowerings"]    # zero new lowerings
    assert after["compiles"] == warm["compiles"]
    assert after["misses"] == warm["misses"]
    assert after["hits"] > warm["hits"]
    assert b.scheduler.refills > 0


def test_micro_runs_amortize_dispatch_count(cfg, mesh, params):
    """k=4 serves the same trace in ~1/4 the executable calls of k=1."""
    runs = {}
    for k in (1, 4):
        with mesh:
            b = ServeBatcher(cfg, mesh, schedule="continuous",
                             policy=BucketPolicy([Bucket(64, 2)]),
                             steps_per_dispatch=k).load_params(params)
            for r in _staggered("a", [2, 8, 2, 8, 2, 2]):
                b.submit(r)
            b.run()
        runs[k] = b.scheduler.micro_runs
        assert b.scheduler.steps == b.scheduler.micro_runs * k
    assert runs[4] <= (runs[1] + 3) // 4 + 1


# ---------------------------------------------------------------------------
# cancellation: slot freed at the next boundary, state wiped, id dropped
# ---------------------------------------------------------------------------


def test_cancel_queued_request_never_runs(cfg, mesh, params):
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         ).load_params(params)
        b.submit(DecodeRequest("keep", [5, 9], max_new_tokens=3))
        b.submit(DecodeRequest("drop", [7, 11], max_new_tokens=3))
        assert b.cancel("drop") is True
        assert b.cancel("drop") is False    # unknown once removed
        out = b.run()
    assert set(out) == {"keep"}
    admitted = {e.request_id for e in b.scheduler.events
                if e.kind == "admit"}
    assert "drop" not in admitted
    # the id is free for reuse immediately
    with mesh:
        b.submit(DecodeRequest("drop", [7, 11], max_new_tokens=3))
        out = b.run()
    assert len(out["drop"].tokens) == 3


def test_cancel_inflight_slot_reused_and_state_wiped(cfg, mesh, params):
    """A mid-flight cancel (issued from the boundary hook) frees the slot
    at the next micro-run boundary; the next tenant of that exact slot
    decodes token-for-token what it decodes in a run where the canceled
    request never existed — i.e. the canceled KV/SSM lanes were wiped."""
    with mesh:
        ref_b = ServeBatcher(cfg, mesh, schedule="continuous",
                             policy=BucketPolicy([Bucket(64, 2)]),
                             steps_per_dispatch=2).load_params(params)
        ref_b.submit(DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
        ref = ref_b.run()["late"].tokens

        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         steps_per_dispatch=2).load_params(params)
        b.submit(DecodeRequest("victim", [5, 9], max_new_tokens=30))
        b.submit(DecodeRequest("other", [3, 4], max_new_tokens=30))
        b.submit(DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
        sched = b.scheduler

        def hook(pos, slots):
            if pos == 6:
                assert b.cancel("victim") is True

        sched.on_boundary = hook
        out = b.run()

    assert "victim" not in out
    assert set(out) == {"other", "late"}
    assert sched.cancellations == 1
    cancel_ev, = [e for e in sched.events if e.kind == "cancel"]
    assert cancel_ev.request_id == "victim" and cancel_ev.step == 6
    admit_late, = [e for e in sched.events
                   if e.kind == "admit" and e.request_id == "late"]
    # the canceled slot is reused at the SAME boundary
    assert admit_late.slot == cancel_ev.slot
    assert admit_late.step == cancel_ev.step
    # ... and its state was wiped: the successor decodes exactly what it
    # decodes when the canceled request never ran (nonzero admission
    # offset covered by the RoPE relative-position contract)
    assert out["late"].tokens == ref
    assert len(out["other"].tokens) == 30   # survivor unharmed
    assert b.pool.slot_resets >= 1          # host-side wipe actually ran


def test_cancel_mid_chunked_prefill_wipes_and_reuses(cfg, mesh, params):
    """Cancel a long-prompt request while its prompt is still being
    chunk-fed (``slot.fed < len(prompt)``, k=4): the boundary cancel must
    wipe the partially-prefilled KV lanes through
    ``StatePool.reset_slots``, and the successor admitted into that slot
    must get its own ``start`` lane — decoding token-for-token what it
    decodes in a run where the canceled request never existed."""
    long_prompt = [1 + (i * 7) % 61 for i in range(24)]   # 6 k=4 chunks
    with mesh:
        ref_b = ServeBatcher(cfg, mesh, schedule="continuous",
                             policy=BucketPolicy([Bucket(128, 2)]),
                             steps_per_dispatch=4).load_params(params)
        ref_b.submit(DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
        ref = ref_b.run()["late"].tokens

        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(128, 2)]),
                         steps_per_dispatch=4).load_params(params)
        b.submit(DecodeRequest("victim", long_prompt, max_new_tokens=8))
        b.submit(DecodeRequest("rider", [3, 4], max_new_tokens=40))
        b.submit(DecodeRequest("late", [7, 11, 13], max_new_tokens=4))
        sched = b.scheduler
        fed_at_cancel = []

        def hook(pos, slots):
            if pos == 8 and not fed_at_cancel:
                victim_slot, = [s for s in slots if s is not None
                                and s.req.request_id == "victim"]
                fed_at_cancel.append(victim_slot.fed)
                assert b.cancel("victim") is True

        sched.on_boundary = hook
        out = b.run()

    # the cancel really landed mid-prefill, not after it
    assert fed_at_cancel and 0 < fed_at_cancel[0] < len(long_prompt)
    assert set(out) == {"rider", "late"}
    assert sched.cancellations == 1
    assert b.pool.slot_resets >= 1          # partial prefill wiped
    cancel_ev, = [e for e in sched.events if e.kind == "cancel"]
    admit_late, = [e for e in sched.events
                   if e.kind == "admit" and e.request_id == "late"]
    # successor takes the canceled slot at the SAME boundary ...
    assert admit_late.step == cancel_ev.step == 8
    assert admit_late.slot == cancel_ev.slot
    # ... with a clean state and its own start lane
    assert out["late"].tokens == ref
    assert len(out["rider"].tokens) == 40   # survivor unharmed


def test_cancel_racing_completion_drops_tokens_and_frees_id(cfg, mesh,
                                                            params):
    """A cancel landing AFTER its request already finished (but before
    run() returned) must still honor the contract: the tokens are
    dropped, and the id is immediately reusable — even for a request
    resubmitted under the same id DURING the same run, which a stale
    cancel mark must not swallow."""
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         policy=BucketPolicy([Bucket(64, 2)]),
                         ).load_params(params)
        # old 'short' generates 2 tokens; the resubmitted one 3, so the
        # result length proves WHICH request produced the tokens
        b.submit(DecodeRequest("short", [5, 9], max_new_tokens=2))
        b.submit(DecodeRequest("rider", [3, 4], max_new_tokens=16))
        sched = b.scheduler
        resubmitted = []

        def hook(pos, slots):
            live = {s.req.request_id for s in slots if s is not None}
            # 'short' finished at step 2; cancel it well after the fact
            if pos == 8 and "short" not in live:
                assert b.cancel("short") is True
            if pos == 10 and not resubmitted:
                b.submit(DecodeRequest("short", [5, 9], max_new_tokens=3))
                resubmitted.append(True)

        sched.on_boundary = hook
        out = b.run()
        assert set(out) == {"rider", "short"}
        assert len(out["short"].tokens) == 3   # the RESUBMITTED request
        assert len(out["rider"].tokens) == 16
        assert sched.cancellations == 1        # old tokens dropped once
        assert not sched._canceled             # no stale mark left behind
        assert not sched._stale_cancels

        # and the id keeps working across runs
        b.submit(DecodeRequest("short", [5, 9], max_new_tokens=2))
        out = b.run()
    assert len(out["short"].tokens) == 2


def test_cancel_unknown_or_fifo_inflight_returns_false(cfg, mesh, params):
    with mesh:
        b = ServeBatcher(cfg, mesh).load_params(params)
        assert b.cancel("nope") is False
        b.submit(DecodeRequest("q", [1, 2], max_new_tokens=2))
        assert b.cancel("q") is True        # queued: removable under fifo
        assert b.run() == {}


# ---------------------------------------------------------------------------
# configuration validation
# ---------------------------------------------------------------------------


def test_steps_per_dispatch_validation(cfg, mesh):
    with pytest.raises(ValueError, match="steps_per_dispatch"):
        ServeBatcher(cfg, mesh, schedule="continuous", steps_per_dispatch=0)
    with pytest.raises(ValueError, match="continuous"):
        ServeBatcher(cfg, mesh, schedule="fifo", steps_per_dispatch=4)
    # bucket positions must tile into micro-runs
    with pytest.raises(ValueError, match="multiple"):
        ServeBatcher(cfg, mesh, schedule="continuous",
                     policy=BucketPolicy([Bucket(30, 2)]),
                     steps_per_dispatch=4)


def test_scheduler_stats_and_metrics_shape(cfg, mesh, params):
    with mesh:
        b = ServeBatcher(cfg, mesh, schedule="continuous",
                         ).load_params(params)
        for r in _staggered("s", [2, 5]):
            b.submit(r)
        b.run()
    stats = b.stats()
    assert 0 < stats["scheduler"]["busy_slot_fraction"] <= 1
    assert stats["scheduler"]["steps_per_dispatch"] == 1
    assert stats["scheduler"]["micro_runs"] == stats["scheduler"]["steps"]
    assert stats["scheduler"]["cancellations"] == 0
    (label, bucket_stats), = stats["buckets"].items()
    assert bucket_stats["requests"] == 2
    assert bucket_stats["slot_steps"] > 0
    assert 0 < bucket_stats["busy_slot_fraction"] <= 1
    # fifo-only concepts stay zeroed on the continuous path
    assert bucket_stats["prefill_seconds"] == 0.0


def test_fifo_batcher_rejects_unknown_schedule(cfg, mesh):
    with pytest.raises(ValueError, match="schedule"):
        ServeBatcher(cfg, mesh, schedule="lifo")


# ---------------------------------------------------------------------------
# property suite: scheduler invariants on a host-level executable stand-in
# ---------------------------------------------------------------------------
#
# The invariants below are pure scheduling facts — they hold for any
# model, so they are checked against the host-level fakes shared in
# ``_serve_stubs`` (positional-receipt tokens: any slot overlap,
# mis-slice, or double-completion corrupts a request's receipt). The
# admission-policy properties live in ``test_policies.py`` on the same
# stand-ins.

_check_invariants = check_invariants
_run_host_trace = run_host_trace


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_scheduler_invariants_seeded_streams(seed, k):
    """Seeded random arrival/length streams (runs even without
    hypothesis): non-overlap, FIFO-or-skip admission, gap <= k,
    conservation, positional receipts."""
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.integers(1, 7)), int(rng.integers(1, 13)))
               for _ in range(int(rng.integers(1, 32)))]
    cancel_at = ((int(rng.integers(0, 24)), int(rng.integers(0, 64)))
                 if rng.random() < 0.5 else None)
    sched, reqs, results, canceled = _run_host_trace(
        lengths, k, batch=int(rng.integers(1, 4)), cancel_at=cancel_at)
    _check_invariants(sched, reqs, results, k, canceled)
    assert sched.cancellations == len(canceled)


@given(st.lists(st.tuples(st.integers(min_value=1, max_value=6),
                          st.integers(min_value=1, max_value=12)),
                min_size=1, max_size=40),
       st.sampled_from([1, 2, 4, 8]),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=120, deadline=None)
def test_scheduler_invariants_property(lengths, k, batch):
    """Hypothesis-driven admission invariants over random streams."""
    sched, reqs, results, _ = _run_host_trace(lengths, k, batch)
    _check_invariants(sched, reqs, results, k)


@given(st.lists(st.tuples(st.integers(min_value=1, max_value=6),
                          st.integers(min_value=1, max_value=12)),
                min_size=2, max_size=24),
       st.sampled_from([1, 2, 4]),
       st.integers(min_value=0, max_value=20),
       st.integers(min_value=0, max_value=23))
@settings(max_examples=60, deadline=None)
def test_scheduler_conservation_under_cancellation(lengths, k, boundary,
                                                   idx):
    """Cancellation never breaks conservation: canceled ids complete
    zero times, everyone else exactly once."""
    sched, reqs, results, canceled = _run_host_trace(
        lengths, k, batch=2, cancel_at=(boundary * k, idx))
    _check_invariants(sched, reqs, results, k, canceled)


@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=10),
       st.integers(min_value=1, max_value=24),
       st.sampled_from([1, 2, 4, 8]),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=80, deadline=None)
def test_admission_is_fifo_for_uniform_streams(plen, n, count, k, batch):
    """When every request has the same shape (no capacity skips are
    possible among peers), admission order == submission order."""
    sched, reqs, results, _ = _run_host_trace([(plen, n)] * count, k, batch)
    admits = [e.request_id for e in sched.events if e.kind == "admit"]
    assert admits == [r.request_id for r in reqs]
    _check_invariants(sched, reqs, results, k)


def test_fifo_order_preserved_for_capacity_skips():
    """A request skipped for lack of remaining positions keeps its queue
    rank: it is admitted before anything submitted after it, as soon as
    capacity allows."""
    # big needs 8+24-1=31 of 32 positions; the shorts can slot around it
    lengths = [(8, 24), (2, 3), (2, 3), (8, 24)]
    sched, reqs, results, _ = _run_host_trace(lengths, 2, batch=2,
                                              max_len=32)
    _check_invariants(sched, reqs, results, 2)
    admits = [e.request_id for e in sched.events if e.kind == "admit"]
    # h3 (second big) cannot jump a dispatch ahead of h1/h2's completions
    assert admits.index("h1") < admits.index("h3")
    assert admits.index("h2") < admits.index("h3")


def test_host_trace_chunked_prefill_dispatch_count():
    """Receipt check at scale: a 512-token prompt costs ~512/k
    micro-runs, not 512 — the chunked-prefill admission headline."""
    lengths = [(512, 8)]
    counts = {}
    for k in (1, 8):
        sched, reqs, results, _ = _run_host_trace(lengths, k, batch=1,
                                                  max_len=1024)
        _check_invariants(sched, reqs, results, k)
        counts[k] = sched.micro_runs
    assert counts[1] == 519                 # one step per live position
    assert counts[8] == 65                  # ceil(519 / 8)
