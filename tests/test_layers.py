"""Layer-level correctness: attention chunking/decode parity, SSD vs naive
recurrence, RWKV batch-vs-stepwise parity, MoE routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import init_params
from repro.layers import attention, moe, rwkv, ssm
from repro.layers.linear import linear
from repro.layers.rope import apply_rope, rope_freqs

KEY = jax.random.PRNGKey(0)
B, S, D = 2, 16, 64


@pytest.fixture(scope="module")
def attn_setup():
    spec = attention.attention_spec(D, 8, 4, 8, "megatron", qkv_bias=True)
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (B, S, D), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return p, x, pos


def test_chunked_equals_unchunked(attn_setup):
    p, x, pos = attn_setup
    kw = dict(n_heads=8, n_kv=4, head_dim=8)
    y1 = attention.self_attention(p, x, pos, q_chunk=4, **kw)
    y2 = attention.self_attention(p, x, pos, q_chunk=10**9, **kw)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=2e-2)


def test_causality(attn_setup):
    """Perturbing a future token must not change past outputs."""
    p, x, pos = attn_setup
    kw = dict(n_heads=8, n_kv=4, head_dim=8)
    y1 = attention.self_attention(p, x, pos, **kw)
    x2 = x.at[:, -1].set(x[:, -1] + 1.0)
    y2 = attention.self_attention(p, x2, pos, **kw)
    np.testing.assert_array_equal(
        np.asarray(y1[:, :-1], np.float32), np.asarray(y2[:, :-1], np.float32))


def test_decode_matches_full_forward(attn_setup):
    p, x, pos = attn_setup
    kw = dict(n_heads=8, n_kv=4, head_dim=8)
    y_full = attention.self_attention(p, x, pos, **kw)
    # build a cache from the first S-1 tokens
    k = linear(p["wk"], x).reshape(B, S, 4, 8)
    v = linear(p["wv"], x).reshape(B, S, 4, 8)
    k = apply_rope(k, pos, rope_freqs(8))
    ck = jnp.zeros((B, S, 4, 8), jnp.bfloat16).at[:, :S - 1].set(k[:, :S - 1])
    cv = jnp.zeros((B, S, 4, 8), jnp.bfloat16).at[:, :S - 1].set(v[:, :S - 1])
    out, nk, nv = attention.decode_self_attention(
        p, x[:, S - 1:S], ck, cv, jnp.int32(S - 1), **kw)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(y_full[:, S - 1:S], np.float32),
        atol=2e-2)
    # cache got the new token written
    np.testing.assert_allclose(np.asarray(nk[:, S - 1], np.float32),
                               np.asarray(k[:, S - 1], np.float32), atol=2e-2)


def test_gqa_head_grouping(attn_setup):
    """With 8 q-heads over 4 kv-heads, groups of 2 share each kv head."""
    q = jax.random.normal(KEY, (B, S, 8, 8), jnp.float32)
    k = jax.random.normal(KEY, (B, S, 4, 8), jnp.float32)
    v = jax.random.normal(KEY, (B, S, 4, 8), jnp.float32)
    y = attention.mha(q, k, v, causal=True)
    # brute-force reference
    ref = np.zeros((B, S, 8, 8), np.float32)
    qn, kn, vn = map(np.asarray, (q, k, v))
    for h in range(8):
        kv = h // 2
        sc = np.einsum("bqd,bsd->bqs", qn[:, :, h], kn[:, :, kv]) / np.sqrt(8)
        mask = np.tril(np.ones((S, S), bool))
        sc = np.where(mask[None], sc, -1e30)
        w = np.exp(sc - sc.max(-1, keepdims=True))
        w = w / w.sum(-1, keepdims=True)
        ref[:, :, h] = np.einsum("bqs,bsd->bqd", w, vn[:, :, kv])
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4)


# ---------------------------------------------------------------------------


def test_ssd_chunked_vs_naive():
    H, P, N = 4, 8, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dA = jnp.asarray(-np.abs(rng.standard_normal((B, S, H))) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y, fin = ssm.ssd_chunked(x, dA, Bm, Cm, chunk=4)
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        h = h * np.exp(np.asarray(dA[:, t]))[..., None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(x[:, t]), np.asarray(Bm[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(Cm[:, t])))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(fin), h, atol=1e-4)


def test_mamba2_decode_matches_chunked():
    spec = ssm.mamba2_spec(D, expand=2, head_dim=8, d_state=8, mode="megatron")
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (B, S, D), jnp.bfloat16)
    y_full = ssm.mamba2(p, x, head_dim=8, d_state=8, chunk=4)
    st = jnp.zeros((B, 16, 8, 8), jnp.float32)
    cv = jnp.zeros((B, 3, 2 * D), jnp.float32)
    outs = []
    for t in range(S):
        o, st, cv = ssm.mamba2_decode(p, x[:, t:t + 1], st, cv, head_dim=8)
        outs.append(o)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full, np.float32), atol=0.2)


def test_rwkv_stepwise_matches_batch():
    spec = rwkv.rwkv6_spec(D, 4 * D, head_dim=8, mode="megatron")
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (B, S, D), jnp.bfloat16)
    y_batch, last, Sfin = rwkv.rwkv6_time_mix(p, x, head_dim=8,
                                              return_state=True)
    prev = jnp.zeros((B, D), jnp.bfloat16)
    Swk = jnp.zeros((B, 8, 8, 8), jnp.float32)
    outs = []
    for t in range(S):
        o, prev, Swk = rwkv.rwkv6_time_mix(
            p, x[:, t:t + 1], head_dim=8, tm_prev=prev, wkv_state=Swk,
            return_state=True)
        outs.append(o)
    y_step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_step, np.float32),
                               np.asarray(y_batch, np.float32), atol=0.1)
    np.testing.assert_allclose(np.asarray(Swk), np.asarray(Sfin), atol=1e-2)


def test_wkv_chunked_exact():
    rng = np.random.default_rng(0)
    T = 64
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, 2, 8)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, T, 2, 8)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
    y1, f1 = rwkv.wkv_scan(r, k, v, w, u, chunk=16)
    y2, f2 = rwkv.wkv_scan(r, k, v, w, u, chunk=10**9)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


# ---------------------------------------------------------------------------


def test_moe_routes_to_topk_and_combines():
    E, k = 8, 2
    spec = moe.moe_spec(D, 128, E, "megatron")
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (B, S, D), jnp.bfloat16)
    y, aux = moe.moe(p, x, n_experts=E, top_k=k, capacity_factor=4.0)
    assert y.shape == x.shape
    assert jnp.isfinite(y.astype(jnp.float32)).all()
    assert float(aux) >= 1.0 - 1e-3  # switch aux loss lower bound is 1


def test_moe_capacity_drops_tokens_not_crashes():
    E, k = 4, 2
    spec = moe.moe_spec(D, 64, E, "megatron")
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (B, S, D), jnp.bfloat16)
    # capacity_factor tiny -> heavy dropping, still well-defined output
    y, _ = moe.moe(p, x, n_experts=E, top_k=k, capacity_factor=0.1)
    assert jnp.isfinite(y.astype(jnp.float32)).all()


def test_moe_grouped_matches_global():
    """Group-limited dispatch == global sort when capacity is ample."""
    E, k = 8, 2
    spec = moe.moe_spec(D, 128, E, "megatron")
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (4, 16, D), jnp.bfloat16)
    y1, a1 = moe.moe(p, x, n_experts=E, top_k=k, capacity_factor=8.0)
    y2, a2 = moe.moe(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                     n_groups=4)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=2e-2)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-5)


def test_flash_path_in_mha():
    """The opt-in Pallas flash path agrees with the pure-JAX block."""
    q = jax.random.normal(KEY, (2, 32, 4, 16), jnp.float32)
    k = jax.random.normal(KEY, (2, 32, 2, 16), jnp.float32)
    v = jax.random.normal(KEY, (2, 32, 2, 16), jnp.float32)
    want = attention.mha(q, k, v, causal=True)
    attention.USE_FLASH_KERNEL = True
    try:
        got = attention.mha(q, k, v, causal=True)
    finally:
        attention.USE_FLASH_KERNEL = False
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fit_pspec_divisibility_and_duplicates():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.dist.sharding import fit_pspec

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:  # noqa: N801
            shape = (4, 8)

    m = FakeMesh()
    # indivisible dims drop axes
    assert fit_pspec((3, 16), P("data", "model"), m) == P(None, "model")
    # composite axes keep the divisible prefix
    assert fit_pspec((8,), P(("data", "model"),), m) == P(("data",))
    # duplicate mesh axis: first dim wins
    assert fit_pspec((32, 32), P("model", "model"), m) == P("model", None)


def test_moe_gate_weights_scale_output():
    """With capacity ample, doubling router logits sharpens but keeps
    normalization: gates per token sum to 1 (renormalized top-k)."""
    E, k = 4, 2
    spec = moe.moe_spec(D, 64, E, "megatron")
    p = init_params(KEY, spec)
    x = jax.random.normal(KEY, (1, 4, D), jnp.bfloat16)
    logits = jnp.einsum("td,de->te", x.reshape(-1, D).astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    vals, _ = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    renorm = vals / vals.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(renorm.sum(-1)), 1.0, atol=1e-6)
