import os
import sys

# src-layout import path (tests run as `PYTHONPATH=src pytest tests/`, but be
# robust when invoked without it)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device. Multi-device integration tests spawn
# subprocesses that set XLA_FLAGS themselves (see test_distributed.py).
