import os
import sys

# src-layout import path (tests run as `PYTHONPATH=src pytest tests/`, but be
# robust when invoked without it)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device. Multi-device integration tests spawn
# subprocesses that set XLA_FLAGS themselves (see test_distributed.py).

import pytest  # noqa: E402

# One seed for every deterministic fixture in the suite. Override with
# REPRO_TEST_SEED to shake out accidental seed-coupling (the contract
# tests — parity, gap-robust prompts — are documented to hold for ANY
# seed; a failure under a different seed is a real finding, not flake).
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


@pytest.fixture(scope="session")
def test_seed() -> int:
    """The suite-wide deterministic seed (REPRO_TEST_SEED to override)."""
    return SEED


def hypothesis_or_skip_stub():
    """Return (given, settings, st), real or stubbed.

    With the ``hypothesis`` dev dependency installed this is the real
    library; without it, ``@given(...)`` marks the test skipped (and the
    ``st`` stand-in absorbs any strategy expression) so the rest of the
    module's tests still collect and run.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        class _AnyStrategy:
            def __call__(self, *args, **kwargs):
                return self

            def __getattr__(self, name):
                return self

        def given(*args, **kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*args, **kwargs):
            return lambda f: f

        return given, settings, _AnyStrategy()
