"""Speculative decoding lanes: host accept/rollback law + greedy parity.

Two layers of evidence, mirroring the scheduler suite:

* **host-fake property tests** — the REAL :class:`ContinuousScheduler`
  in spec mode over ``_serve_stubs.SpecHostExe``, whose verify lane
  emits LOCAL positional receipts (``local cursor + 1``). Receipts make
  the accept-prefix law an arithmetic identity: whatever mismatch
  schedule the fake draft plays — rollbacks, continuation requeues,
  cancels mid-speculation, chunked prefill — every completed request
  must hold exactly ``[P, P+1, ..., P+n-1]``. Conservation, carry
  hygiene, and guaranteed progress ride along;
* **real-model parity matrix** — speculation is an ACCELERATION, never
  a model change: greedy streams with ``speculative=k`` are asserted
  token-identical to plain continuous decode for k in {1, 4}, float and
  ``quantized=True`` alike, on gap-robust prompts (top-2 logit gaps
  clear float rounding, so block-verify's k-position scoring cannot
  flip a tie), across slot reuse, plus a rollback-stress run with the
  shallowest possible draft and zero post-warmup lowerings.
"""

import jax
import numpy as np
import pytest
from _serve_stubs import (
    check_spec_invariants,
    run_paged_spec_host_trace,
    run_spec_host_trace,
    spec_expected_receipt,
)
from conftest import hypothesis_or_skip_stub

from repro.configs import reduced_config
from repro.dist.sharding import init_params
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.serve import Bucket, BucketPolicy, DecodeRequest, ServeBatcher

given, settings, st = hypothesis_or_skip_stub()


# ---------------------------------------------------------------------------
# host-fake property tests: the accept/rollback law on the real scheduler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_spec_invariants_seeded_streams(seed, k):
    """Random arrival/length streams x random mismatch schedules x
    optional mid-speculation cancel: receipts, conservation, carry."""
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.integers(1, 7)), int(rng.integers(1, 13)))
               for _ in range(int(rng.integers(1, 24)))]
    mismatch = {int(p) for p in rng.integers(0, 40,
                                             size=int(rng.integers(0, 12)))}
    cancel_at = ((int(rng.integers(0, 24)), int(rng.integers(0, 64)))
                 if rng.random() < 0.5 else None)
    sched, reqs, results, canceled = run_spec_host_trace(
        lengths, k, batch=int(rng.integers(1, 4)), mismatch=mismatch,
        cancel_at=cancel_at)
    check_spec_invariants(sched, reqs, results, canceled)
    assert sched.cancellations == len(canceled)


@given(st.lists(st.tuples(st.integers(min_value=1, max_value=6),
                          st.integers(min_value=1, max_value=12)),
                min_size=1, max_size=24),
       st.sampled_from([2, 4, 8]),
       st.integers(min_value=1, max_value=3),
       st.sets(st.integers(min_value=0, max_value=40), max_size=16))
@settings(max_examples=80, deadline=None)
def test_accept_prefix_law_property(lengths, k, batch, mismatch):
    """The committed stream is invariant under the draft's mistakes:
    any mismatch schedule only stretches the schedule (rollbacks,
    requeues), never changes, drops, or duplicates a receipt."""
    sched, reqs, results, _ = run_spec_host_trace(
        lengths, k, batch, mismatch=mismatch)
    check_spec_invariants(sched, reqs, results)
    if not mismatch:
        assert sched.spec_rollbacks == 0


@given(st.lists(st.tuples(st.integers(min_value=1, max_value=6),
                          st.integers(min_value=1, max_value=12)),
                min_size=2, max_size=16),
       st.sampled_from([2, 4]),
       st.integers(min_value=0, max_value=20),
       st.integers(min_value=0, max_value=23),
       st.sets(st.integers(min_value=0, max_value=40), max_size=12))
@settings(max_examples=60, deadline=None)
def test_spec_conservation_under_cancellation(lengths, k, boundary, idx,
                                              mismatch):
    """Cancelling a request mid-speculation (possibly mid-rollback)
    never breaks conservation: the canceled id completes zero times and
    leaks no carry; everyone else keeps exact receipts."""
    sched, reqs, results, canceled = run_spec_host_trace(
        lengths, k, batch=2, mismatch=mismatch,
        cancel_at=(boundary * k, idx))
    check_spec_invariants(sched, reqs, results, canceled)


def test_chunked_prefill_meets_speculation():
    """A prompt many micro-runs long feeds in k-token chunks (feeds are
    never rolled back), then decodes speculatively through a hostile
    mismatch schedule — receipts stay exact and prefill still amortizes."""
    sched, reqs, results, _ = run_spec_host_trace(
        [(40, 6)], 8, batch=1, max_len=128, mismatch=set(range(0, 60, 3)))
    check_spec_invariants(sched, reqs, results)
    assert results["s0"].tokens == spec_expected_receipt(40, 6)
    # 40 feed steps cost ceil(40/8)=5 micro-runs, not 40
    assert sched.spec_rollbacks > 0


def test_rollbacks_requeue_as_continuations():
    """A draft that is wrong at every position burns ~k-1 bucket
    positions per committed token, exhausting the window: the slot must
    requeue as a continuation (prompt := prompt + committed) and the
    final stream must still be exact, with no leaked carry."""
    sched, reqs, results, _ = run_spec_host_trace(
        [(2, 12)], 8, batch=1, max_len=32, mismatch=set(range(64)))
    check_spec_invariants(sched, reqs, results)
    assert sched.spec_continuations >= 1
    assert sched.spec_rollbacks >= 3
    assert results["s0"].tokens == spec_expected_receipt(2, 12)


def test_continuation_outgrowing_bucket_delivers_partial():
    """When rollbacks stretch a continuation's need past every bucket,
    the committed prefix is delivered rather than dropped (and counted
    as a partial result)."""
    sched, reqs, results, _ = run_spec_host_trace(
        [(2, 20)], 8, batch=1, max_len=32, mismatch=set(range(64)))
    check_spec_invariants(sched, reqs, results)
    assert sched.spec_partial_results >= 1
    toks = results["s0"].tokens
    assert toks == spec_expected_receipt(2, len(toks))


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("k", [2, 4])
def test_paged_spec_invariants_seeded_streams(seed, k):
    """Speculative lanes over a REAL PageAllocator: random streams x
    mismatch schedules x optional mid-speculation cancel. Receipts stay
    exact through the page indirection, page invariants hold at every
    boundary (one writer per page, shared pages never draft-writable),
    and pages conserve after the drain."""
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.integers(1, 7)), int(rng.integers(1, 13)))
               for _ in range(int(rng.integers(1, 16)))]
    mismatch = {int(p) for p in rng.integers(0, 40,
                                             size=int(rng.integers(0, 12)))}
    cancel_at = ((int(rng.integers(0, 24)), int(rng.integers(0, 64)))
                 if rng.random() < 0.5 else None)
    sched, reqs, results, canceled = run_paged_spec_host_trace(
        lengths, k, batch=int(rng.integers(1, 4)), mismatch=mismatch,
        cancel_at=cancel_at)
    check_spec_invariants(sched, reqs, results, canceled)
    assert sched.cancellations == len(canceled)


def test_paged_spec_cancel_mid_speculation_reclaims_pages():
    """A cancel landing while the lane holds draft pages must reclaim
    the whole lease — committed and draft alike (the harness asserts
    only scratch + prefix-cache pages remain in use after the drain)."""
    sched, reqs, results, canceled = run_paged_spec_host_trace(
        [(3, 10), (2, 8), (4, 6)], 4, batch=2,
        mismatch=set(range(0, 40, 2)), cancel_at=(4, 0))
    check_spec_invariants(sched, reqs, results, canceled)
    assert canceled


def test_paged_chunked_prefill_meets_speculation():
    """Long prompt (many micro-runs of feeds) x hostile mismatches x
    page-local coordinates: the accept-prefix law holds unchanged and
    the lease's committed run grows page by page."""
    sched, reqs, results, _ = run_paged_spec_host_trace(
        [(40, 6)], 8, batch=1, max_len=128,
        mismatch=set(range(0, 60, 3)))
    check_spec_invariants(sched, reqs, results)
    assert results["s0"].tokens == spec_expected_receipt(40, 6)
    assert sched.spec_rollbacks > 0


def test_paged_spec_rollbacks_requeue_and_release():
    """The continuation-requeue path under paging: a hostile draft
    exhausts the window, the slot parks, and its lease is released (the
    harness would fail conservation if the requeue leaked it)."""
    sched, reqs, results, _ = run_paged_spec_host_trace(
        [(2, 12)], 8, batch=1, max_len=32, mismatch=set(range(64)))
    check_spec_invariants(sched, reqs, results)
    assert sched.spec_continuations >= 1
    assert results["s0"].tokens == spec_expected_receipt(2, 12)


def test_spec_counters_and_stats_shape():
    """Counter arithmetic: a perfect draft accepts every drafted token,
    the stats block exposes the acceptance headline, and feeds are never
    counted as draft work."""
    sched, reqs, results, _ = run_spec_host_trace(
        [(2, 9), (3, 7)], 4, batch=2)
    check_spec_invariants(sched, reqs, results)
    s = sched.stats()["spec"]
    assert s["spec_k"] == 4 and s["draft_layers"] == 1
    assert s["rollbacks"] == 0 and s["continuations"] == 0
    assert s["draft_tokens"] == s["accepted_tokens"] > 0
    assert s["accepted_tokens_per_dispatch"] > 1


# ---------------------------------------------------------------------------
# real-model parity matrix: spec on/off x k in {1, 4} x {float, quantized}
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("yi_6b").with_(n_layers=2, vocab=64)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


@pytest.fixture(scope="module")
def params(cfg, test_seed):
    return init_params(jax.random.PRNGKey(test_seed),
                       build_model(cfg).param_specs())


# gap-robust prompts (the paged-benchmark trick): tails spread across
# the vocab so every decode step's top-2 logit gap clears BOTH float
# rounding noise and the ~0.05 int8 quantization noise — block-verify
# re-associates sums and evaluates RoPE at LOCAL positions, which
# yields equal scores but not bitwise-equal floats, and the quantized
# head can flip ties narrower than its resolution (the int8 contract)
_SPEC_TRACE = [
    (f"g{i}", [2 + (7 * i + 13 * j) % 50 for j in range(2 + i % 3)],
     4 + i % 4)
    for i in range(6)
]

_POLICY = BucketPolicy([Bucket(32, 2)])


@pytest.fixture(scope="module")
def continuous_reference(cfg, mesh, params):
    """Plain continuous greedy tokens per (k, variant), lazily built."""
    cache = {}

    def get(k, quantized):
        key = (k, quantized)
        if key not in cache:
            with mesh:
                b = ServeBatcher(cfg, mesh, quantized=quantized,
                                 policy=_POLICY, schedule="continuous",
                                 steps_per_dispatch=k).load_params(params)
                for rid, p, n in _SPEC_TRACE:
                    b.submit(DecodeRequest(rid, p, max_new_tokens=n))
                cache[key] = {r: v.tokens for r, v in b.run().items()}
        return cache[key]

    return get


@pytest.mark.parametrize("k", [1, 4])
@pytest.mark.parametrize("quantized", [False, True],
                         ids=["float", "quantized"])
@pytest.mark.parametrize("paged", [False, True],
                         ids=["dense", "paged"])
def test_speculative_matches_plain_continuous(cfg, mesh, params, k,
                                              quantized, paged,
                                              continuous_reference):
    """Greedy streams with speculation on are token-identical to plain
    continuous decode at the same k — acceleration, never a model change
    — dense AND paged alike: the paged axis routes draft+verify writes
    through draft-page leases (page_size 4 so leases actually extend and
    roll back mid-trace), and the stream must be unchanged."""
    ref = continuous_reference(k, quantized)
    with mesh:
        b = ServeBatcher(cfg, mesh, quantized=quantized, policy=_POLICY,
                         schedule="continuous", steps_per_dispatch=k,
                         paged=4 if paged else None,
                         speculative=k).load_params(params)
        for rid, p, n in _SPEC_TRACE:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        res = {r: v.tokens for r, v in b.run().items()}
    for rid, _, n in _SPEC_TRACE:
        assert res[rid] == ref[rid], (k, quantized, paged, rid)
        assert len(res[rid]) == n
    s = b.scheduler.stats()["spec"]
    assert s["spec_k"] == k
    assert s["verifies"] > 0
    assert 0 < s["accepted_tokens"] <= s["draft_tokens"]
    assert b.scheduler.refills > 0     # parity held ACROSS slot reuse
    if paged:
        # every lease resolved and released: only scratch + prefix-cache
        # pages remain, and rollbacks actually exercised draft pages
        st = b.pool.allocator.stats()
        assert st["pages_in_use"] == \
            st["scratch_pages"] + st["prefix_entries"]
        assert st["draft_pages_committed"] + \
            st["draft_pages_rolled_back"] > 0


def test_rollback_stress_shallow_draft(cfg, mesh, params,
                                       continuous_reference):
    """draft='prefix:1' under random weights disagrees with the 2-layer
    target constantly — maximum rollback pressure — and the stream must
    STILL match plain continuous decode exactly."""
    ref = continuous_reference(4, False)
    with mesh:
        b = ServeBatcher(cfg, mesh, policy=_POLICY, schedule="continuous",
                         steps_per_dispatch=4, speculative=4,
                         draft="prefix:1").load_params(params)
        for rid, p, n in _SPEC_TRACE:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        res = {r: v.tokens for r, v in b.run().items()}
    for rid, _, _ in _SPEC_TRACE:
        assert res[rid] == ref[rid], rid
    assert b.scheduler.stats()["spec"]["rollbacks"] > 0
    assert b.scheduler._spec_carry == {}


def test_speculative_zero_new_lowerings_after_warmup(cfg, mesh, params):
    """A second wave (different lengths) runs entirely on the one warm
    fused executable: speculation must not fragment the cache."""
    with mesh:
        b = ServeBatcher(cfg, mesh, policy=_POLICY, schedule="continuous",
                         steps_per_dispatch=4,
                         speculative=4).load_params(params)
        for rid, p, n in _SPEC_TRACE:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        b.run()
        warm = b.cache.stats()["lowerings"]
        for rid, p, n in _SPEC_TRACE:
            b.submit(DecodeRequest("w" + rid, p[::-1],
                                   max_new_tokens=n + 1))
        b.run()
    assert b.cache.stats()["lowerings"] == warm
    keys = [key for key in b.cache._entries if key.kind == "masked_decode"]
    assert keys and all(key.spec == (4, 1) for key in keys)


def test_speculative_validation_errors(cfg, mesh):
    """The lane's preconditions fail loudly at construction time."""
    with pytest.raises(ValueError, match="continuous"):
        ServeBatcher(cfg, mesh, speculative=1)
    with pytest.raises(ValueError, match="steps_per_dispatch"):
        ServeBatcher(cfg, mesh, schedule="continuous",
                     steps_per_dispatch=2, speculative=4)
    with pytest.raises(ValueError, match="draft"):
        ServeBatcher(cfg, mesh, schedule="continuous", draft="prefix:1")
    with pytest.raises(ValueError, match="prefix"):
        ServeBatcher(cfg, mesh, schedule="continuous", steps_per_dispatch=2,
                     speculative=2, draft="suffix:1")
    with pytest.raises(ValueError, match="depth|\\[1,"):
        ServeBatcher(cfg, mesh, schedule="continuous", steps_per_dispatch=2,
                     speculative=2, draft="prefix:9")
    # paged x speculative is legal now, but only with draft-lease
    # headroom: a pool that cannot back one lane + its draft demand
    # fails loudly instead of deadlocking admission
    with pytest.raises(ValueError, match="page_count"):
        ServeBatcher(cfg, mesh, schedule="continuous", steps_per_dispatch=2,
                     speculative=2, paged=(2, 8))
