"""SRS (shift-round-saturate) semantics + quantization properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_skip_stub

given, settings, st = hypothesis_or_skip_stub()

from repro.quant.qtensor import QTensor, choose_shift, quantize, requantize
from repro.quant.srs import INT_RANGE, requant_shift, saturate, srs

SETTINGS = dict(max_examples=50, deadline=None)


def test_saturate_bounds():
    x = jnp.array([-1000, -129, -128, 0, 127, 128, 1000], jnp.int32)
    y = saturate(x, "int8")
    assert y.dtype == jnp.int8
    np.testing.assert_array_equal(
        np.asarray(y), [-128, -128, -128, 0, 127, 127, 127])


@pytest.mark.parametrize("rounding", ["floor", "half_up", "half_even"])
def test_srs_matches_integer_reference(rounding):
    rng = np.random.default_rng(0)
    acc = rng.integers(-(2**24), 2**24, 4096).astype(np.int32)
    for shift in [0, 1, 3, 8, 15]:
        got = np.asarray(srs(jnp.asarray(acc), shift, "int8", rounding))
        # pure-python reference
        ref = []
        for a in acc.tolist():
            if shift == 0:
                r = a
            elif rounding == "floor":
                r = a >> shift
            elif rounding == "half_up":
                r = (a + (1 << (shift - 1))) >> shift
            else:  # half_even
                fl = a >> shift
                rem = a & ((1 << shift) - 1)
                half = 1 << (shift - 1)
                r = fl + (1 if (rem > half or (rem == half and fl & 1)) else 0)
            ref.append(max(-128, min(127, r)))
        np.testing.assert_array_equal(got, np.array(ref, np.int8))


@given(shift=st.integers(0, 20),
       vals=st.lists(st.integers(-(2**28), 2**28), min_size=1, max_size=64))
@settings(**SETTINGS)
def test_srs_monotone(shift, vals):
    """SRS is monotone non-decreasing in the accumulator value."""
    a = jnp.asarray(sorted(vals), jnp.int32)
    y = np.asarray(srs(a, shift, "int8")).astype(np.int32)
    assert (np.diff(y) >= 0).all()


@given(st.lists(st.floats(-100, 100, allow_nan=False,
                          allow_subnormal=False), min_size=1, max_size=64),
       st.sampled_from(["int8", "int16"]))
@settings(**SETTINGS)
def test_quantize_error_bound(vals, dtype):
    """Quantization error is bounded by half an LSB (when not saturating)."""
    x = np.asarray([v if abs(v) > 1e-9 or v == 0 else 1e-9 for v in vals])
    q = quantize(x, dtype)
    deq = np.asarray(q.dequantize())
    lsb = 2.0 ** (-q.shift)
    lo, hi = INT_RANGE[dtype]
    unsat = (x >= lo * lsb) & (x <= hi * lsb)
    assert np.all(np.abs(deq - x)[unsat] <= 0.5 * lsb + 1e-12)


@given(st.floats(0.01, 1000.0, allow_nan=False),
       st.sampled_from(["int8", "int16"]))
@settings(**SETTINGS)
def test_choose_shift_maximal(amax, dtype):
    """choose_shift picks the LARGEST shift that still represents amax
    (values beyond the integer range saturate at shift 0)."""
    from repro.quant.qtensor import MAX_SHIFT

    s = choose_shift(np.asarray([amax]), dtype)
    lo, hi = INT_RANGE[dtype]
    if amax > hi:
        assert s == 0  # saturating regime
        return
    assert amax * 2**s <= hi
    if 0 < s < MAX_SHIFT:  # one more bit would overflow
        assert amax * 2 ** (s + 1) > hi


def test_requant_shift_chain():
    assert requant_shift(7, 7, 7) == 7
    assert requant_shift(7, 5, 3) == 9
    with pytest.raises(ValueError):
        requant_shift(2, 2, 8)  # would need a left shift


def test_requantize_reduces_precision():
    q = quantize(np.array([0.5, -0.25, 0.125]), "int8", shift=7)
    q2 = requantize(q, 4, "int8")
    assert q2.shift == 4
    np.testing.assert_allclose(
        np.asarray(q2.dequantize()), [0.5, -0.25, 0.125], atol=2**-4)
