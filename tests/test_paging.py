"""Property suite for the host-side page allocator (repro.serve.paging).

The allocator is pure host bookkeeping — no JAX — so these tests churn
it hard: randomized admit/publish/release interleavings (hypothesis
when installed, seeded np.random twins always) against the invariants
the paged serving path relies on:

* no page is ever writable by two slots at once;
* reference counts hit zero exactly at release, and pages conserve:
  free + in-use == page_count at every step;
* copy-on-write never hands out a shared (prefix-cache) page as any
  slot's private page — the divergent page is a fresh allocation;
* the prefix cache actually skips prefill for a shared system prompt.
"""

from __future__ import annotations

import numpy as np
import pytest

from conftest import SEED, hypothesis_or_skip_stub
from repro.serve.paging import PageAllocator, prefix_page_hashes

given, settings, st = hypothesis_or_skip_stub()


def check_invariants(alloc: PageAllocator, leases) -> None:
    """Assert every allocator invariant against the live lease set."""
    # conservation: every page is free xor refcounted, never both
    assert alloc.pages_free + alloc.pages_in_use == alloc.page_count
    assert len(alloc._refs) == alloc.pages_in_use
    assert set(alloc._free).isdisjoint(alloc._refs)
    assert all(c > 0 for c in alloc._refs.values())

    writable = []          # (lease, page) for every private page
    for lease in leases:
        for i, p in enumerate(lease.pages):
            assert p in alloc._refs, (i, p)
            if i >= lease.shared and i >= lease.published:
                writable.append(p)
    # no page is writable by two slots at once
    assert len(writable) == len(set(writable)), writable
    # a writable page is never a prefix-cache (shared) page
    cached = set(alloc._prefix.values())
    assert cached.isdisjoint(writable)
    # shared pages are pinned: slot ref + cache ref
    for lease in leases:
        for p in lease.pages[:lease.shared]:
            assert alloc._refs[p] >= 2, p
    # scratch pages are pinned forever and never leased or cached
    for p in alloc._scratch:
        assert p in alloc._refs
        assert p not in cached


def _total_need(prompt, max_new):
    return len(prompt) + max_new - 1


def _churn(alloc: PageAllocator, rng: np.random.Generator, rounds: int):
    """Random admit/publish/release interleaving with invariant checks."""
    prompts = [tuple(rng.integers(0, 50, size=n).tolist())
               for n in (5, 17, 33, 48)]
    live = []
    for _ in range(rounds):
        op = rng.integers(0, 3)
        if op == 0:
            prompt = prompts[rng.integers(0, len(prompts))]
            need = _total_need(prompt, int(rng.integers(1, 9)))
            if alloc.can_admit(prompt, need):
                lease = alloc.admit(prompt, need)
                assert lease is not None
                assert lease.shared_len <= len(prompt) - 1
                live.append(lease)
        elif op == 1 and live:
            lease = live[rng.integers(0, len(live))]
            fed = int(rng.integers(0, len(lease.prompt) + 1))
            alloc.publish(lease, fed)
        elif op == 2 and live:
            lease = live.pop(rng.integers(0, len(live)))
            alloc.release(lease)
        check_invariants(alloc, live)
    for lease in live:
        alloc.release(lease)
    check_invariants(alloc, [])


def test_hash_chain_prefix_property():
    ps = 4
    a = prefix_page_hashes([1, 2, 3, 4, 5, 6, 7, 8], ps)
    b = prefix_page_hashes([1, 2, 3, 4, 9, 9, 9, 9], ps)
    assert a[0] == b[0] and a[1] != b[1]
    # only FULL pages hash: a 7-token prompt has one 4-token page
    assert len(prefix_page_hashes([1, 2, 3, 4, 5, 6, 7], ps)) == 1


def test_admit_release_roundtrip():
    alloc = PageAllocator(page_count=16, page_size=4)
    lease = alloc.admit((1, 2, 3, 4, 5), need=8)
    assert lease is not None and lease.shared == 0
    assert len(lease.pages) == 2 and alloc.pages_in_use == 2
    check_invariants(alloc, [lease])
    alloc.release(lease)
    assert alloc.pages_in_use == 0 and alloc.pages_free == 16
    check_invariants(alloc, [])


def test_prefix_reuse_skips_prefill_for_shared_system_prompt():
    """Regression: two requests sharing a system prompt — the second
    maps the published pages read-only and skips that prefill span."""
    alloc = PageAllocator(page_count=32, page_size=4)
    system = (9, 8, 7, 6, 5, 4, 3, 2)            # two full pages
    first = alloc.admit(system + (11, 12), need=12)
    assert first.shared == 0
    alloc.publish(first, fed=10)                  # whole prompt fed
    second = alloc.admit(system + (21,), need=11)
    assert second.shared == 2 and second.shared_len == 8
    assert second.pages[:2] == first.pages[:2]    # same physical pages
    # the shared pages are read-only for BOTH slots now
    for p in second.pages[:2]:
        assert alloc._refs[p] >= 3                # 2 slots + cache
    assert alloc.skipped_tokens == 8 and alloc.prefix_hits == 1
    assert alloc.stats()["prefill_skip_rate"] > 0
    check_invariants(alloc, [first, second])
    alloc.release(first)
    # published pages survive the publisher's release under the cache ref
    third = alloc.admit(system + (31, 32, 33), need=14)
    assert third.shared == 2
    check_invariants(alloc, [second, third])
    alloc.release(second)
    alloc.release(third)
    check_invariants(alloc, [])


def test_cow_divergent_page_is_fresh_allocation():
    """The first divergent page is allocated private (COW-by-allocation),
    never the cached page of the other branch."""
    alloc = PageAllocator(page_count=32, page_size=4)
    a = alloc.admit((1, 2, 3, 4, 5, 6, 7, 8, 9), need=12)
    alloc.publish(a, fed=9)
    b = alloc.admit((1, 2, 3, 4, 99, 98, 97, 96, 95), need=12)
    assert b.shared == 1 and b.pages[0] == a.pages[0]
    assert b.pages[1] != a.pages[1]               # diverged: private page
    check_invariants(alloc, [a, b])


def test_sharing_always_leaves_one_prompt_token_to_feed():
    """Even a bit-identical resubmission shares at most the pages before
    the prompt's last token — the slot must feed >= 1 token."""
    alloc = PageAllocator(page_count=32, page_size=4)
    prompt = (1, 2, 3, 4, 5, 6, 7, 8)             # exactly two pages
    a = alloc.admit(prompt, need=10)
    alloc.publish(a, fed=8)
    b = alloc.admit(prompt, need=10)
    assert b.shared == 1 and b.shared_len == 4    # page 2 NOT shared
    check_invariants(alloc, [a, b])


def test_lru_eviction_under_pressure_and_exhaustion():
    alloc = PageAllocator(page_count=4, page_size=4)
    a = alloc.admit((1, 2, 3, 4, 5), need=6)      # 2 pages
    alloc.publish(a, fed=5)
    alloc.release(a)                               # page 0 cached, rc=1
    assert alloc.pages_in_use == 1
    b = alloc.admit((9, 9, 9, 9, 9, 9, 9), need=14)   # needs all 4 pages
    assert b is not None and alloc.evictions == 1
    assert len(alloc._prefix) == 0                # cache entry evicted
    # pool exhausted: admission fails cleanly and leaks nothing
    free_before = alloc.pages_free
    assert alloc.admit((5, 5, 5), need=5) is None
    assert alloc.pages_free == free_before
    check_invariants(alloc, [b])


def test_failed_admit_rolls_back_prefix_pins():
    alloc = PageAllocator(page_count=3, page_size=4)
    a = alloc.admit((1, 2, 3, 4, 5), need=6)
    alloc.publish(a, fed=5)
    refs_before = dict(alloc._refs)
    # shares page 0 but needs 3 private pages with only 1 free
    assert alloc.admit((1, 2, 3, 4, 6, 7, 8, 9, 10), need=14) is None
    assert alloc._refs == refs_before             # pins rolled back
    check_invariants(alloc, [a])


def test_scratch_pages_pinned_and_stable():
    alloc = PageAllocator(page_count=8, page_size=4)
    s2 = alloc.scratch(2)
    assert alloc.scratch(2) == s2                 # idempotent
    s3 = alloc.scratch(3)
    assert s3[:2] == s2
    check_invariants(alloc, [])
    assert alloc.pages_in_use == 3


def test_bad_geometry_rejected():
    with pytest.raises(ValueError):
        PageAllocator(0, 16)
    with pytest.raises(ValueError):
        PageAllocator(16, 0)


def test_churn_conserves_pages_seeded():
    rng = np.random.default_rng(SEED)
    alloc = PageAllocator(page_count=24, page_size=4)
    alloc.scratch(2)
    _churn(alloc, rng, rounds=300)
    # everything released: only scratch + cache refs remain
    assert alloc.pages_in_use == 2 + len(alloc._prefix)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_churn_conserves_pages_hypothesis(seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(page_count=16, page_size=4)
    alloc.scratch(1)
    _churn(alloc, rng, rounds=120)
    assert alloc.pages_in_use == 1 + len(alloc._prefix)


@given(st.lists(st.integers(min_value=0, max_value=7),
                min_size=1, max_size=40),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=50, deadline=None)
def test_probe_matches_published_prefix(prompt, page_size):
    """probe() returns exactly the page-aligned published span, capped
    so at least one prompt token stays unshared."""
    alloc = PageAllocator(page_count=64, page_size=page_size)
    prompt = tuple(prompt)
    need = len(prompt) + 4
    lease = alloc.admit(prompt, need)
    alloc.publish(lease, fed=len(prompt))
    got = alloc.probe(prompt)
    cap = (len(prompt) - 1) // page_size
    full = len(prompt) // page_size
    assert got == min(cap, full) * page_size
    assert got <= len(prompt) - 1
