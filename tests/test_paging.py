"""Property suite for the host-side page allocator (repro.serve.paging).

The allocator is pure host bookkeeping — no JAX — so these tests churn
it hard: randomized admit/publish/release interleavings (hypothesis
when installed, seeded np.random twins always) against the invariants
the paged serving path relies on:

* no page is ever writable by two slots at once;
* reference counts hit zero exactly at release, and pages conserve:
  free + in-use == page_count at every step;
* copy-on-write never hands out a shared (prefix-cache) page as any
  slot's private page — the divergent page is a fresh allocation;
* the prefix cache actually skips prefill for a shared system prompt.
"""

from __future__ import annotations

import numpy as np
import pytest

from conftest import SEED, hypothesis_or_skip_stub
from repro.serve.paging import PageAllocator, prefix_page_hashes

given, settings, st = hypothesis_or_skip_stub()


def check_invariants(alloc: PageAllocator, leases) -> None:
    """Assert every allocator invariant against the live lease set."""
    # conservation: every page is free xor refcounted, never both
    assert alloc.pages_free + alloc.pages_in_use == alloc.page_count
    assert len(alloc._refs) == alloc.pages_in_use
    assert set(alloc._free).isdisjoint(alloc._refs)
    assert all(c > 0 for c in alloc._refs.values())

    writable = []          # (lease, page) for every private page
    for lease in leases:
        for i, p in enumerate(lease.pages):
            assert p in alloc._refs, (i, p)
            if i >= lease.shared and i >= lease.published:
                writable.append(p)
        # draft pages are always writable (revocable by construction:
        # never shared, never published) and a released lease holds none
        assert not (lease.released and (lease.pages or lease.draft))
        for p in lease.draft:
            assert p in alloc._refs, p
            writable.append(p)
    # no page is writable by two slots at once
    assert len(writable) == len(set(writable)), writable
    # a writable page is never a prefix-cache (shared) page
    cached = set(alloc._prefix.values())
    assert cached.isdisjoint(writable)
    # shared pages are pinned: slot ref + cache ref
    for lease in leases:
        for p in lease.pages[:lease.shared]:
            assert alloc._refs[p] >= 2, p
    # scratch pages are pinned forever and never leased or cached
    for p in alloc._scratch:
        assert p in alloc._refs
        assert p not in cached


def _total_need(prompt, max_new):
    return len(prompt) + max_new - 1


def _churn(alloc: PageAllocator, rng: np.random.Generator, rounds: int):
    """Random admit/publish/release interleaving with invariant checks."""
    prompts = [tuple(rng.integers(0, 50, size=n).tolist())
               for n in (5, 17, 33, 48)]
    live = []
    for _ in range(rounds):
        op = rng.integers(0, 5)
        if op == 0:
            prompt = prompts[rng.integers(0, len(prompts))]
            need = _total_need(prompt, int(rng.integers(1, 9)))
            lazy = bool(rng.random() < 0.5)
            if alloc.can_admit(prompt, need, lazy=lazy):
                lease = alloc.admit(prompt, need, lazy=lazy)
                assert lease is not None
                assert lease.shared_len <= len(prompt) - 1
                live.append(lease)
        elif op == 1 and live:
            lease = live[rng.integers(0, len(live))]
            fed = int(rng.integers(0, len(lease.prompt) + 1))
            alloc.publish(lease, fed)
        elif op == 2 and live:
            lease = live.pop(rng.integers(0, len(live)))
            alloc.release(lease)
            if rng.random() < 0.25:
                alloc.release(lease)    # double release must be a no-op
        elif op == 3 and live:
            # speculative write front: extend the lease with revocable
            # draft pages (may fail under pressure — that is the valve)
            lease = live[rng.integers(0, len(live))]
            alloc.draft_lease(lease, int(rng.integers(0,
                                                      alloc.page_size * 8)))
        elif op == 4 and live:
            # boundary accept decision at an arbitrary committed cursor
            lease = live[rng.integers(0, len(live))]
            span = (len(lease.pages) + len(lease.draft)) * alloc.page_size
            alloc.resolve_draft(lease, int(rng.integers(0, span + 1)))
        check_invariants(alloc, live)
    for lease in live:
        alloc.release(lease)
    check_invariants(alloc, [])


def test_hash_chain_prefix_property():
    ps = 4
    a = prefix_page_hashes([1, 2, 3, 4, 5, 6, 7, 8], ps)
    b = prefix_page_hashes([1, 2, 3, 4, 9, 9, 9, 9], ps)
    assert a[0] == b[0] and a[1] != b[1]
    # only FULL pages hash: a 7-token prompt has one 4-token page
    assert len(prefix_page_hashes([1, 2, 3, 4, 5, 6, 7], ps)) == 1


def test_admit_release_roundtrip():
    alloc = PageAllocator(page_count=16, page_size=4)
    lease = alloc.admit((1, 2, 3, 4, 5), need=8)
    assert lease is not None and lease.shared == 0
    assert len(lease.pages) == 2 and alloc.pages_in_use == 2
    check_invariants(alloc, [lease])
    alloc.release(lease)
    assert alloc.pages_in_use == 0 and alloc.pages_free == 16
    check_invariants(alloc, [])


def test_prefix_reuse_skips_prefill_for_shared_system_prompt():
    """Regression: two requests sharing a system prompt — the second
    maps the published pages read-only and skips that prefill span."""
    alloc = PageAllocator(page_count=32, page_size=4)
    system = (9, 8, 7, 6, 5, 4, 3, 2)            # two full pages
    first = alloc.admit(system + (11, 12), need=12)
    assert first.shared == 0
    alloc.publish(first, fed=10)                  # whole prompt fed
    second = alloc.admit(system + (21,), need=11)
    assert second.shared == 2 and second.shared_len == 8
    assert second.pages[:2] == first.pages[:2]    # same physical pages
    # the shared pages are read-only for BOTH slots now
    for p in second.pages[:2]:
        assert alloc._refs[p] >= 3                # 2 slots + cache
    assert alloc.skipped_tokens == 8 and alloc.prefix_hits == 1
    assert alloc.stats()["prefill_skip_rate"] > 0
    check_invariants(alloc, [first, second])
    alloc.release(first)
    # published pages survive the publisher's release under the cache ref
    third = alloc.admit(system + (31, 32, 33), need=14)
    assert third.shared == 2
    check_invariants(alloc, [second, third])
    alloc.release(second)
    alloc.release(third)
    check_invariants(alloc, [])


def test_cow_divergent_page_is_fresh_allocation():
    """The first divergent page is allocated private (COW-by-allocation),
    never the cached page of the other branch."""
    alloc = PageAllocator(page_count=32, page_size=4)
    a = alloc.admit((1, 2, 3, 4, 5, 6, 7, 8, 9), need=12)
    alloc.publish(a, fed=9)
    b = alloc.admit((1, 2, 3, 4, 99, 98, 97, 96, 95), need=12)
    assert b.shared == 1 and b.pages[0] == a.pages[0]
    assert b.pages[1] != a.pages[1]               # diverged: private page
    check_invariants(alloc, [a, b])


def test_sharing_always_leaves_one_prompt_token_to_feed():
    """Even a bit-identical resubmission shares at most the pages before
    the prompt's last token — the slot must feed >= 1 token."""
    alloc = PageAllocator(page_count=32, page_size=4)
    prompt = (1, 2, 3, 4, 5, 6, 7, 8)             # exactly two pages
    a = alloc.admit(prompt, need=10)
    alloc.publish(a, fed=8)
    b = alloc.admit(prompt, need=10)
    assert b.shared == 1 and b.shared_len == 4    # page 2 NOT shared
    check_invariants(alloc, [a, b])


def test_lru_eviction_under_pressure_and_exhaustion():
    alloc = PageAllocator(page_count=4, page_size=4)
    a = alloc.admit((1, 2, 3, 4, 5), need=6)      # 2 pages
    alloc.publish(a, fed=5)
    alloc.release(a)                               # page 0 cached, rc=1
    assert alloc.pages_in_use == 1
    b = alloc.admit((9, 9, 9, 9, 9, 9, 9), need=14)   # needs all 4 pages
    assert b is not None and alloc.evictions == 1
    assert len(alloc._prefix) == 0                # cache entry evicted
    # pool exhausted: admission fails cleanly and leaks nothing
    free_before = alloc.pages_free
    assert alloc.admit((5, 5, 5), need=5) is None
    assert alloc.pages_free == free_before
    check_invariants(alloc, [b])


def test_failed_admit_rolls_back_prefix_pins():
    alloc = PageAllocator(page_count=3, page_size=4)
    a = alloc.admit((1, 2, 3, 4, 5), need=6)
    alloc.publish(a, fed=5)
    refs_before = dict(alloc._refs)
    # shares page 0 but needs 3 private pages with only 1 free
    assert alloc.admit((1, 2, 3, 4, 6, 7, 8, 9, 10), need=14) is None
    assert alloc._refs == refs_before             # pins rolled back
    check_invariants(alloc, [a])


def test_scratch_pages_pinned_and_stable():
    alloc = PageAllocator(page_count=8, page_size=4)
    s2 = alloc.scratch(2)
    assert alloc.scratch(2) == s2                 # idempotent
    s3 = alloc.scratch(3)
    assert s3[:2] == s2
    check_invariants(alloc, [])
    assert alloc.pages_in_use == 3


def test_release_is_idempotent_regression():
    """Latent-bug regression: a lease released twice (a continuation
    requeue whose slot is also freed at the boundary) must not push its
    pages onto the free list twice — conservation survives, and the
    released lease refuses further draft work."""
    alloc = PageAllocator(page_count=8, page_size=4)
    lease = alloc.admit((1, 2, 3, 4, 5), need=8)
    other = alloc.admit((9, 9, 9), need=4)
    alloc.release(lease)
    free_after = alloc.pages_free
    alloc.release(lease)                     # double release: no-op
    assert alloc.pages_free == free_after
    check_invariants(alloc, [other, lease])
    with pytest.raises(ValueError):
        alloc.draft_lease(lease, 4)
    alloc.resolve_draft(lease, 99)           # no-op, not a crash
    assert alloc.publish(lease, 5) == 0
    check_invariants(alloc, [other, lease])


def test_draft_lease_extend_commit_rollback():
    """The spec x paged lifecycle: lazy admission leases the prompt span
    only, draft_lease extends the run to the write front, and the
    boundary resolution splices committed pages / rolls back the rest."""
    alloc = PageAllocator(page_count=16, page_size=4)
    lease = alloc.admit((1, 2, 3, 4, 5, 6), need=14, lazy=True)
    assert len(lease.pages) == 2             # prompt span, not need
    assert alloc.draft_lease(lease, 11)      # front at local 11: 3 pages
    assert len(lease.draft) == 1
    check_invariants(alloc, [lease])
    alloc.resolve_draft(lease, 9)            # page [8,12) starts below 9
    assert len(lease.pages) == 3 and lease.draft == []
    assert alloc.draft_pages_committed == 1
    assert alloc.draft_lease(lease, 14)      # extend again: 4th page
    in_use = alloc.pages_in_use
    alloc.resolve_draft(lease, 10)           # 12 >= 10: rolled back
    assert len(lease.pages) == 3
    assert alloc.draft_pages_rolled_back == 1
    assert alloc.pages_in_use == in_use - 1
    check_invariants(alloc, [lease])
    alloc.release(lease)
    check_invariants(alloc, [])
    assert alloc.pages_in_use == 0


def test_draft_release_drains_outstanding_draft_pages():
    """Cancel mid-speculation: releasing a lease with unresolved draft
    pages returns them too (nothing leaks, nothing double-frees)."""
    alloc = PageAllocator(page_count=8, page_size=4)
    lease = alloc.admit((1, 2, 3), need=12, lazy=True)
    assert alloc.draft_lease(lease, 9)
    assert len(lease.draft) == 2
    alloc.release(lease)
    assert alloc.pages_in_use == 0 and alloc.pages_free == 8
    check_invariants(alloc, [lease])


def test_spec_demand_and_lazy_admission_budget():
    """Lazy admission charges the prompt span; the reserve argument
    holds back the draft-lease headroom the scheduler's admission loop
    accounts per speculative lane."""
    alloc = PageAllocator(page_count=8, page_size=4)
    assert alloc.spec_demand(4) == 2         # ceil(4/4) + 1
    assert alloc.spec_demand(1) == 2
    prompt = (1, 2, 3, 4, 5, 6)
    assert not alloc.can_admit(prompt, need=40)
    assert alloc.can_admit(prompt, need=40, lazy=True)
    assert not alloc.can_admit(prompt, need=40, lazy=True, reserve=7)
    lease = alloc.admit(prompt, need=40, lazy=True)
    assert len(lease.pages) == 2
    alloc.release(lease)
    check_invariants(alloc, [])


def test_bad_geometry_rejected():
    with pytest.raises(ValueError):
        PageAllocator(0, 16)
    with pytest.raises(ValueError):
        PageAllocator(16, 0)


def test_churn_conserves_pages_seeded():
    rng = np.random.default_rng(SEED)
    alloc = PageAllocator(page_count=24, page_size=4)
    alloc.scratch(2)
    _churn(alloc, rng, rounds=300)
    # everything released: only scratch + cache refs remain
    assert alloc.pages_in_use == 2 + len(alloc._prefix)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_churn_conserves_pages_hypothesis(seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(page_count=16, page_size=4)
    alloc.scratch(1)
    _churn(alloc, rng, rounds=120)
    assert alloc.pages_in_use == 1 + len(alloc._prefix)


@given(st.lists(st.integers(min_value=0, max_value=7),
                min_size=1, max_size=40),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=50, deadline=None)
def test_probe_matches_published_prefix(prompt, page_size):
    """probe() returns exactly the page-aligned published span, capped
    so at least one prompt token stays unshared."""
    alloc = PageAllocator(page_count=64, page_size=page_size)
    prompt = tuple(prompt)
    need = len(prompt) + 4
    lease = alloc.admit(prompt, need)
    alloc.publish(lease, fed=len(prompt))
    got = alloc.probe(prompt)
    cap = (len(prompt) - 1) // page_size
    full = len(prompt) // page_size
    assert got == min(cap, full) * page_size
    assert got <= len(prompt) - 1
