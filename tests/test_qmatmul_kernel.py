"""Pallas qmatmul kernel vs pure-jnp oracle: bit-exact across shapes/dtypes.

The kernel runs in interpret mode on CPU (the "AIE simulation" role); the
oracle is ref.py (the "x86 simulation" role). The paper's bit-exactness
guarantee is asserted literally: array_equal, not allclose.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_skip_stub

given, settings, st = hypothesis_or_skip_stub()

from repro.kernels.qmatmul.ops import qlinear
from repro.kernels.qmatmul.ref import qlinear_ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    lo, hi = (-128, 128) if dtype == jnp.int8 else (-1024, 1024)
    return jnp.asarray(RNG.integers(lo, hi, shape), dtype)


SHAPES = [
    (1, 8, 8),          # GEMV corner
    (4, 8, 8),          # one native AIE tile
    (8, 128, 128),      # paper micro-batch latency setting
    (128, 128, 128),    # paper Table II workload
    (33, 70, 50),       # ragged: exercises the zero-pad path
    (256, 64, 96),
    (5, 1, 3),          # degenerate
]


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("use_bias", [False, True])
def test_i8_bit_exact(M, K, N, relu, use_bias):
    x = _rand((M, K), jnp.int8)
    w = _rand((K, N), jnp.int8)
    b = jnp.asarray(RNG.integers(-(2**16), 2**16, (N,)), jnp.int32) \
        if use_bias else None
    for shift in (0, 5, 9):
        got = qlinear(x, w, b, shift=shift, relu=relu)
        want = qlinear_ref(x, w, b, shift=shift, relu=relu)
        assert got.dtype == want.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("M,K,N", [(8, 16, 24), (64, 64, 64)])
@pytest.mark.parametrize("dt_a,dt_b,out_dtype", [
    ("int16", "int8", "int8"),
    ("int16", "int8", "int16"),
    ("int16", "int16", "int16"),
])
def test_mixed_precision_bit_exact(M, K, N, dt_a, dt_b, out_dtype):
    x = _rand((M, K), jnp.dtype(dt_a))
    w = _rand((K, N), jnp.dtype(dt_b))
    got = qlinear(x, w, None, shift=8, out_dtype=out_dtype)
    want = qlinear_ref(x, w, None, shift=8, out_dtype=out_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rounding", ["floor", "half_up", "half_even"])
def test_rounding_modes_bit_exact(rounding):
    x = _rand((16, 32), jnp.int8)
    w = _rand((32, 16), jnp.int8)
    got = qlinear(x, w, None, shift=6, rounding=rounding)
    want = qlinear_ref(x, w, None, shift=6, rounding=rounding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("acc_blocks", [(1, 1), (1, 2), (2, 1), (2, 2)])
def test_accumulator_blocking_schemes(acc_blocks):
    """The paper's 2x2 scheme and its degenerate variants all agree."""
    x = _rand((32, 48), jnp.int8)
    w = _rand((48, 32), jnp.int8)
    got = qlinear(x, w, None, shift=7, block=(8, 16, 8),
                  acc_blocks=acc_blocks)
    want = qlinear_ref(x, w, None, shift=7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    m=st.integers(1, 40), k=st.integers(1, 48), n=st.integers(1, 40),
    shift=st.integers(0, 12), relu=st.booleans(), seed=st.integers(0, 2**31),
)
@settings(max_examples=25, deadline=None)
def test_property_random_shapes(m, k, n, shift, relu, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    b = jnp.asarray(rng.integers(-(2**12), 2**12, (n,)), jnp.int32)
    got = qlinear(x, w, b, shift=shift, relu=relu)
    want = qlinear_ref(x, w, b, shift=shift, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_relu_clamps_after_srs():
    """Algorithm 1 order: SRS then ReLU — negatives become exactly 0."""
    x = jnp.full((4, 8), -10, jnp.int8)
    w = jnp.full((8, 4), 10, jnp.int8)
    y = qlinear(x, w, None, shift=0, relu=True)
    assert np.asarray(y).min() == 0
