"""Branch-and-bound placement: optimality, constraints, Eq. 2 semantics."""

import itertools

import pytest
from conftest import hypothesis_or_skip_stub

given, settings, st = hypothesis_or_skip_stub()

from repro.core.ir import PlacementSpec
from repro.core.placement import Block, Placer, placement_cost


def test_cost_function_eq2():
    # two 1x1 blocks side by side at row 0: J = |c_out0 - c_in1| + mu*(0+0)
    a = PlacementSpec(0, 0, 1, 1)
    b = PlacementSpec(1, 0, 1, 1)
    assert placement_cost([a, b], lam=1.0, mu=0.05) == pytest.approx(1.0)
    # vertical hop costs lambda
    c = PlacementSpec(1, 2, 1, 1)
    assert placement_cost([a, c], lam=1.0, mu=0.05) == pytest.approx(
        1.0 + 1.0 * 2 + 0.05 * 2)


def test_ports_follow_paper_convention():
    p = PlacementSpec(3, 2, 4, 2)
    assert p.c_in == 3          # inputs broadcast up the leftmost column
    assert p.c_out == 6         # cascade exits east
    assert p.r_in == p.r_out == 2
    assert p.r_top == 3


def test_bnb_matches_brute_force_small():
    placer = Placer(5, 3, lam=1.0, mu=0.05, beam=None)
    blocks = [Block(2, 2), Block(1, 2), Block(2, 1)]
    got = placer.branch_and_bound(blocks, start=(0, 0))
    want = placer.brute_force(blocks, start=(0, 0))
    assert got.cost == pytest.approx(want.cost)


@given(
    sizes=st.lists(
        st.tuples(st.integers(1, 2), st.integers(1, 2)),
        min_size=2, max_size=4),
    lam=st.floats(0.1, 2.0), mu=st.floats(0.0, 0.5),
)
@settings(max_examples=20, deadline=None)
def test_bnb_optimal_property(sizes, lam, mu):
    placer = Placer(4, 3, lam=lam, mu=mu, beam=None)
    blocks = [Block(w, h) for w, h in sizes]
    try:
        want = placer.brute_force(blocks)
    except ValueError:
        # instance is infeasible: B&B must agree
        with pytest.raises(ValueError):
            placer.branch_and_bound(blocks)
        return
    got = placer.branch_and_bound(blocks)
    assert got.cost == pytest.approx(want.cost)


def test_no_overlap_and_in_bounds():
    placer = Placer(6, 4, beam=32)
    blocks = [Block(3, 2), Block(2, 2), Block(3, 2), Block(2, 1)]
    res = placer.branch_and_bound(blocks, start=(0, 0))
    rects = [(p.col, p.row, p.width, p.height) for p in res.positions]
    for (c, r, w, h) in rects:
        assert 0 <= c and c + w <= 6 and 0 <= r and r + h <= 4
    for (a, b) in itertools.combinations(res.positions, 2):
        no_olap = (a.col + a.width <= b.col or b.col + b.width <= a.col
                   or a.row + a.height <= b.row or b.row + b.height <= a.row)
        assert no_olap


def test_fixed_constraints_respected():
    placer = Placer(6, 4, beam=None)
    blocks = [Block(2, 2), Block(2, 2), Block(1, 1)]
    res = placer.branch_and_bound(blocks, fixed={1: (4, 2)})
    assert (res.positions[1].col, res.positions[1].row) == (4, 2)


def test_infeasible_fixed_raises():
    placer = Placer(4, 4, beam=None)
    blocks = [Block(2, 2), Block(2, 2)]
    with pytest.raises(ValueError):
        placer.branch_and_bound(blocks, start=(0, 0), fixed={1: (1, 1)})


def test_block_too_large_raises():
    placer = Placer(4, 4)
    with pytest.raises(ValueError):
        placer.branch_and_bound([Block(5, 1)])


def test_bnb_beats_or_ties_greedy_fig3_style():
    """Paper Fig. 3: B&B vs greedy-right vs greedy-up on a 38x8 array."""
    placer = Placer(38, 8, lam=1.0, mu=0.05, beam=64)
    blocks = [Block(4, 4), Block(4, 2), Block(8, 2), Block(4, 4),
              Block(2, 2), Block(8, 4), Block(4, 2), Block(2, 1)]
    bnb = placer.branch_and_bound(blocks, start=(0, 0))
    gr = placer.greedy_right(blocks)
    gu = placer.greedy_up(blocks)
    assert bnb.cost <= gr.cost + 1e-9
    assert bnb.cost <= gu.cost + 1e-9
    assert bnb.cost < gu.cost  # strictly better than at least one greedy


def test_lower_row_bias():
    """mu > 0 pulls blocks toward the memory-tile row (row 0)."""
    placer = Placer(8, 8, lam=1.0, mu=0.5, beam=None)
    res = placer.branch_and_bound([Block(2, 2), Block(2, 2)])
    assert all(p.row == 0 for p in res.positions)
