"""``repro.plan``: the one compile-plan API.

Acceptance properties pinned here:

* the launchers and the serve batcher are THIN consumers — the RA501
  layering rule of ``repro.analysis`` (real import/call-graph analysis,
  re-export aware) proves none of them imports step builders or
  sharding wiring, constructs a mesh, calls ``jax.jit``, or lowers
  directly; all executable construction goes through ``ExecutionPlan``;
* the pass pipeline runs in order and records every decision
  (``describe()`` is JSON-able);
* PlaceStages: beam mode matches exact branch-and-bound on small grids,
  stage slices never overlap, and a 2-stage plan on the 8-device debug
  mesh shards the ``layers`` axis across the mesh slice chosen by the
  ``core.placement`` cost model while reproducing the unpipelined loss;
* Quantize calibrates per-tensor MLP shifts and keeps the SRS shift >= 0;
* Compile routes everything through the shared ExecutableCache: a warm
  bucket performs zero new lowerings.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.dist.sharding import init_params
from repro.models import build_model
from repro.models.base import ShapeSpec
from repro.plan import (
    MeshSpec,
    PLAN_PIPELINE,
    assign_stage_slices,
    build_plan,
    stack_depth,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")

PASS_ORDER = ["ResolveMesh", "ResolveSharding", "PlaceStages", "Quantize",
              "Compile"]


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("yi_6b").with_(n_layers=2, vocab=64)


# ---------------------------------------------------------------------------
# ACCEPTANCE: launchers/batcher contain no direct execution wiring
# ---------------------------------------------------------------------------

def test_launchers_are_thin_plan_consumers():
    """The RA501 layering rule (import-graph analysis, not a grep) must
    report zero unbaselined findings over the shipped tree — launchers,
    the batcher, and the benchmarks build nothing the plan should
    build. See docs/static_analysis.md for the rule's exact contract."""
    from repro.analysis import analyze

    report = analyze(
        [os.path.join(SRC, "repro"), os.path.join(ROOT, "benchmarks")],
        rules=["RA501"],
        baseline=os.path.join(ROOT, "analysis_baseline.json"))
    assert not report.findings, "\n".join(
        f.render() for f in report.findings)


def test_token_argmax_is_plan_owned_and_cached(cfg):
    """Regression for the one real RA501 finding the analyzer surfaced:
    the batcher used to ``jax.jit`` its greedy-argmax helper itself.
    The helper now lives on the plan and caches per output sharding."""
    plan = build_plan(cfg, ShapeSpec("t", 32, 2, "decode"),
                      mesh_spec=MeshSpec.debug(1, 1))
    exe = plan.serve_executable("decode", batch=2, max_len=32)
    tok_sh = exe.bundle.in_shardings[2]
    fn = plan.token_argmax(tok_sh)
    assert plan.token_argmax(tok_sh) is fn, (
        "same sharding must reuse the compiled helper")
    logits = jnp.zeros((2, cfg.vocab)).at[:, 3].set(1.0)
    out = fn(logits)
    assert out.dtype == jnp.int32
    assert list(map(int, out)) == [3, 3]


# ---------------------------------------------------------------------------
# pipeline order + introspection
# ---------------------------------------------------------------------------


def test_pass_pipeline_order_and_describe(cfg):
    assert [name for name, _ in PLAN_PIPELINE] == PASS_ORDER
    plan = build_plan(cfg, ShapeSpec("t", 32, 2, "train"),
                      mesh_spec=MeshSpec.debug(1, 1))
    assert plan.ir.pass_names() == PASS_ORDER
    d = plan.describe()
    json.dumps(d)                              # CI artifact must serialize
    assert d["passes"][0]["pass"] == "ResolveMesh"
    assert d["params"], "ResolveSharding must record param PartitionSpecs"
    assert d["executables"] == {"train": {"batch": 2, "seq_len": 32,
                                          "shape": "t"}}
    # single stage: the layers axis stays replicated
    assert plan.rules.get("layers") is None
    assert d["stages"] == []


def test_build_plan_validation(cfg):
    with pytest.raises(ValueError, match="unknown sharding mode"):
        build_plan(cfg, None, mode="nope", mesh_spec=MeshSpec.debug(1, 1))
    with pytest.raises(ValueError, match="pipeline_stages"):
        build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1),
                   pipeline_stages=0)
    with pytest.raises(ValueError, match="exceeds the layer stack"):
        build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1),
                   pipeline_stages=99)
    # arch aliases + --debug resolve through the registry
    plan = build_plan("yi-6b", None, debug=True)
    assert plan.cfg.name == "yi-6b" and plan.mesh.devices.size == 1


def test_stack_depth_per_family():
    assert stack_depth(reduced_config("yi_6b")) == 4
    hybrid = reduced_config("zamba2_2_7b")     # 4 layers in groups of 2
    assert stack_depth(hybrid) == 2


# ---------------------------------------------------------------------------
# PlaceStages: beam == exact, no overlap, graceful fallback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cols,rows,stages", [
    (4, 2, 2), (2, 4, 2), (2, 4, 4), (4, 8, 4), (8, 4, 2), (16, 16, 4),
])
def test_stage_placement_beam_matches_exact(cols, rows, stages):
    exact = assign_stage_slices(cols, rows, stages, beam=None)
    beam = assign_stage_slices(cols, rows, stages, beam=4)
    assert beam.cost == pytest.approx(exact.cost), (
        "beam placement must not lose optimality on small stage counts")


def _overlaps(a, b):
    return not (a.col + a.width <= b.col or b.col + b.width <= a.col
                or a.row + a.height <= b.row or b.row + b.height <= a.row)


@pytest.mark.parametrize("cols,rows,stages", [
    (4, 2, 2), (2, 8, 4), (4, 4, 2), (2, 16, 8),
])
def test_stage_slices_never_overlap_and_tile_the_mesh(cols, rows, stages):
    res = assign_stage_slices(cols, rows, stages)
    pos = res.positions
    for i in range(len(pos)):
        for j in range(i + 1, len(pos)):
            assert not _overlaps(pos[i], pos[j]), (i, j, pos)
    assert sum(p.width * p.height for p in pos) == cols * rows


def test_stage_fallback_on_tiny_mesh_is_recorded():
    cfg4 = reduced_config("yi_6b")             # 4 layers
    plan = build_plan(cfg4, None, mesh_spec=MeshSpec.debug(1, 1),
                      pipeline_stages=2)
    assert plan.ir.stage_axis is None and plan.ir.stages == []
    assert plan.rules.get("layers") is None    # still replicated
    fallbacks = [e for name, e in plan.ir.decisions
                 if name == "PlaceStages" and "fallback" in e]
    assert fallbacks, "fallback reason must be recorded in the decisions"
    assert "stages" in fallbacks[0]["fallback"]


# ---------------------------------------------------------------------------
# ACCEPTANCE: 2-stage plan on the 8-device debug mesh — layers sharded on
# the cost-model slice, loss identical to the unpipelined plan
# ---------------------------------------------------------------------------


def _run8(body: str, timeout=900):
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.dist.sharding import init_params
        from repro.models import build_model
        from repro.models.base import ShapeSpec
        from repro.plan import MeshSpec, build_plan
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_two_stage_plan_matches_unpipelined_loss_8dev():
    out = _run8("""
    cfg = reduced_config("yi_6b").with_(vocab=64)        # 4 layers
    shape = ShapeSpec("t", 16, 8, "train")
    p1 = build_plan(cfg, shape, mesh_spec=MeshSpec.debug(2, 4),
                    pipeline_stages=1)
    p2 = build_plan(cfg, shape, mesh_spec=MeshSpec.debug(2, 4),
                    pipeline_stages=2)
    # the layers axis shards across the data slice the cost model chose
    assert p2.ir.stage_axis == "data"
    assert p2.rules.get("layers") == "data"
    assert p2.ir.placement_method == "bnb"
    assert [ (s.first_layer, s.n_layers, s.row, s.height)
             for s in p2.ir.stages ] == [(0, 2, 0, 1), (2, 2, 1, 1)]
    sp = p2.ir.param_pspecs["['blocks']['attn']['wq']['w']"]
    assert sp.startswith("PartitionSpec('data'"), sp
    # stacked weights replicate under the single-stage plan
    sp1 = p1.ir.param_pspecs["['blocks']['attn']['wq']['w']"]
    assert not sp1.startswith("PartitionSpec('data'"), sp1

    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "labels": jnp.ones((8, 16), jnp.int32)}
    model = build_model(cfg)
    ref = float(model.loss(
        init_params(jax.random.PRNGKey(0), model.param_specs()), batch))
    losses = []
    for plan in (p1, p2):
        params, opt = plan.init_train_state(seed=0)
        exe = plan.executable("train")
        _, _, metrics = exe.compiled(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert abs(losses[0] - losses[1]) < 1e-3, losses
    assert abs(losses[1] - ref) < 1e-2, (losses, ref)
    # the two plans compiled distinct executables (stages is in the key)
    keys = {k.stages for k in p1.cache._entries} | \
           {k.stages for k in p2.cache._entries}
    assert keys == {1, 2}
    print("STAGE PARITY OK", losses, ref)
    """)
    assert "STAGE PARITY OK" in out


def test_two_stage_decode_state_shards_8dev():
    out = _run8("""
    cfg = reduced_config("yi_6b").with_(vocab=64)
    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(2, 4),
                      pipeline_stages=2)
    state = plan.fresh_decode_state(8, 32)
    shard = state["cache_k"].sharding
    # the KV cache's layer dim rides the same stage slices as the weights
    assert str(shard.spec).startswith("PartitionSpec('data'"), shard.spec
    b = plan.make_batcher()
    from repro.serve import DecodeRequest
    with plan.activate():
        b.init_demo_params(0)
        for i in range(4):
            b.submit(DecodeRequest(f"r{i}", [1 + i, 2, 3], max_new_tokens=4))
        res = b.run()
    assert all(len(r.tokens) == 4 for r in res.values())
    print("STAGED DECODE OK")
    """)
    assert "STAGED DECODE OK" in out


# ---------------------------------------------------------------------------
# Quantize: calibration invariants
# ---------------------------------------------------------------------------


def test_quantize_pass_records_and_calibrates():
    full = reduced_config("yi_6b")
    plan = build_plan(full, None, mesh_spec=MeshSpec.debug(1, 1),
                      quantized=True)
    assert plan.cfg.quantized and plan.cfg.quantized_mlp
    assert plan.ir.quant["mlp"] and not plan.ir.quant["calibrated"]
    params = init_params(jax.random.PRNGKey(0),
                         build_model(full).param_specs())
    plan.calibrate(params)
    assert plan.ir.quant["calibrated"]
    x_s, w_s, o_s = plan.ir.quant["mlp_shifts"]
    assert o_s <= x_s + w_s                    # SRS shift stays >= 0
    assert (plan.cfg.mlp_x_shift, plan.cfg.mlp_w_shift,
            plan.cfg.mlp_out_shift) == (x_s, w_s, o_s)
    names = [name for name, _ in plan.ir.decisions]
    assert names.count("Quantize") == 2        # pass + calibration record


def test_quantized_train_plan_keeps_float_mlp(cfg):
    """MLP quantization is a decode-path decision: a quantized TRAIN plan
    keeps the float MLP (only serve plans route it through the kernel)."""
    plan = build_plan(cfg, ShapeSpec("t", 32, 2, "train"),
                      mesh_spec=MeshSpec.debug(1, 1), quantized=True)
    assert plan.cfg.quantized and not plan.cfg.quantized_mlp


# ---------------------------------------------------------------------------
# Compile: everything AOT through the shared cache
# ---------------------------------------------------------------------------


def test_plan_serve_zero_new_lowerings_after_warmup(cfg):
    from repro.serve import DecodeRequest

    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    assert set(plan.ir.executables) == {"decode", "prefill",
                                        "masked_decode"}
    batcher = plan.make_batcher()
    with plan.activate():
        batcher.init_demo_params(0)
        batcher.submit(DecodeRequest("w0", [1, 2], max_new_tokens=3))
        batcher.run()
        warm = dict(plan.stats())
        batcher.submit(DecodeRequest("w1", [2, 3], max_new_tokens=3))
        out = batcher.run()
    after = plan.stats()
    assert len(out) == 1
    assert after["hits"] > warm["hits"]
    assert after["lowerings"] == warm["lowerings"]
    assert after["compiles"] == warm["compiles"]


def test_plan_train_executable_counted_and_cached(cfg):
    plan = build_plan(cfg, ShapeSpec("t", 32, 2, "train"),
                      mesh_spec=MeshSpec.debug(1, 1))
    e1 = plan.executable("train")
    stats = plan.stats()
    assert stats["compiles"] == 1 and stats["lowerings"] == 1
    e2 = plan.executable("train")
    assert e2 is e1                            # cache hit, same executable
    assert plan.stats()["hits"] == 1
    params, opt = plan.init_train_state(seed=0)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    _, _, metrics = e1.compiled(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
