"""Serving subsystem: executable cache, state pools, bucketed batching.

The two acceptance properties this file pins down:

* a second request group hitting an already-seen (arch, shape, mode)
  bucket is served straight from the ExecutableCache — the hit counter
  increments and the lowering/compile counters do NOT move;
* int8 ``quantized`` debug decode produces the same greedy argmax tokens
  as the float path for (at least) the first 4 steps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_skip_stub

from repro.configs import reduced_config
from repro.dist.sharding import init_params
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.serve import (
    Bucket,
    BucketPolicy,
    DecodeRequest,
    ServeBatcher,
    StatePool,
)
from repro.serve.batcher import _pow2ceil

given, settings, st = hypothesis_or_skip_stub()


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("yi_6b").with_(n_layers=2, vocab=64)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


@pytest.fixture(scope="module")
def params(cfg, test_seed):
    return init_params(jax.random.PRNGKey(test_seed),
                       build_model(cfg).param_specs())


@pytest.fixture(scope="module")
def batcher(cfg, mesh, params):
    """One warm float batcher shared by the read-only tests."""
    with mesh:
        return ServeBatcher(cfg, mesh).load_params(params)


# ---------------------------------------------------------------------------
# bucket policy / request admission
# ---------------------------------------------------------------------------


def test_bucket_policy_smallest_fit():
    policy = BucketPolicy([Bucket(256, 2), Bucket(64, 2)])
    assert policy.bucket_for(10) == Bucket(64, 2)
    assert policy.bucket_for(64) == Bucket(64, 2)
    assert policy.bucket_for(65) == Bucket(256, 2)
    with pytest.raises(ValueError, match="positions"):
        policy.bucket_for(257)


def test_request_validation():
    with pytest.raises(ValueError, match="empty prompt"):
        DecodeRequest("r", [], 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        DecodeRequest("r", [1], 0)
    # need_len pads the prompt to a power of two
    assert DecodeRequest("r", [1, 2, 3], 4).need_len == 4 + 4


def test_submit_rejects_oversized_request(batcher):
    with pytest.raises(ValueError, match="positions"):
        batcher.submit(DecodeRequest("big", [1] * 300, 8))


def test_submit_rejects_duplicate_request_id(cfg, mesh, params):
    """Two queued requests with one id would last-write-win in results."""
    with mesh:
        b = ServeBatcher(cfg, mesh).load_params(params)
        b.submit(DecodeRequest("dup", [1, 2], max_new_tokens=2))
        with pytest.raises(ValueError, match="duplicate request id"):
            b.submit(DecodeRequest("dup", [3, 4], max_new_tokens=2))
        b.run()
        # the id is free again once its result has been returned
        b.submit(DecodeRequest("dup", [5, 6], max_new_tokens=2))
        out = b.run()
    assert len(out["dup"].tokens) == 2


# ---------------------------------------------------------------------------
# property-based: _pow2ceil and BucketPolicy.bucket_for
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=1 << 20))
@settings(max_examples=200, deadline=None)
def test_pow2ceil_minimal_covering_power(n):
    p = _pow2ceil(n)
    assert p >= n                          # covers
    assert p & (p - 1) == 0                # a power of two
    assert p == 1 or p // 2 < n            # and the SMALLEST such


@given(st.integers(min_value=1, max_value=1 << 20),
       st.integers(min_value=0, max_value=1 << 10))
@settings(max_examples=100, deadline=None)
def test_pow2ceil_monotone(n, delta):
    assert _pow2ceil(n + delta) >= _pow2ceil(n)


_BUCKET_LENS = st.lists(
    st.integers(min_value=4, max_value=15).map(lambda e: 1 << e),
    min_size=1, max_size=5, unique=True)


@given(_BUCKET_LENS, st.integers(min_value=1, max_value=1 << 16))
@settings(max_examples=200, deadline=None)
def test_bucket_for_minimal_covering_bucket(lens, need):
    policy = BucketPolicy([Bucket(n, 2) for n in lens])
    fitting = [n for n in sorted(lens) if need <= n]
    if not fitting:
        # over-long requests are rejected at submit time, never queued
        with pytest.raises(ValueError, match="positions"):
            policy.bucket_for(need)
        return
    b = policy.bucket_for(need)
    assert b.max_len == fitting[0]         # the smallest bucket that fits


@given(_BUCKET_LENS,
       st.integers(min_value=1, max_value=1 << 14),
       st.integers(min_value=0, max_value=1 << 14))
@settings(max_examples=100, deadline=None)
def test_bucket_for_monotone_in_need(lens, need, delta):
    """A larger request never lands in a smaller bucket."""
    policy = BucketPolicy([Bucket(n, 2) for n in lens])
    try:
        small = policy.bucket_for(need)
    except ValueError:
        small = None
    try:
        big = policy.bucket_for(need + delta)
    except ValueError:
        return                              # bigger need may only overflow
    assert small is not None               # need <= need+delta must fit too
    assert big.max_len >= small.max_len


# ---------------------------------------------------------------------------
# ACCEPTANCE: warm bucket -> zero new lowerings, hit counter moves
# ---------------------------------------------------------------------------


def test_second_request_hits_cache_zero_new_lowerings(batcher, mesh):
    with mesh:
        batcher.submit(DecodeRequest("warm0", [1, 2], max_new_tokens=3))
        batcher.submit(DecodeRequest("warm1", [3, 4, 5], max_new_tokens=3))
        batcher.run()
        warm = batcher.cache.stats()
        assert warm["compiles"] >= 2          # prefill + decode compiled once

        batcher.submit(DecodeRequest("hit0", [2, 3], max_new_tokens=3))
        batcher.submit(DecodeRequest("hit1", [4, 5, 6], max_new_tokens=3))
        out = batcher.run()
        after = batcher.cache.stats()

    assert len(out) == 2 and all(len(r.tokens) == 3 for r in out.values())
    assert after["hits"] > warm["hits"]                   # served from cache
    assert after["compiles"] == warm["compiles"]          # zero new compiles
    assert after["lowerings"] == warm["lowerings"]        # zero new lowerings
    assert after["misses"] == warm["misses"]


def test_cache_single_flight_concurrent_misses():
    """Two threads missing the same key build once; a hit on a different
    key never waits behind an in-flight compile."""
    import threading
    import time as _time

    from repro.serve import ExecutableCache

    class FakeBundle:
        def lower(self):
            _time.sleep(0.2)
            return self

        def compile(self):
            return object()

    from repro.serve import CacheKey

    cache = ExecutableCache()
    key = CacheKey("a", "decode", 1, 8, 0, "megatron", (("data", 1),))
    other = CacheKey("a", "decode", 2, 8, 0, "megatron", (("data", 1),))
    builds = []
    results = []

    def get(k):
        results.append(cache.get_or_build(
            k, lambda: builds.append(k) or FakeBundle()))

    threads = [threading.Thread(target=get, args=(key,)) for _ in range(3)]
    for t in threads:
        t.start()
    _time.sleep(0.05)                     # builders are inside the compile
    t0 = _time.perf_counter()
    get(other)                            # different key: only its own 0.2s
    # serialized behind the other build this would be >= 0.35s
    assert _time.perf_counter() - t0 < 0.35
    for t in threads:
        t.join()
    assert builds.count(key) == 1         # single-flight per key
    assert cache.stats()["compiles"] == 2
    assert len({id(r.compiled) for r in results if r.key == key}) == 1


def test_cache_keys_distinct_steps_per_dispatch(cfg, mesh):
    """Micro-run executables are keyed by k: a k-step scanned program is
    a different executable than the single-step one, so distinct k
    values must never collide — and re-requesting a warm k must be a
    pure cache hit (zero new lowerings)."""
    from repro.plan import build_plan

    plan = build_plan(cfg, None, mesh_spec=mesh)
    e1 = plan.serve_executable("masked_decode", batch=2, max_len=64,
                               steps_per_dispatch=1)
    e4 = plan.serve_executable("masked_decode", batch=2, max_len=64,
                               steps_per_dispatch=4)
    assert e1 is not e4
    assert e1.key != e4.key
    assert (e1.key.steps, e4.key.steps) == (1, 4)
    warm = dict(plan.cache.stats())
    assert warm["entries"] == 2 and warm["compiles"] == 2

    again = plan.serve_executable("masked_decode", batch=2, max_len=64,
                                  steps_per_dispatch=4)
    assert again is e4                       # same k: resident executable
    after = plan.cache.stats()
    assert after["hits"] == warm["hits"] + 1
    assert after["lowerings"] == warm["lowerings"]   # zero new lowerings
    assert after["compiles"] == warm["compiles"]


def test_steps_per_dispatch_rejected_for_other_kinds(cfg, mesh):
    """k only parameterizes the masked-decode micro-run; silently keying
    a prefill/decode build by k would fracture the cache."""
    from repro.plan import build_plan
    from repro.serve import CacheKey

    plan = build_plan(cfg, None, mesh_spec=mesh)
    with pytest.raises(ValueError, match="masked_decode"):
        plan.serve_executable("decode", batch=2, max_len=64,
                              steps_per_dispatch=4)
    with pytest.raises(ValueError, match="steps_per_dispatch"):
        plan.serve_executable("masked_decode", batch=2, max_len=64,
                              steps_per_dispatch=0)
    # CacheKey default keeps pre-micro-run keys stable (steps == 1)
    key = CacheKey("a", "decode", 1, 8, 0, "megatron", (("data", 1),))
    assert key.steps == 1


def test_distinct_buckets_get_distinct_executables(cfg, mesh, params):
    with mesh:
        b = ServeBatcher(cfg, mesh,
                         policy=BucketPolicy([Bucket(64, 2), Bucket(256, 2)]),
                         ).load_params(params)
        b.submit(DecodeRequest("short", [1, 2], max_new_tokens=2))
        b.submit(DecodeRequest("long", [1] * 40, max_new_tokens=60))
        res = b.run()
    assert res["short"].bucket == "b2xl64"
    assert res["long"].bucket == "b2xl256"
    # 2 buckets x (prefill + decode)
    assert b.cache.stats()["entries"] == 4


# ---------------------------------------------------------------------------
# correctness: batched prefill->decode == unbatched greedy loop
# ---------------------------------------------------------------------------


def _unbatched_greedy(model, params, prompt, n_new, max_len=64):
    state = jax.tree.map(
        jnp.zeros_like,
        init_params(jax.random.PRNGKey(0),
                    model.decode_state_specs(1, max_len)))
    toks, tok = [], None
    for i in range(len(prompt) + n_new - 1):
        t = jnp.array([prompt[i] if i < len(prompt) else tok], jnp.int32)
        logits, state = model.decode_step(params, state, t, jnp.int32(i))
        tok = int(jnp.argmax(logits, -1)[0])
        if i >= len(prompt) - 1:
            toks.append(tok)
    return toks


def test_batched_decode_matches_unbatched(cfg, mesh, params, batcher):
    """Mixed prompt lengths in one group reproduce per-sequence greedy
    decode exactly: teacher-forced prefill never pollutes the cache."""
    model = build_model(cfg)
    prompts = [[1, 2], [5, 11, 23, 8]]
    refs = [_unbatched_greedy(model, params, p, 5) for p in prompts]
    with mesh:
        for i, p in enumerate(prompts):
            batcher.submit(DecodeRequest(f"m{i}", p, max_new_tokens=5))
        got = batcher.run()
    for i, ref in enumerate(refs):
        assert got[f"m{i}"].tokens == ref, (i, got[f"m{i}"].tokens, ref)


# ---------------------------------------------------------------------------
# ACCEPTANCE: int8 quantized decode matches float argmax for 4 steps
# ---------------------------------------------------------------------------


def test_quantized_decode_matches_float_argmax(mesh, test_seed):
    """On the FULL debug config (the one ``--debug --quantized`` serves),
    quantized decode — int8 LM head AND the a16w8 MLP down-projection with
    plan-calibrated shifts — must reproduce the float greedy tokens for 4
    steps. Prompts are chosen so every decode step's top-2 logit gap
    clears the ~0.02 int8-weight noise floor; gaps below it may flip (the
    int8 contract, not a bug)."""
    full = reduced_config("yi_6b")
    full_params = init_params(jax.random.PRNGKey(test_seed),
                              build_model(full).param_specs())
    prompts = [[7, 3], [2, 3, 4], [6, 2, 8], [2, 4, 8, 16]]
    with mesh:
        bf = ServeBatcher(full, mesh).load_params(full_params)
        bq = ServeBatcher(full, mesh,
                          quantized=True).load_params(full_params)
        for i, p in enumerate(prompts):
            bf.submit(DecodeRequest(f"f{i}", p, max_new_tokens=4))
            bq.submit(DecodeRequest(f"q{i}", p, max_new_tokens=4))
        rf, rq = bf.run(), bq.run()
    # --quantized now covers the MLP too, with calibrated shifts
    assert bq.cfg.quantized_mlp
    assert bq.plan.ir.quant["calibrated"]
    for i in range(len(prompts)):
        assert rf[f"f{i}"].tokens[:4] == rq[f"q{i}"].tokens[:4], i
    # quantized executables are keyed separately, never shared
    assert all(k.quantized for k in bq.cache._entries)


# ---------------------------------------------------------------------------
# state pool
# ---------------------------------------------------------------------------


def test_state_pool_reuses_and_zeroes(cfg, mesh):
    from repro.plan import build_plan

    pool = StatePool(build_plan(cfg, None, mesh_spec=mesh))
    s1 = pool.acquire(2, 64)
    dirty = jax.tree.map(lambda x: x + 1, s1)        # simulate used state
    pool.release(2, 64, dirty)
    s2 = pool.acquire(2, 64)
    stats = pool.stats()["2x64"]
    assert stats["created"] == 1 and stats["reused"] == 1
    assert stats["in_use"] == 1 and stats["free"] == 0
    for leaf in jax.tree.leaves(s2):
        assert not np.asarray(leaf, np.float32).any()


def test_batcher_pool_cycles_states(batcher):
    """Every dispatch in the earlier tests released its state back."""
    stats = batcher.pool.stats()
    assert stats and all(p["in_use"] == 0 for p in stats.values())


def test_state_pool_reuse_is_per_bucket(cfg, mesh):
    """Buckets never share buffers: re-acquiring a released bucket reuses
    (no fresh allocation), while a different shape allocates its own."""
    from repro.plan import build_plan

    pool = StatePool(build_plan(cfg, None, mesh_spec=mesh))
    s64 = pool.acquire(2, 64)
    pool.release(2, 64, s64)
    s128 = pool.acquire(2, 128)            # different bucket: fresh
    pool.release(2, 128, s128)
    pool.acquire(2, 64)                    # released bucket: reused
    pool.acquire(2, 128)
    assert pool.stats()["2x64"] == {
        "created": 1, "reused": 1, "in_use": 1, "free": 0,
        "slot_resets": 0, "slots_wiped": 0}
    assert pool.stats()["2x128"] == {
        "created": 1, "reused": 1, "in_use": 1, "free": 0,
        "slot_resets": 0, "slots_wiped": 0}


def test_state_pool_reset_slots_no_leak(cfg, mesh):
    """The donated per-slot reset wipes exactly the masked lanes — a
    reused slot can never inherit its predecessor's KV — and leaves the
    surviving requests' state bit-identical."""
    from repro.plan import build_plan

    pool = StatePool(build_plan(cfg, None, mesh_spec=mesh))
    state = pool.acquire(2, 64)
    dirty = jax.tree.map(lambda x: x + 1, state)     # both slots "used"
    wiped = pool.reset_slots(2, 64, dirty, np.array([True, False]))
    sspecs = pool.plan.model.decode_state_specs(2, 64)
    leaves = jax.tree.leaves(wiped)
    axes = [s.logical.index("batch") for s in jax.tree.leaves(
        sspecs, is_leaf=lambda x: hasattr(x, "logical"))]
    assert pool.slot_resets == 1
    for leaf, axis in zip(leaves, axes):
        arr = np.moveaxis(np.asarray(leaf, np.float32), axis, 0)
        assert not arr[0].any()                      # slot 0 wiped clean
        assert (arr[1] == 1.0).all()                 # slot 1 untouched


# ---------------------------------------------------------------------------
# CLI argument validation (satellite: --tokens 0 summary crash)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argv", [
    ["--arch", "yi-6b", "--debug", "--tokens", "0"],
    ["--arch", "yi-6b", "--debug", "--rounds", "0"],
    ["--arch", "yi-6b", "--debug", "--steps-per-dispatch", "0"],
    ["--arch", "yi-6b", "--debug", "--steps-per-dispatch", "4"],
])
def test_serve_cli_rejects_bad_counts(monkeypatch, argv):
    from repro.launch import serve

    monkeypatch.setattr("sys.argv", ["serve.py"] + argv)
    with pytest.raises(SystemExit) as exc:
        serve.main()
    assert exc.value.code == 2
