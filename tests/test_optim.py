"""Optimizers + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    def _seeds(f):
        return settings(max_examples=25, deadline=None)(
            given(st.integers(0, 2**31 - 1))(f))
except ImportError:
    # Dev dep absent: fall back to a fixed seed sweep. (Other files use
    # conftest.hypothesis_or_skip_stub, which skips the property test;
    # here the strategy is a single integer so we can keep it running.)

    def _seeds(f):
        return pytest.mark.parametrize("seed", [0, 7, 1337, 2**31 - 1])(f)

from repro.dist.sharding import ParamSpec
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    error_feedback_reduce,
)
from repro.optim.optimizers import adafactor, adamw

KEY = jax.random.PRNGKey(0)


def _quadratic_problem():
    target = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((8, 8)), jnp.float32),
              "b": jnp.ones((8,), jnp.float32)}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    return params, loss


def _run(opt, params, loss, steps=60):
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state, _ = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    return params, loss(params)


def test_adamw_converges_on_quadratic():
    params, loss = _quadratic_problem()
    l0 = float(loss(params))
    _, lT = _run(adamw(lr=0.05, weight_decay=0.0), params, loss)
    assert float(lT) < 0.05 * l0


def test_adafactor_converges_on_quadratic():
    params, loss = _quadratic_problem()
    l0 = float(loss(params))
    _, lT = _run(adafactor(lr=0.05), params, loss, steps=120)
    assert float(lT) < 0.2 * l0


def test_adamw_grad_clipping_bounds_update():
    opt = adamw(lr=1.0, max_grad_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 1e6, jnp.float32)}
    upd, state, gnorm = opt.update(g, state, params)
    assert float(gnorm) > 1e5          # raw norm reported
    assert np.isfinite(np.asarray(upd["w"])).all()
    assert np.abs(np.asarray(upd["w"])).max() < 20.0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((16, 8), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    state = opt.init(params)
    assert state["f"]["w"]["vr"].shape == (16,)
    assert state["f"]["w"]["vc"].shape == (8,)
    assert state["f"]["b"]["v"].shape == (8,)
    # state_specs mirrors the same shapes
    specs = opt.state_specs({
        "w": ParamSpec((16, 8), ("row_in", "fsdp")),
        "b": ParamSpec((8,), (None,)),
    })
    assert specs["f"]["w"]["vr"].shape == (16,)
    assert specs["f"]["w"]["vc"].shape == (8,)


@_seeds
def test_compression_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(128) * rng.uniform(0.01, 10),
                    jnp.float32)
    q, scale = compress_int8(g)
    err = np.abs(np.asarray(decompress_int8(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the ACCUMULATED applied update tracks the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(3)
    residual = jnp.zeros((64,), jnp.float32)
    total_true = np.zeros(64)
    total_applied = np.zeros(64)
    for step in range(200):
        g = jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)
        applied, residual = error_feedback_reduce(g, residual)
        total_true += np.asarray(g)
        total_applied += np.asarray(applied)
    # applied total = true total - final residual
    np.testing.assert_allclose(
        total_applied + np.asarray(residual), total_true, atol=1e-3)
    assert np.abs(np.asarray(residual)).max() < 0.05  # one quantum-ish
