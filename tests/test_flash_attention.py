"""Flash-attention Pallas kernel vs oracle (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention

KEY = jax.random.PRNGKey(0)


def _qkv(BH, Sq, Sk, hd, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (BH, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (BH, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (BH, Sk, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("Sq,Sk,hd", [
    (32, 32, 16), (64, 64, 8), (128, 128, 32), (96, 96, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(Sq, Sk, hd, causal):
    q, k, v = _qkv(2, Sq, Sk, hd)
    got = flash_attention(q, k, v, causal=causal)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_q_start_offset():
    """Decode-style offset: q rows sit at positions q_start..q_start+Sq."""
    q, k, v = _qkv(1, 32, 64, 16)
    got = flash_attention(q, k, v[:, :, :], causal=True, q_start=32)
    want = attention_ref(q, k, v, causal=True, q_start=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_block_sweep():
    q, k, v = _qkv(1, 64, 64, 16)
    want = attention_ref(q, k, v, causal=True)
    for bq, bk in [(8, 8), (16, 32), (32, 16), (64, 64)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(2, 64, 64, 16, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2)


def test_flash_online_softmax_stability():
    """Large score magnitudes: online softmax must not overflow."""
    q, k, v = _qkv(1, 32, 32, 16)
    got = flash_attention(q * 100, k * 100, v, causal=False,
                          block_q=8, block_k=8)
    want = attention_ref(q * 100, k * 100, v, causal=False)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
