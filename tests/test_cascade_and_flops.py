"""Cascade resolution invariants + analytic MODEL_FLOPS accounting."""

import pytest
from conftest import hypothesis_or_skip_stub

given, settings, st = hypothesis_or_skip_stub()

from repro.configs import get_config
from repro.core.cascade import cascade_grid_factor, resolve_cascade
from repro.core.device import AIEMLDevice, NATIVE_TILINGS
from repro.launch.model_flops import model_flops, param_counts
from repro.models.base import SHAPES

DEV = AIEMLDevice()
T8 = NATIVE_TILINGS[("int8", "int8")]


@given(f_in=st.integers(1, 4096), f_out=st.integers(1, 4096))
@settings(max_examples=40, deadline=None)
def test_resolve_cascade_covers_layer(f_in, f_out):
    c = resolve_cascade(f_in, f_out, T8, DEV, batch=128, a_bytes=1, w_bytes=1)
    assert c.cas_len * c.f_in_slice >= f_in
    assert c.cas_num * c.f_out_slice >= f_out
    assert c.f_in_slice % T8.K == 0
    assert c.f_out_slice % T8.N == 0
    # resident weight slice fits tile-local memory
    assert c.f_in_slice * c.f_out_slice <= DEV.local_mem_bytes


def test_resolve_cascade_honors_overrides():
    c = resolve_cascade(256, 256, T8, DEV, batch=128, a_bytes=1, w_bytes=1,
                        overrides={"cas_len": 4, "cas_num": 2})
    assert c.cas_len == 4 and c.cas_num == 2
    assert c.cas_len * c.f_in_slice >= 256


def test_cascade_grid_factor():
    assert cascade_grid_factor(16, 4) == (4, 4)
    assert cascade_grid_factor(16, 16) == (16, 1)
    assert cascade_grid_factor(7, 3) == (1, 7)  # prime TP


# ---------------------------------------------------------------------------


def test_param_counts_match_known_sizes():
    """Sanity: published parameter counts within 12%."""
    expect = {
        "yi_6b": 6.1e9,
        "qwen1_5_4b": 4.0e9,
        "mistral_large_123b": 123e9,
        "qwen1_5_110b": 111e9,
        "rwkv6_7b": 7.6e9,
        "zamba2_2_7b": 2.7e9,
        "kimi_k2_1t": 1.0e12,
        "phi3_5_moe_42b": 42e9,
    }
    for arch, want in expect.items():
        total, active = param_counts(get_config(arch))
        assert abs(total - want) / want < 0.12, (arch, total, want)
        assert active <= total


def test_moe_active_params():
    """Kimi: ~32B active of ~1T total (top-8 of 384 experts)."""
    total, active = param_counts(get_config("kimi_k2_1t"))
    assert 25e9 < active < 45e9, active
    assert total > 9e11


def test_model_flops_scaling():
    cfg = get_config("yi_6b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    # train = 6ND over 1M tokens; prefill = 2ND over 1M tokens => 3x
    assert t / p == pytest.approx(3.0, rel=0.01)
    # decode: 2*N*batch(128) tokens
    _, n_active = param_counts(cfg)
    assert d == pytest.approx(2.0 * n_active * 128, rel=1e-6)
