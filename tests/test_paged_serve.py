"""Paged KV cache: dense-parity, prefix reuse, and zero-lowering churn.

The acceptance properties this file pins down (docs/memory_model.md):

* **token-for-token parity with dense** — the same request set produces
  identical greedy tokens under ``schedule="fifo"`` (dense slabs) and
  ``schedule="continuous", paged=...`` for ``steps_per_dispatch`` in
  {1, 2, 4}, float, quantized, and hybrid-SSM alike: paged attention
  runs at LOCAL positions through the page table, and RoPE's
  relative-position property makes that invisible to the scores;
* **shared-prefix reuse** — requests sharing a system prompt map the
  published prefix pages read-only, skip that prefill span, and still
  produce exactly the dense tokens;
* **zero new lowerings after warmup** — the paged masked-decode program
  is ONE executable per (bucket, k), keyed apart from the dense one;
  churning traffic (prefix hits and misses alike) only moves the cache
  hit counter;
* **boundary-time reclaim** — finish, cancellation, and drain all hand
  pages back: after every run() the pool holds only scratch pages and
  live prefix-cache entries.
"""

import jax
import pytest

from repro.configs import reduced_config
from repro.dist.sharding import init_params
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.models.base import PAGED_STATE_KEYS, paged_state_specs
from repro.serve import Bucket, BucketPolicy, DecodeRequest, ServeBatcher

PAGED = (64, 16)          # (page_count, page_size) used throughout


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("yi_6b").with_(n_layers=2, vocab=64)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


@pytest.fixture(scope="module")
def params(cfg, test_seed):
    return init_params(jax.random.PRNGKey(test_seed),
                       build_model(cfg).param_specs())


@pytest.fixture(scope="module")
def hybrid_setup(test_seed):
    hcfg = reduced_config("zamba2_2_7b")
    return hcfg, init_params(jax.random.PRNGKey(test_seed),
                             build_model(hcfg).param_specs())


# same gap-robust trace as test_scheduler.py: every decode step's top-2
# logit gap clears float-rounding noise at any admission offset
_PARITY_TRACE = [
    ("p0", [63, 51, 50], 7),
    ("p1", [33, 17, 32], 5),
    ("p2", [63, 1], 2),
    ("p3", [30, 52], 4),
    ("p4", [39, 53], 7),
    ("p5", [55, 44, 23], 7),
]

# two waves sharing one 18-token system prompt (> one 16-token page):
# wave 2 must hit the prefix published by wave 1
_SYSTEM = [7, 3, 11, 2, 9, 40, 41, 5, 8, 60, 13, 21, 34, 55, 1, 6, 17, 28]
_SHARED_TRACE = [
    [("s0", _SYSTEM + [63, 51], 6), ("s1", _SYSTEM + [33, 17, 9], 5)],
    [("s2", _SYSTEM + [12], 4), ("s3", _SYSTEM + [44, 2], 5)],
]


@pytest.fixture(scope="module")
def fifo_reference(cfg, mesh, params, hybrid_setup):
    """Lazy per-variant DENSE fifo token reference."""
    cache = {}

    def get(variant, trace=None):
        trace = trace or _PARITY_TRACE
        key = (variant, id(trace))
        if key in cache:
            return cache[key]
        with mesh:
            if variant == "hybrid":
                hcfg, hparams = hybrid_setup
                b = ServeBatcher(hcfg, mesh,
                                 policy=BucketPolicy([Bucket(64, 2)]),
                                 ).load_params(hparams)
            else:
                b = ServeBatcher(cfg, mesh,
                                 quantized=(variant == "quantized"),
                                 ).load_params(params)
            out = {}
            for wave in (trace if isinstance(trace[0], list) else [trace]):
                for rid, p, n in wave:
                    b.submit(DecodeRequest(rid, p, max_new_tokens=n))
                out.update({r: v.tokens for r, v in b.run().items()})
            cache[key] = out
        return cache[key]

    return get


def _paged_batcher(cfg_, mesh, params_, k, quantized=False):
    b = ServeBatcher(cfg_, mesh, quantized=quantized,
                     schedule="continuous", steps_per_dispatch=k,
                     policy=BucketPolicy([Bucket(64, 2)]),
                     paged=PAGED).load_params(params_)
    return b


def _assert_reclaimed(b):
    """After a drained run(), only scratch + prefix-cache pages remain."""
    s = b.stats()["paged"]
    assert s["pages_in_use"] == s["scratch_pages"] + s["prefix_entries"], s


# ---------------------------------------------------------------------------
# ACCEPTANCE: paged == dense tokens across the k x variant matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("quantized", [False, True],
                         ids=["float", "quantized"])
def test_paged_matches_dense_argmax(cfg, mesh, params, quantized, k,
                                    fifo_reference):
    ref = fifo_reference("quantized" if quantized else "float")
    with mesh:
        b = _paged_batcher(cfg, mesh, params, k, quantized=quantized)
        for rid, p, n in _PARITY_TRACE:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        rc = b.run()
    assert b.scheduler.refills > 0          # parity held ACROSS slot reuse
    for rid, _, n in _PARITY_TRACE:
        assert ref[rid] == rc[rid].tokens, (k, rid)
        assert len(rc[rid].tokens) == n
    for key in b.cache._entries:
        if key.kind == "masked_decode":
            assert key.steps == k and key.paged == PAGED
    _assert_reclaimed(b)


@pytest.mark.parametrize("k", [1, 4])
def test_paged_matches_dense_on_hybrid_ssm(mesh, k, fifo_reference,
                                           hybrid_setup):
    """Hybrid: KV leaves go paged while the SSM/conv recurrence stays
    dense and still gets the fresh-lane wipe on slot reuse."""
    ref = fifo_reference("hybrid")
    hcfg, hparams = hybrid_setup
    with mesh:
        b = _paged_batcher(hcfg, mesh, hparams, k)
        for rid, p, n in _PARITY_TRACE:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        res = {r: v.tokens for r, v in b.run().items()}
    for rid, _, _ in _PARITY_TRACE:
        assert ref[rid] == res[rid], (k, rid)
    _assert_reclaimed(b)


# ---------------------------------------------------------------------------
# ACCEPTANCE: shared-prefix requests skip prefill and keep dense tokens
# ---------------------------------------------------------------------------


def test_shared_prefix_skips_prefill_with_dense_parity(cfg, mesh, params,
                                                       fifo_reference):
    """Two waves sharing one system prompt: the second wave's requests
    reuse the published prefix pages (prefill_skip_rate > 0, one page
    table entry per shared page) and still produce exactly the dense
    FIFO tokens."""
    ref = fifo_reference("float", _SHARED_TRACE)
    with mesh:
        b = _paged_batcher(cfg, mesh, params, k=4)
        out = {}
        for wave in _SHARED_TRACE:
            for rid, p, n in wave:
                b.submit(DecodeRequest(rid, p, max_new_tokens=n))
            out.update({r: v.tokens for r, v in b.run().items()})
    for wave in _SHARED_TRACE:
        for rid, _, n in wave:
            assert ref[rid] == out[rid], rid
    s = b.stats()["paged"]
    assert s["prefix_hits"] >= len(_SHARED_TRACE[1])
    assert s["skipped_prefill_tokens"] >= len(_SHARED_TRACE[1]) * 16
    assert s["prefill_skip_rate"] > 0
    # metrics surface the same counters per bucket
    m = b.stats()["buckets"]["b2xl64"]
    assert m["prefix_hits"] == s["prefix_hits"]
    assert m["peak_pages"] == s["peak_pages"]
    _assert_reclaimed(b)


# ---------------------------------------------------------------------------
# ACCEPTANCE: zero new lowerings after warmup; paged keys never collide
# ---------------------------------------------------------------------------


def test_paged_zero_new_lowerings_under_churn(cfg, mesh, params):
    with mesh:
        b = _paged_batcher(cfg, mesh, params, k=4)
        for rid, p, n in _PARITY_TRACE[:3]:
            b.submit(DecodeRequest(rid, p, max_new_tokens=n))
        b.run()
        warm = dict(b.cache.stats())
        assert warm["compiles"] == 1        # ONE paged executable

        for wave in range(3):
            for rid, p, n in _PARITY_TRACE:
                b.submit(DecodeRequest(f"w{wave}-{rid}", p,
                                       max_new_tokens=n))
            # alternate waves hit the shared system prompt so churn
            # exercises prefix hits AND misses on the warm executable
            if wave % 2:
                for rid, p, n in _SHARED_TRACE[0]:
                    b.submit(DecodeRequest(f"w{wave}-{rid}", p,
                                           max_new_tokens=n))
            b.run()
        after = b.cache.stats()

    assert after["lowerings"] == warm["lowerings"]
    assert after["compiles"] == warm["compiles"]
    assert after["misses"] == warm["misses"]
    assert after["hits"] > warm["hits"]
    _assert_reclaimed(b)


def test_paged_and_dense_executables_key_separately(cfg, mesh, params):
    """Same bucket geometry, paged vs dense: two distinct cache entries
    (the paged program has a ninth input and a pooled state layout)."""
    with mesh:
        plan_kw = dict(schedule="continuous",
                       policy=BucketPolicy([Bucket(64, 2)]))
        bd = ServeBatcher(cfg, mesh, **plan_kw).load_params(params)
        bd.submit(DecodeRequest("d", [5, 9], max_new_tokens=2))
        dense = bd.run()
        bp = ServeBatcher(cfg, mesh, paged=PAGED,
                          **plan_kw).load_params(params)
        bp.submit(DecodeRequest("d", [5, 9], max_new_tokens=2))
        paged = bp.run()
    assert dense["d"].tokens == paged["d"].tokens
    keys = [k for k in bp.cache._entries if k.kind == "masked_decode"]
    assert {k.paged for k in keys} == {PAGED}
    keys_d = [k for k in bd.cache._entries if k.kind == "masked_decode"]
    assert {k.paged for k in keys_d} == {()}


# ---------------------------------------------------------------------------
# reclaim on cancellation; validation; spec transform
# ---------------------------------------------------------------------------


def test_cancel_returns_pages_at_boundary(cfg, mesh, params):
    canceled = []

    def on_boundary(pos, slots):
        if pos == 4 and not canceled:
            canceled.append(True)
            b.cancel("victim")

    with mesh:
        b = _paged_batcher(cfg, mesh, params, k=4)
        b.scheduler.on_boundary = on_boundary
        b.submit(DecodeRequest("victim", [9, 5, 3], max_new_tokens=12))
        b.submit(DecodeRequest("stays", [63, 51, 50], max_new_tokens=7))
        out = b.run()
    assert "victim" not in out and "stays" in out
    assert b.scheduler.cancellations == 1
    _assert_reclaimed(b)


def test_paged_requires_continuous_schedule(cfg, mesh):
    with pytest.raises(ValueError, match="continuous"):
        ServeBatcher(cfg, mesh, schedule="fifo", paged=PAGED)


def test_paged_requires_page_aligned_buckets(cfg, mesh):
    with pytest.raises(ValueError, match="multiple of"):
        ServeBatcher(cfg, mesh, schedule="continuous",
                     policy=BucketPolicy([Bucket(72, 2)]), paged=(8, 16))


@pytest.mark.parametrize("arch", ["yi_6b", "zamba2_2_7b", "rwkv6_7b",
                                  "llama_3_2_vision_90b",
                                  "seamless_m4t_large_v2"])
def test_paged_state_specs_page_kv_only(arch):
    """Across all five families: cache_k/cache_v swap [batch, max_len]
    for [page_count, page_size]; cross caches and recurrent state keep
    their dense per-slot shapes (and their batch axis)."""
    model = build_model(reduced_config(arch))
    dense = model.decode_state_specs(2, 64)
    paged = paged_state_specs(dense, 8, 16)
    assert set(dense) == set(paged)
    for name, spec in paged.items():
        if name in PAGED_STATE_KEYS and name in dense:
            assert spec.shape[-4:-2] == (8, 16), name
            assert "batch" not in spec.logical, name
        else:
            assert spec.shape == dense[name].shape, name
