"""Fault tolerance: checkpoint/restart bit-exactness, failure injection,
straggler detection, elastic restore, data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import SyntheticTokens, make_train_iterator
from repro.dist.sharding import init_params
from repro.models import build_model
from repro.optim.optimizers import adamw
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FailureInjector, InjectedFailure, StragglerMonitor
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _tiny_setup():
    cfg = reduced_config("yi_6b").with_(vocab=64, n_layers=2)
    model = build_model(cfg)
    params = init_params(KEY, model.param_specs())
    opt = adamw(lr=1e-3)
    return model, params, opt


def _iter_factory(vocab=64):
    def factory(start):
        return make_train_iterator(vocab, 16, 4, seed=7, start_step=start)
    return factory


# ---------------------------------------------------------------------------


def test_data_pipeline_deterministic_and_resumable():
    ds = SyntheticTokens(vocab=97, seq_len=8, global_batch=4, seed=5)
    b1, b2 = ds.batch(13), ds.batch(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # iterator resume produces the same stream
    it = make_train_iterator(97, 8, 4, seed=5, start_step=0)
    stream = [next(it) for _ in range(5)]
    it2 = make_train_iterator(97, 8, 4, seed=5, start_step=3)
    np.testing.assert_array_equal(stream[3]["tokens"], next(it2)["tokens"])
    # labels are next-token shifted
    full = SyntheticTokens(97, 8, 4, seed=5).batch(0)
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_data_host_sharding_partitions_batch():
    a = SyntheticTokens(97, 8, 8, seed=5, host_id=0, n_hosts=2).batch(0)
    b = SyntheticTokens(97, 8, 8, seed=5, host_id=1, n_hosts=2).batch(0)
    assert a["tokens"].shape == (4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "step": jnp.int32(7)}
    for s in (10, 20, 30):
        mgr.save(s, state)
    assert mgr.all_steps() == [20, 30]  # retention pruned step 10
    restored = mgr.restore(30, state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_trainer_restart_is_bit_exact(tmp_path):
    """Run 8 steps straight vs 4 steps + crash + resume: same params."""
    model, params0, opt = _tiny_setup()

    def fresh():
        return jax.tree.map(lambda x: x.copy(), params0), opt.init(params0)

    # straight run
    cfg_a = TrainerConfig(steps=8, ckpt_every=100, log_every=100,
                          ckpt_dir=str(tmp_path / "a"))
    ta = Trainer(model.loss, opt, cfg_a)
    pa, _, _ = ta.fit(*fresh(), _iter_factory(), resume=False)

    # crash at 4, resume
    cfg_b = TrainerConfig(steps=8, ckpt_every=4, log_every=100,
                          ckpt_dir=str(tmp_path / "b"))
    tb = Trainer(model.loss, opt, cfg_b)
    tb.injector = FailureInjector(fail_at_steps=(5,))
    pb, ob = fresh()
    with pytest.raises(InjectedFailure):
        tb.fit(pb, ob, _iter_factory(), resume=True)
    # new trainer process resumes from the checkpoint at step 4
    tb2 = Trainer(model.loss, opt, cfg_b)
    pb2, ob2 = fresh()
    pb_final, _, hist = tb2.fit(pb2, ob2, _iter_factory(), resume=True)
    assert hist[0]["step"] == 4  # resumed, not restarted

    for ka, kb in zip(jax.tree.leaves(pa), jax.tree.leaves(pb_final)):
        np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))


def test_elastic_restore_reshards(tmp_path):
    """A checkpoint written under one layout restores onto another mesh."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(5, state)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored = mgr.restore(5, state, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(InjectedFailure):
        inj.check(3)
    inj.check(3)  # second time: already fired


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=2)
    flags = [mon.observe(i, dt) for i, dt in
             enumerate([1.0, 1.0, 1.0, 1.0, 5.0, 1.0])]
    assert flags == [False, False, False, False, True, False]
    assert len(mon.events) == 1 and mon.events[0]["step"] == 4
    # the straggler did not poison the EWMA
    assert mon.ewma < 1.5


def test_step_timing_immune_to_wall_clock_jumps(tmp_path, monkeypatch):
    """Step durations use the monotonic clock: a wall-clock jump (NTP
    slew, DST) mid-run must not spoof the straggler monitor or record
    negative/huge dt values in the history."""
    import repro.train.trainer as trainer_mod

    model, params, opt = _tiny_setup()
    # wall clock that jumps an hour backward, then forward, every call —
    # if fit() still measured intervals with time.time() every dt would
    # be +-3600s and the monitor would flag (or mask) everything
    base = [1_000_000.0]

    def jumping_wall_clock():
        base[0] += 3600.0 if len(mon_calls) % 2 else -3600.0
        mon_calls.append(None)
        return base[0]

    mon_calls = []
    monkeypatch.setattr(trainer_mod.time, "time", jumping_wall_clock)
    cfg = TrainerConfig(steps=5, ckpt_every=100, log_every=100,
                        ckpt_dir=str(tmp_path / "clock"))
    t = Trainer(model.loss, opt, cfg)
    _, _, hist = t.fit(jax.tree.map(lambda x: x.copy(), params),
                       opt.init(params), _iter_factory(), resume=False)
    assert len(hist) == 5
    for h in hist:
        assert 0.0 <= h["dt"] < 3600.0, h
    # tiny identical steps: the jumping wall clock must not have spoofed
    # a straggler (a 3600s "dt" is > threshold x ewma by any margin)
    assert t.monitor.events == []


def test_grad_accumulation_matches_full_batch(tmp_path):
    """microbatches=2 gives the same loss trajectory as full batch (linear
    loss in batch => identical gradients)."""
    model, params, opt = _tiny_setup()
    cfg1 = TrainerConfig(steps=3, ckpt_every=100, log_every=100,
                         ckpt_dir=str(tmp_path / "m1"), microbatches=1)
    cfg2 = TrainerConfig(steps=3, ckpt_every=100, log_every=100,
                         ckpt_dir=str(tmp_path / "m2"), microbatches=2)
    p1, _, h1 = Trainer(model.loss, opt, cfg1).fit(
        jax.tree.map(lambda x: x.copy(), params), opt.init(params),
        _iter_factory(), resume=False)
    p2, _, h2 = Trainer(model.loss, opt, cfg2).fit(
        jax.tree.map(lambda x: x.copy(), params), opt.init(params),
        _iter_factory(), resume=False)
    np.testing.assert_allclose(h1[0]["loss"], h2[0]["loss"], rtol=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)
