"""``repro.analysis``: the static-analysis suite itself.

Acceptance properties pinned here:

* every rule has a known-bad fixture that produces findings with the
  right rule id and a known-good twin that is clean — the proof that a
  real violation turns the CI ``static-analysis`` job red;
* the CacheKey-completeness rule fails when a synthetic
  compile-affecting kwarg is injected into the *real*
  ``ExecutionPlan.serve_executable`` without a matching key field
  (the issue's acceptance demo for the rule);
* the shipped tree is clean: ``analyze(src/repro, benchmarks)`` with
  the repo baseline reports zero unbaselined findings and zero
  baseline hygiene errors;
* baseline round-trip: finding -> baseline entry -> clean run ->
  remove entry -> red again; entries without justification and stale
  entries are hard errors;
* the CLI exits 0/1 correctly and ``--json`` emits the shared report
  shape that ``scripts/check_docs.py --json`` also produces.

The suite is jax-free on purpose — the analyzer must work in a bare
interpreter, and these tests prove it by never importing jax.
"""

import json
import os
import re
import subprocess
import sys

import pytest

from repro.analysis import (
    ALL_RULES,
    Baseline,
    analyze,
    write_baseline,
)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC_REPRO = os.path.join(ROOT, "src", "repro")
BENCHMARKS = os.path.join(ROOT, "benchmarks")
FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
REPO_BASELINE = os.path.join(ROOT, "analysis_baseline.json")

RULE_FIXTURES = {
    "RA101": ("retrace_bad.py", "retrace_good.py"),
    "RA201": ("cachekey_bad.py", "cachekey_good.py"),
    "RA301": ("donation_bad.py", "donation_good.py"),
    "RA401": ("hotpath_bad.py", "hotpath_good.py"),
    "RA501": ("layering_bad", "layering_good"),
}


def run_rule(rule_id, target):
    return analyze([os.path.join(FIXTURES, target)],
                   rules=[rule_id], baseline=None)


# ---------------------------------------------------------------------------
# ACCEPTANCE: every rule flags its bad fixture and passes its good twin
# ---------------------------------------------------------------------------


def test_every_registered_rule_has_a_fixture():
    assert {r.id for r in ALL_RULES} == set(RULE_FIXTURES), (
        "new rules must ship a bad/good fixture pair")


@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_bad_fixture_turns_red(rule_id):
    bad, _ = RULE_FIXTURES[rule_id]
    report = run_rule(rule_id, bad)
    assert report.findings, f"{bad} must produce {rule_id} findings"
    assert {f.rule for f in report.findings} == {rule_id}
    assert all(f.line > 0 and f.file for f in report.findings)
    assert not report.ok  # this is exactly what fails the CI job


@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_good_twin_is_clean(rule_id):
    _, good = RULE_FIXTURES[rule_id]
    report = run_rule(rule_id, good)
    assert not report.findings, "\n".join(
        f.render() for f in report.findings)
    assert report.ok


def test_retrace_finding_kinds():
    report = run_rule("RA101", "retrace_bad.py")
    kinds = {f.key.split(":")[0] for f in report.findings}
    assert {"branch", "loop", "concretize", "host-roundtrip",
            "mutable-closure", "unhashable-static"} <= kinds


def test_layering_resolves_laundered_reexport():
    report = run_rule("RA501", "layering_bad")
    laundered = [f for f in report.findings
                 if "imported via wrappers" in f.message]
    assert laundered, ("the wrappers shim must not hide "
                       "repro.dist.sharding from the import graph")
    assert "rules_for_mode" in laundered[0].message


def test_donation_flags_loop_and_straightline_reads():
    report = run_rule("RA301", "donation_bad.py")
    messages = " | ".join(f.message for f in report.findings)
    assert "next loop iteration" in messages
    assert "read again at line" in messages


# ---------------------------------------------------------------------------
# ACCEPTANCE: synthetic compile-affecting kwarg in the REAL plan is caught
# ---------------------------------------------------------------------------


def test_cachekey_rule_catches_synthetic_kwarg_in_real_plan(tmp_path):
    """Inject `fusion_mode` into the real ExecutionPlan.serve_executable:
    consumed by the masked_decode builder, never passed to _key. The
    rule must fail — this is how the next `steps`/`paged` can't be
    forgotten."""
    plan_src = open(os.path.join(SRC_REPRO, "plan", "plan.py")).read()
    patched = plan_src.replace(
        "def serve_executable(self, kind: str, *, batch: int, "
        "max_len: int,",
        "def serve_executable(self, kind: str, *, batch: int, "
        "max_len: int,\n                         fusion_mode: int = 0,")
    patched = patched.replace(
        "steps_per_dispatch=steps_per_dispatch, paged=paged, spec=spec)",
        "steps_per_dispatch=steps_per_dispatch + fusion_mode, "
        "paged=paged, spec=spec)")
    assert patched != plan_src, "plan.py drifted; update the patch anchors"
    work = tmp_path / "plan"
    work.mkdir()
    (work / "plan.py").write_text(patched)
    cache_src = open(os.path.join(SRC_REPRO, "serve", "cache.py")).read()
    (work / "cache.py").write_text(cache_src)

    report = analyze([str(work)], rules=["RA201"], baseline=None)
    hits = [f for f in report.findings if "fusion_mode" in f.message]
    assert hits, "unkeyed synthetic kwarg must produce an RA201 finding"
    assert hits[0].key.startswith("unkeyed-param:ExecutionPlan."
                                  "serve_executable")

    # control: the unpatched pair is clean
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "plan.py").write_text(plan_src)
    (clean / "cache.py").write_text(cache_src)
    assert analyze([str(clean)], rules=["RA201"], baseline=None).ok


def test_cachekey_rule_catches_unkeyed_draft_signature():
    """The speculative-decode shape of the same bug: ``spec_k`` and
    ``draft_layers`` pick the compiled program but are dropped by the
    key method. Both fields must be flagged — missing either one means
    two different draft signatures share an executable."""
    report = run_rule("RA201", "cachekey_spec_bad.py")
    assert not report.ok
    messages = " | ".join(f.message for f in report.findings)
    assert "spec_k" in messages
    assert "draft_layers" in messages


def test_cachekey_rule_catches_unkeyed_paged_field_in_spec_path():
    """The paged-speculative twin (ISSUE 10): a key method that keeps
    the draft signature but drops the page geometry goes red — a
    dense-spec and a paged-spec plan must never share an executable,
    since the paged one compiles with a ninth (page-table) input."""
    report = run_rule("RA201", "cachekey_paged_spec_bad.py")
    assert not report.ok
    messages = " | ".join(f.message for f in report.findings)
    assert "`paged`" in messages
    assert "`spec`" not in messages      # spec IS keyed: not flagged


# ---------------------------------------------------------------------------
# ACCEPTANCE: the shipped tree is clean under the repo baseline
# ---------------------------------------------------------------------------


def test_shipped_tree_has_no_unbaselined_findings():
    report = analyze([SRC_REPRO, BENCHMARKS], baseline=REPO_BASELINE)
    assert not report.findings, "\n".join(
        f.render() for f in report.findings)
    assert not report.errors, "\n".join(report.errors)
    assert report.files > 80, "scan roots look wrong"


def test_repo_baseline_entries_all_justified():
    base = Baseline.load(REPO_BASELINE)
    assert not base.load_errors, "\n".join(base.load_errors)
    for entry in base.entries:
        assert len(entry["justification"].strip()) >= 10, (
            f"{entry['ident']}: a justification must actually say why")


# ---------------------------------------------------------------------------
# baseline round-trip: finding -> baseline -> clean -> remove -> red
# ---------------------------------------------------------------------------

BAD_SNIPPET = '''
import jax


class AdmissionPolicy:
    def select(self, pending, fits, now):
        jax.block_until_ready(pending)
        return pending
'''


def test_baseline_round_trip(tmp_path):
    mod = tmp_path / "policy.py"
    mod.write_text(BAD_SNIPPET)
    base = tmp_path / "baseline.json"

    red = analyze([str(mod)], baseline=None)
    assert len(red.findings) == 1 and red.findings[0].rule == "RA401"

    write_baseline(base, red.findings, "fixture: sync sanctioned here")
    green = analyze([str(mod)], baseline=base)
    assert green.ok and len(green.baselined) == 1

    base.unlink()
    red_again = analyze([str(mod)], baseline=base)  # missing file = empty
    assert not red_again.ok and len(red_again.findings) == 1

    # idents are line-number free: shifting the code keeps the baseline
    write_baseline(base, red.findings, "fixture: sync sanctioned here")
    mod.write_text("# a new leading comment line\n" + BAD_SNIPPET)
    shifted = analyze([str(mod)], baseline=base)
    assert shifted.ok and len(shifted.baselined) == 1


def test_baseline_hygiene_errors(tmp_path):
    mod = tmp_path / "policy.py"
    mod.write_text(BAD_SNIPPET)

    unjustified = tmp_path / "unjustified.json"
    unjustified.write_text(json.dumps({
        "version": 1,
        "suppressions": [{"ident": "RA401:whatever", "justification": ""}],
    }))
    report = analyze([str(mod)], baseline=unjustified)
    assert any("no justification" in e for e in report.errors)

    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({
        "version": 1,
        "suppressions": [{"ident": "RA401:nonexistent:thing",
                          "justification": "was real once, code moved"}],
    }))
    report = analyze([str(mod)], baseline=stale)
    assert any("stale suppression" in e for e in report.errors)
    assert not report.ok, "a stale baseline must fail CI, not pass it"


# ---------------------------------------------------------------------------
# CLI + shared JSON report shape
# ---------------------------------------------------------------------------


def _run_cli(args, cwd=ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


def _assert_report_shape(data, tool):
    assert data["tool"] == tool
    assert isinstance(data["ok"], bool)
    assert set(data["counts"]) >= {"files", "findings"}
    for f in data["findings"]:
        assert set(f) >= {"rule", "file", "line", "message"}


def test_cli_red_on_fixture_and_json_report(tmp_path):
    out = tmp_path / "report.json"
    proc = _run_cli([os.path.join(FIXTURES, "hotpath_bad.py"),
                     "--no-baseline", "--json", str(out)])
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert re.search(r"hotpath_bad\.py:\d+: RA401", proc.stdout)
    data = json.loads(out.read_text())
    _assert_report_shape(data, "repro.analysis")
    assert not data["ok"] and data["counts"]["findings"] >= 1


def test_cli_green_on_shipped_tree():
    proc = _run_cli(["src/repro", "benchmarks"])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro.analysis: OK" in proc.stdout


def test_cli_rule_filter_and_list():
    proc = _run_cli(["--list-rules"])
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule.id in proc.stdout
    proc = _run_cli([os.path.join(FIXTURES, "hotpath_bad.py"),
                     "--no-baseline", "--rules", "RA501"])
    assert proc.returncode == 0, "RA501 alone must not flag hotpath_bad"


def test_check_docs_json_shares_report_shape(tmp_path):
    out = tmp_path / "docs_report.json"
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "check_docs.py"),
         "--json", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(out.read_text())
    _assert_report_shape(data, "scripts.check_docs")
    assert data["ok"]
