"""Multi-device integration tests (subprocess: needs its own XLA_FLAGS).

Each test spawns a fresh python that forces 8 host devices, builds a 2x4
("data","model") mesh, and runs REAL sharded computation — a train step in
both sharding modes with loss-parity against single-device execution, and a
decode step. This is the executable counterpart of the 512-device dry-run.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, timeout=600):
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import reduced_config
        from repro.dist.sharding import (init_params, rules_for_mode,
                                         sharding_ctx, specs_to_shardings,
                                         abstract_params)
        from repro.models import build_model
        from repro.models.base import ShapeSpec
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("mode", ["cascade", "megatron", "megatron_sp"])
def test_sharded_train_step_matches_single_device(mode):
    out = _run(f"""
    cfg = reduced_config("yi_6b").with_(vocab=64, n_layers=2, d_model=64,
                                        n_heads=8, n_kv=4,
                                        sharding_mode="{mode}")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.param_specs())
    batch = {{"tokens": jnp.ones((8, 16), jnp.int32),
              "labels": jnp.ones((8, 16), jnp.int32)}}
    # single-device reference
    ref = float(model.loss(params, batch))
    rules = rules_for_mode("{mode}")
    shardings = specs_to_shardings(model.param_specs(), mesh, rules)
    params_sh = jax.device_put(params, shardings)
    with mesh, sharding_ctx(mesh, rules):
        loss = jax.jit(model.loss)(params_sh, batch)
    got = float(loss)
    assert abs(got - ref) < 1e-2, (got, ref)
    # gradient parity on one leaf
    g_ref = jax.grad(model.loss)(params, batch)
    with mesh, sharding_ctx(mesh, rules):
        g_sh = jax.jit(jax.grad(model.loss))(params_sh, batch)
    a = np.asarray(jax.tree.leaves(g_ref)[0], np.float32)
    b = np.asarray(jax.tree.leaves(g_sh)[0], np.float32)
    assert np.allclose(a, b, atol=1e-2), np.abs(a - b).max()
    print("PARITY OK", got, ref)
    """)
    assert "PARITY OK" in out


def test_sharded_moe_and_decode():
    out = _run("""
    cfg = reduced_config("phi3_5_moe_42b").with_(vocab=64)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.param_specs())
    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "labels": jnp.ones((8, 16), jnp.int32)}
    ref = float(model.loss(params, batch))
    rules = rules_for_mode("megatron")
    shardings = specs_to_shardings(model.param_specs(), mesh, rules)
    params_sh = jax.device_put(params, shardings)
    with mesh, sharding_ctx(mesh, rules):
        got = float(jax.jit(model.loss)(params_sh, batch))
    assert abs(got - ref) < 1e-2, (got, ref)
    # decode under sharding
    sspecs = model.decode_state_specs(8, 16)
    state = jax.device_put(init_params(key, sspecs),
                           specs_to_shardings(sspecs, mesh, rules))
    with mesh, sharding_ctx(mesh, rules):
        logits, state2 = jax.jit(model.decode_step)(
            params_sh, state, jnp.ones((8,), jnp.int32), jnp.int32(3))
    assert logits.shape == (8, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    print("MOE+DECODE OK")
    """)
    assert "MOE+DECODE OK" in out


def test_production_mesh_shapes():
    out = _run("""
    # make_production_mesh needs 512 devices; with 8 it must raise cleanly
    from repro.launch.mesh import make_production_mesh, make_debug_mesh
    try:
        make_production_mesh()
        raise AssertionError("should have raised")
    except RuntimeError as e:
        assert "512" in str(e) or "256" in str(e)
    m = make_debug_mesh(2, 4)
    assert m.axis_names == ("data", "model")
    assert m.devices.shape == (2, 4)
    print("MESH OK")
    """)
    assert "MESH OK" in out


def test_serve_batcher_on_sharded_mesh():
    """The serving stack end-to-end on 8 devices under megatron_sp: two
    dispatches through the same bucket must reuse the AOT executables
    (zero new lowerings) while producing full token streams."""
    out = _run("""
    from repro.serve import Bucket, BucketPolicy, DecodeRequest, ServeBatcher
    cfg = reduced_config("yi_6b").with_(vocab=64, n_layers=2,
                                        sharding_mode="megatron_sp")
    with mesh:
        b = ServeBatcher(cfg, mesh, policy=BucketPolicy([Bucket(64, 4)]))
        b.init_demo_params(0)
        for i in range(4):
            b.submit(DecodeRequest(f"a{i}", [1 + i, 2, 3], max_new_tokens=5))
        r1 = b.run()
        warm = dict(b.cache.stats())
        for i in range(4):
            b.submit(DecodeRequest(f"b{i}", [1 + i, 2, 3], max_new_tokens=5))
        r2 = b.run()
        after = b.cache.stats()
    assert all(len(r.tokens) == 5 for r in r1.values())
    # determinism across dispatches: same prompts -> same tokens
    for i in range(4):
        assert r1[f"a{i}"].tokens == r2[f"b{i}"].tokens
    assert after["hits"] > warm["hits"]
    assert after["lowerings"] == warm["lowerings"]
    assert after["compiles"] == warm["compiles"]
    print("SERVE BATCH OK")
    """)
    assert "SERVE BATCH OK" in out


def test_int8_compressed_psum_shard_map():
    """error_feedback_reduce inside shard_map over the data axis."""
    out = _run("""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.compression import error_feedback_reduce

    g = jax.random.normal(jax.random.PRNGKey(1), (8, 32), jnp.float32)
    res = jnp.zeros((8, 32), jnp.float32)

    @partial(shard_map, mesh=mesh, in_specs=(P("data", None), P("data", None)),
             out_specs=(P("data", None), P("data", None)))
    def reduce_fn(g, r):
        out, new_r = error_feedback_reduce(g, r, axis_name="data")
        return out, new_r

    reduced, new_res = reduce_fn(g, res)
    # every data shard sees the same mean (per model column)
    want = np.asarray(g, np.float32).reshape(2, 4, 32).mean(0)
    got = np.asarray(reduced, np.float32).reshape(2, 4, 32)
    for i in range(2):
        assert np.allclose(got[i], want, atol=0.05), np.abs(got[i]-want).max()
    print("COMPRESSED PSUM OK")
    """)
    assert "COMPRESSED PSUM OK" in out
