"""The paper's own Table III/V workloads compile and run bit-exactly."""

import numpy as np
import pytest

from repro.configs.paper_models import (
    PAPER_MODELS,
    build_paper_model,
)


@pytest.mark.parametrize("name", list(PAPER_MODELS))
def test_paper_model_compiles_and_is_bit_exact(name):
    m = build_paper_model(name, batch=16)
    rows, f_in, widths, _ = PAPER_MODELS[name]
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (16, f_in)).astype(np.float32)
    y86 = m.predict(x, "x86")
    yai = m.predict(x, "aie")
    np.testing.assert_array_equal(y86, yai)
    assert y86.shape == (16, widths[-1])
    assert m.tiles_used <= 304
