"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions, one decode step (assignment deliverable f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.dist.sharding import init_params
from repro.models import SHAPES, build_model, supports_shape
from repro.models.base import ShapeSpec

KEY = jax.random.PRNGKey(0)
SMOKE = ShapeSpec("smoke", 32, 2, "train")


def _batch(model, shape):
    ispec = model.input_specs(shape)
    out = {}
    for k, s in ispec.items():
        if s.dtype == jnp.int32 and s.ndim:
            out[k] = jnp.full(s.shape, 3, jnp.int32)
        elif s.ndim == 0:
            out[k] = jnp.int32(1)
        else:
            out[k] = jax.random.normal(KEY, s.shape, s.dtype) * 0.1
    return out


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # spot-check the assigned table rows
    table = {
        "llama_3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen1_5_4b": (40, 2560, 20, 20, 6912, 151936),
        "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152064),
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "kimi_k2_1t": (61, 7168, 64, 8, 2048, 163840),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256256),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
    }
    L, d, h, kv, ff, v = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
            cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v)


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_train_step(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = init_params(KEY, model.param_specs())
    batch = _batch(model, SMOKE)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch
    logits = model.forward(params, batch)
    assert logits.shape[-1] == cfg.vocab
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), arch


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_decode_step(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = init_params(KEY, model.param_specs())
    state = init_params(KEY, model.decode_state_specs(2, 16))
    toks = jnp.array([1, 2], jnp.int32)
    logits, state2 = model.decode_step(params, state, toks, jnp.int32(3))
    assert logits.shape == (2, cfg.vocab)
    assert jnp.isfinite(logits).all(), arch
    # state structure preserved
    assert jax.tree_util.tree_structure(state) == \
        jax.tree_util.tree_structure(state2)


@pytest.mark.parametrize("arch", list_archs())
def test_shape_support_matrix(arch):
    """long_500k only for SSM/hybrid; everything else supports all shapes."""
    cfg = get_config(arch)
    for name in SHAPES:
        ok, reason = supports_shape(cfg, name)
        if name == "long_500k":
            expect = cfg.family in ("ssm", "hybrid")
            assert ok == expect, (arch, name, reason)
        else:
            assert ok, (arch, name, reason)


def test_decoder_lm_loss_decreases_quickly():
    """Tiny decoder learns the synthetic motif structure."""
    from repro.data.pipeline import SyntheticTokens
    from repro.optim.optimizers import adamw

    cfg = reduced_config("yi_6b").with_(vocab=64, n_layers=2)
    model = build_model(cfg)
    params = init_params(KEY, model.param_specs())
    opt = adamw(lr=3e-3)
    opt_state = opt.init(params)
    ds = SyntheticTokens(vocab=64, seq_len=32, global_batch=8, seed=1)

    @jax.jit
    def step(params, opt_state, batch):
        loss, g = jax.value_and_grad(model.loss)(params, batch)
        upd, opt_state, _ = opt.update(g, opt_state, params)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, upd)
        return params, opt_state, loss

    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses[::10]
