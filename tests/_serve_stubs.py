"""Host-level stand-ins for driving the REAL continuous scheduler.

Shared by ``test_scheduler.py`` (slot/admission invariants), the policy
property suite (``test_policies.py``), and any future host-level serving
test. The scheduler under test is the production
:class:`~repro.serve.scheduler.ContinuousScheduler`; only the executable
and the state pool are faked, so every invariant checked here is a fact
about the shipped scheduling code, not about a model.

The fake executable emits token ``pos + i + 1`` on every active
lane-step, which makes result slices *positional receipts*: request r
admitted at ``start`` must receive exactly
``[start+len(prompt), ..., start+len(prompt)+n-1]`` — any slot overlap,
mis-slice, or double-completion corrupts the receipt.
"""

from __future__ import annotations

import collections
import types

import numpy as np

from repro.serve import Bucket, BucketPolicy, DecodeRequest
from repro.serve.scheduler import ContinuousScheduler


class HostExe:
    """Fake masked-decode executable: positional-receipt tokens."""

    def __init__(self):
        self.bundle = types.SimpleNamespace(in_shardings=(None,) * 8)
        self.calls = 0

    def compiled(self, params, state, feed, prev, pos, start, active,
                 fresh):
        self.calls += 1
        active = np.asarray(active)
        k, B = active.shape
        base = int(pos)
        toks = (np.arange(base + 1, base + k + 1, dtype=np.int32)[:, None]
                * active)
        return toks, toks[-1], state


class HostPlan:
    """Plan stand-in: one HostExe per (batch, max_len, k)."""

    def __init__(self):
        self.exes = {}

    def serve_executable(self, kind, *, batch, max_len,
                         steps_per_dispatch=1, **kw):
        assert kind == "masked_decode"
        key = (batch, max_len, steps_per_dispatch)
        if key not in self.exes:
            self.exes[key] = HostExe()
        return self.exes[key]


class NullPool:
    """State pool stand-in that only counts per-slot wipes."""

    def __init__(self):
        self.slot_resets = 0

    def acquire(self, batch, max_len):
        return {}

    def release(self, batch, max_len, state):
        pass

    def reset_slots(self, batch, max_len, state, slot_mask):
        self.slot_resets += 1
        return state


def make_host_scheduler(batch, max_len=64, k=1, admission=None,
                        clock=None) -> ContinuousScheduler:
    """A real scheduler over the host fakes, ready to ``run()``."""
    policy = BucketPolicy([Bucket(max_len, batch)])
    return ContinuousScheduler(HostPlan(), policy, NullPool(),
                               steps_per_dispatch=k, admission=admission,
                               clock=clock)


def expected_receipt(start, plen, n):
    first = start + plen - 1
    return list(range(first + 1, first + 1 + n))


def check_invariants(sched, reqs, results, k, canceled=(), shed=()):
    """Slot non-overlap + conservation + positional receipts + gap <= k.

    ``canceled``/``shed`` ids must complete zero times; every other
    submitted id exactly once, with exactly ``max_new_tokens`` tokens
    whose values prove which steps its slot actually held.
    """
    canceled, shed = set(canceled), set(shed)
    assert set(results) == ({r.request_id for r in reqs}
                            - canceled - shed)
    by_id = {r.request_id: r for r in reqs}
    admit_at = {}
    for e in sched.events:
        if e.kind == "admit":
            admit_at[e.request_id] = e.step
    for rid in shed:
        assert rid not in admit_at, f"shed id {rid} was admitted"
    for rid, res in results.items():
        req = by_id[rid]
        assert len(res.tokens) == req.max_new_tokens
        # positional receipt: the slot held exactly these steps
        assert res.tokens == expected_receipt(
            admit_at[rid], len(req.prompt), req.max_new_tokens), rid

    # slot non-overlap: per slot, the event stream alternates
    # admit -> (free | cancel) -> admit -> ...  ("shed" never holds one)
    occupancy = collections.defaultdict(lambda: None)
    for e in sched.events:
        if e.kind == "shed":
            continue
        if e.kind == "admit":
            assert occupancy[e.slot] is None, (
                f"slot {e.slot} double-admitted at {e.step}")
            occupancy[e.slot] = e.request_id
        else:
            assert occupancy[e.slot] == e.request_id, (
                f"slot {e.slot} freed by non-tenant at {e.step}")
            occupancy[e.slot] = None

    # refill gap bounded by the micro-run length
    if sched.refills:
        assert 1 <= sched.max_refill_gap <= k


class SpecHostExe:
    """Fake fused speculative executable: LOCAL positional receipts.

    ``verify[i, b] = (pos + i - start[i, b]) + 1`` — one past the lane's
    local cursor, so a step's value depends only on how many tokens the
    slot has actually consumed, never on which micro-run replayed it.
    The scheduler's rollback bumps ``slot.start`` by exactly the
    rejected count, so the committed stream for a request with prompt
    length P must be exactly ``[P, P+1, ..., P+n-1]`` no matter how many
    drafts were rejected, requeued, or replayed along the way: the
    accept-prefix law as an arithmetic identity on receipts.

    ``drafts`` mirrors ``verify`` except where the lane's local cursor
    sits in ``mismatch`` — those steps propose a wrong token, forcing
    the host to roll back every later step of that micro-run.
    """

    def __init__(self, mismatch=frozenset()):
        self.bundle = types.SimpleNamespace(in_shardings=(None,) * 8)
        self.calls = 0
        self.mismatch = frozenset(mismatch)

    def compiled(self, params, state, feed, prev, pos, start, active,
                 fresh):
        self.calls += 1
        active = np.asarray(active)
        start = np.asarray(start)
        k, B = active.shape
        local = (int(pos) + np.arange(k, dtype=np.int32)[:, None]
                 - start)                       # [k, B] local cursor
        verify = ((local + 1) * active).astype(np.int32)
        drafts = verify.copy()
        if self.mismatch:
            bad = np.isin(local, list(self.mismatch)) & active
            drafts[bad] += 997                  # draft disagrees here
        return verify, drafts, state


class SpecHostPlan:
    """Plan stand-in: one SpecHostExe per (batch, max_len, k, spec)."""

    def __init__(self, mismatch=frozenset()):
        self.exes = {}
        self.mismatch = frozenset(mismatch)

    def serve_executable(self, kind, *, batch, max_len,
                         steps_per_dispatch=1, spec=None, **kw):
        assert kind == "masked_decode" and spec is not None
        key = (batch, max_len, steps_per_dispatch, spec)
        if key not in self.exes:
            self.exes[key] = SpecHostExe(self.mismatch)
        return self.exes[key]


class PagedSpecHostExe(SpecHostExe):
    """SpecHostExe with the paged 9th input (the page table).

    Receipts stay LOCAL — page indirection must be invisible to the
    committed stream — but every active step's write position has to be
    covered by the table the scheduler built (committed run + draft
    lease), which is exactly the contract ``draft_lease`` exists for.
    """

    def __init__(self, mismatch=frozenset(), page_size=4):
        super().__init__(mismatch)
        self.bundle = types.SimpleNamespace(in_shardings=(None,) * 9)
        self.page_size = page_size

    def compiled(self, params, state, feed, prev, pos, start, active,
                 fresh, table):
        table = np.asarray(table)
        act = np.asarray(active)
        k, B = act.shape
        assert table.shape[0] == B and table.dtype == np.int32
        local = (int(pos) + np.arange(k, dtype=np.int32)[:, None]
                 - np.asarray(start))
        for i in range(k):
            for b in range(B):
                if act[i, b]:
                    assert 0 <= local[i, b] // self.page_size \
                        < table.shape[1], (i, b, local[i, b])
        return super().compiled(params, state, feed, prev, pos, start,
                                active, fresh)


class PagedSpecHostPlan(SpecHostPlan):
    """Plan stand-in for speculative x paged micro-runs."""

    def serve_executable(self, kind, *, batch, max_len,
                         steps_per_dispatch=1, spec=None, paged=None,
                         **kw):
        assert kind == "masked_decode"
        assert spec is not None and paged is not None
        key = (batch, max_len, steps_per_dispatch, spec, paged)
        if key not in self.exes:
            self.exes[key] = PagedSpecHostExe(self.mismatch, paged[1])
        return self.exes[key]


class PagedNullPool(NullPool):
    """NullPool plus a REAL PageAllocator: the scheduler's paged branch
    (lazy admission, draft leases, boundary resolution, publish/release,
    page-table builds) runs against real host bookkeeping while the
    device state stays fake."""

    def __init__(self, page_count, page_size):
        super().__init__()
        from repro.serve.paging import PageAllocator

        self.paged = (page_count, page_size)
        self.allocator = PageAllocator(page_count, page_size)


def check_page_invariants(alloc, slots) -> None:
    """Boundary-time page conservation over the live slots' leases."""
    assert alloc.pages_free + alloc.pages_in_use == alloc.page_count
    cached = set(alloc._prefix.values())
    writable = []
    for s in slots:
        if s is None or s.pages is None:
            continue
        for i, p in enumerate(s.pages.pages):
            assert p in alloc._refs, p
            if i >= s.pages.shared and i >= s.pages.published:
                writable.append(p)
        writable.extend(s.pages.draft)
    # one writer per page, and shared (cached) pages never draft-writable
    assert len(writable) == len(set(writable)), writable
    assert cached.isdisjoint(writable)


def run_paged_spec_host_trace(lengths, k, batch, max_len=64, page_size=4,
                              page_count=None, mismatch=(),
                              cancel_at=None, reqs=None):
    """Drive the real scheduler in SPECULATIVE x PAGED mode over the
    host fakes (real PageAllocator, fake executable/state).

    Page invariants are checked at EVERY micro-run boundary through the
    ``on_boundary`` hook, and page conservation is asserted after the
    drain: whatever mix of accepts, rollbacks, continuation requeues,
    and cancels the trace produced, only scratch and prefix-cache pages
    may remain in use. Returns ``(sched, reqs, results, canceled)``.
    """
    policy = BucketPolicy([Bucket(max_len, batch)])
    if page_count is None:
        # enough to fully back every lane plus the spec draft headroom
        page_count = (batch * (max_len // page_size) + batch
                      + (-(-k // page_size) + 1))
    pool = PagedNullPool(page_count, page_size)
    sched = ContinuousScheduler(PagedSpecHostPlan(mismatch), policy,
                                pool, steps_per_dispatch=k, spec=(k, 1))
    if reqs is None:
        reqs = [DecodeRequest(
            f"s{i}", [1 + (i + j) % 7 for j in range(plen)],
            max_new_tokens=n)
            for i, (plen, n) in enumerate(lengths)]
    canceled = []
    cancel_state = {"rid": None}
    if cancel_at is not None:
        boundary, idx = cancel_at
        cancel_state["rid"] = reqs[idx % len(reqs)].request_id
        cancel_state["boundary"] = boundary

    def hook(pos, slots):
        rid = cancel_state["rid"]
        if rid is not None and pos >= cancel_state["boundary"] and \
                rid not in canceled and any(
                    s is not None and s.req.request_id == rid
                    for s in slots):
            sched.cancel(rid)
            canceled.append(rid)
        check_page_invariants(pool.allocator, slots)

    sched.on_boundary = hook
    pending = collections.deque(reqs)
    results = sched.run(pending, None, {})
    alloc = pool.allocator
    assert alloc.pages_in_use == len(alloc._scratch) + len(alloc._prefix)
    return sched, reqs, results, canceled


def spec_expected_receipt(plen, n):
    """Local receipts: token j of a prompt-P request is P + j."""
    return list(range(plen, plen + n))


def run_spec_host_trace(lengths, k, batch, max_len=64, mismatch=(),
                        cancel_at=None, reqs=None):
    """Drive the real scheduler in SPECULATIVE mode over the host fakes.

    ``mismatch`` is a set of local cursor positions where the fake draft
    proposes a wrong token (forcing a rollback of everything after it in
    that micro-run). Returns ``(sched, reqs, results, canceled)``.
    """
    policy = BucketPolicy([Bucket(max_len, batch)])
    sched = ContinuousScheduler(SpecHostPlan(mismatch), policy,
                                NullPool(), steps_per_dispatch=k,
                                spec=(k, 1))
    if reqs is None:
        reqs = [DecodeRequest(
            f"s{i}", [1 + (i + j) % 7 for j in range(plen)],
            max_new_tokens=n)
            for i, (plen, n) in enumerate(lengths)]
    canceled = []
    if cancel_at is not None:
        boundary, idx = cancel_at
        rid = reqs[idx % len(reqs)].request_id

        def hook(pos, slots):
            if pos >= boundary and rid not in canceled and any(
                    s is not None and s.req.request_id == rid
                    for s in slots):
                sched.cancel(rid)
                canceled.append(rid)

        sched.on_boundary = hook
    pending = collections.deque(reqs)
    results = sched.run(pending, None, {})
    return sched, reqs, results, canceled


def check_spec_invariants(sched, reqs, results, canceled=()):
    """Conservation + local receipts + no leaked carry, spec mode.

    Every non-canceled id completes exactly once with EXACTLY its
    ``max_new_tokens`` receipts ``[P, ..., P+n-1]`` — rollbacks and
    continuation requeues may stretch the schedule but can never change,
    duplicate, or drop a committed token — and the continuation carry
    map must be empty once ``run()`` returns.
    """
    canceled = set(canceled)
    assert set(results) == {r.request_id for r in reqs} - canceled
    by_id = {r.request_id: r for r in reqs}
    for rid, res in results.items():
        req = by_id[rid]
        exp = spec_expected_receipt(len(req.prompt), req.max_new_tokens)
        if sched.spec_partial_results:
            # a continuation outgrew every bucket: the committed prefix
            # is delivered as-is — still exact, still non-empty
            assert res.tokens and res.tokens == exp[:len(res.tokens)], rid
        else:
            assert res.tokens == exp, rid
    assert sched._spec_carry == {}, "continuation carry leaked past run()"


def run_host_trace(lengths, k, batch, max_len=64, cancel_at=None,
                   admission=None, reqs=None):
    """Drive the real scheduler over the host fakes; returns
    ``(sched, reqs, results, canceled)``.

    ``lengths`` is a list of ``(prompt_len, max_new_tokens)`` pairs used
    to synthesize requests ``h0, h1, ...`` — or pass ``reqs`` to supply
    your own (priorities, tenants, deadlines). ``cancel_at=(boundary,
    idx)`` cancels the idx-th request from the ``on_boundary`` hook at
    the first boundary >= ``boundary`` where it is in flight.
    """
    sched = make_host_scheduler(batch, max_len=max_len, k=k,
                                admission=admission)
    if reqs is None:
        reqs = [DecodeRequest(
            f"h{i}", [1 + (i + j) % 7 for j in range(plen)],
            max_new_tokens=n)
            for i, (plen, n) in enumerate(lengths)]
    canceled = []
    if cancel_at is not None:
        boundary, idx = cancel_at
        rid = reqs[idx % len(reqs)].request_id

        def hook(pos, slots):
            if pos >= boundary and rid not in canceled and any(
                    s is not None and s.req.request_id == rid
                    for s in slots):
                sched.cancel(rid)
                canceled.append(rid)

        sched.on_boundary = hook
    pending = collections.deque(reqs)
    results = sched.run(pending, None, {})
    return sched, reqs, results, canceled
