"""Step builders (launch/steps.py): lower+compile on a 1-device debug mesh
for every step kind and sharding mode — the single-device analogue of the
512-device dry-run, executed in-process."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import (
    make_prefill_decode_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.base import ShapeSpec

SMOKE_TRAIN = ShapeSpec("t", 32, 4, "train")
SMOKE_PREFILL = ShapeSpec("p", 32, 4, "prefill")
SMOKE_DECODE = ShapeSpec("d", 32, 4, "decode")


@pytest.mark.parametrize("mode", ["cascade", "megatron", "megatron_sp"])
def test_train_step_lowers_all_modes(mode):
    cfg = reduced_config("yi_6b").with_(n_layers=2, vocab=64)
    mesh = make_debug_mesh(1, 1)
    bundle = make_train_step(cfg, SMOKE_TRAIN, mesh, mode)
    compiled = bundle.lower().compile()
    assert compiled.cost_analysis() is not None


def test_train_step_microbatched_lowers():
    cfg = reduced_config("yi_6b").with_(n_layers=2, vocab=64, microbatches=2)
    mesh = make_debug_mesh(1, 1)
    compiled = make_train_step(cfg, SMOKE_TRAIN, mesh).lower().compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0


@pytest.mark.parametrize("arch", ["phi3_5_moe_42b", "zamba2_2_7b",
                                  "rwkv6_7b", "seamless_m4t_large_v2",
                                  "llama_3_2_vision_90b"])
def test_prefill_and_serve_lower_per_family(arch):
    cfg = reduced_config(arch)
    mesh = make_debug_mesh(1, 1)
    make_prefill_step(cfg, SMOKE_PREFILL, mesh).lower().compile()
    make_serve_step(cfg, SMOKE_DECODE, mesh).lower().compile()


@pytest.mark.parametrize("arch", ["yi_6b", "zamba2_2_7b", "rwkv6_7b"])
def test_prefill_decode_step_lowers_per_family(arch):
    cfg = reduced_config(arch).with_(vocab=64)
    mesh = make_debug_mesh(1, 1)
    bundle = make_prefill_decode_step(cfg, batch=2, prefill_len=8,
                                      max_len=32, mesh=mesh)
    assert bundle.lower().compile().cost_analysis() is not None


def test_quantized_serve_step_lowers():
    """cfg.quantized routes the decode LM head through the qmatmul kernel
    and must still lower/compile AOT like the float path."""
    cfg = reduced_config("yi_6b").with_(n_layers=2, vocab=64, quantized=True)
    mesh = make_debug_mesh(1, 1)
    make_serve_step(cfg, SMOKE_DECODE, mesh).lower().compile()


def test_train_step_executes_and_updates_params():
    """Compile AND run one step end-to-end through the bundle."""
    from repro.dist.sharding import abstract_params, init_params
    from repro.models import build_model

    cfg = reduced_config("yi_6b").with_(n_layers=2, vocab=64)
    mesh = make_debug_mesh(1, 1)
    bundle = make_train_step(cfg, SMOKE_TRAIN, mesh)
    compiled = bundle.lower().compile()
    model = build_model(cfg)
    from repro.optim.optimizers import make_optimizer

    params = init_params(jax.random.PRNGKey(0), model.param_specs())
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    batch = {"tokens": jnp.ones((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    p0 = np.asarray(jax.tree.leaves(params)[0], np.float32).copy()
    new_params, new_opt, metrics = compiled(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    p1 = np.asarray(jax.tree.leaves(new_params)[0], np.float32)
    assert not np.array_equal(p0, p1)  # the optimizer moved something
