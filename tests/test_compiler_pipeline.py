"""End-to-end AIE4ML compiler pipeline: passes, packing, bit-exactness."""

import numpy as np
import pytest

from repro.core import (
    AIEMLDevice,
    CompileConfig,
    DenseSpec,
    OpKind,
    build_mlp_graph,
    compile_graph,
    run_passes,
)
from repro.core.packing import pack_dense_weight, tile_interleave

RNG = np.random.default_rng(7)


def _mlp(batch=16, f_in=48, widths=(64, 32, 10), seed=3):
    layers = []
    for i, w in enumerate(widths):
        layers.append(DenseSpec(
            w,
            bias=RNG.standard_normal(w) * 0.1,
            activation="relu" if i + 1 < len(widths) else None,
        ))
    return build_mlp_graph(batch=batch, f_in=f_in, layers=list(layers),
                           seed=seed)


def test_lower_fuses_dense_relu():
    g = _mlp()
    run_passes(g, CompileConfig())
    denses = g.compute_nodes()
    assert all(n.op != OpKind.RELU for n in g)
    assert denses[0].params.get("relu") is True
    assert denses[-1].params.get("relu") is not True


def test_quantize_pass_populates_chain():
    g = _mlp()
    run_passes(g, CompileConfig())
    prev_shift = g.inputs()[0].quant["shift"]
    for n in g.compute_nodes():
        q = n.quant
        assert q["in_shift"] == prev_shift
        assert q["srs_shift"] == q["in_shift"] + q["w_shift"] - q["out_shift"]
        assert q["srs_shift"] >= 0
        assert q["weight_q"].dtype == np.int8
        prev_shift = q["out_shift"]


def test_resolve_and_place_fit_device():
    g = _mlp()
    run_passes(g, CompileConfig())
    dev = g.meta["device"]
    assert g.meta["tiles_used"] <= dev.n_tiles
    for n in g.compute_nodes():
        c = n.cascade
        assert c.cas_len * c.f_in_slice >= \
            g.predecessors(n.name)[0].out_spec.features
        assert c.cas_num * c.f_out_slice >= n.out_spec.features
        p = n.place
        assert 0 <= p.col and p.col + p.width <= dev.n_cols
        assert 0 <= p.row and p.row + p.height <= dev.n_rows


def test_packing_roundtrip():
    """Packed tile stream reconstructs the padded weight exactly."""
    w = RNG.integers(-128, 128, (50, 70)).astype(np.int8)
    out = pack_dense_weight(w, cas_len=2, cas_num=3, f_in_slice=32,
                            f_out_slice=24, K=8, N=8)
    packed, padded = out["packed"], out["padded"]
    # reconstruct
    rec = np.zeros_like(padded)
    kt, nt = 32 // 8, 24 // 8
    for r in range(3):
        for c in range(2):
            slice_ = packed[r, c]  # [kt, nt, K, N]
            flat = slice_.transpose(0, 2, 1, 3).reshape(32, 24)
            rec[c * 32:(c + 1) * 32, r * 24:(r + 1) * 24] = flat
    np.testing.assert_array_equal(rec, padded)
    np.testing.assert_array_equal(padded[:50, :70], w)
    assert (padded[50:, :] == 0).all() and (padded[:, 70:] == 0).all()


def test_tile_interleave_layout():
    w = np.arange(32).reshape(8, 4).astype(np.int8)
    t = tile_interleave(w, 4, 2)  # [2, 2, 4, 2]
    np.testing.assert_array_equal(t[0, 0], w[:4, :2])
    np.testing.assert_array_equal(t[1, 1], w[4:, 2:])


def test_memtile_edges_and_retiling():
    g = _mlp()
    run_passes(g, CompileConfig())
    edges = g.memtile_edges
    assert len(edges) == 3  # dense0->dense1, dense1->dense2, dense2->output
    e01 = [e for e in edges if e.src == "dense_0" and e.dst == "dense_1"][0]
    # writer emits (M, N) tiles; reader consumes (M, K) tiles — re-tiling
    assert e01.write_tiling[1] == g["dense_0"].tile["N"]
    assert e01.read_tiling[1] == g["dense_1"].tile["K"]
    assert e01.double_buffered
    assert g.meta["memtile_bytes"] <= \
        g.meta["device"].n_memtiles * g.meta["device"].memtile_bytes


def test_x86_aie_bit_exact_and_float_close():
    g = _mlp()
    x = RNG.uniform(-1, 1, (16, 48)).astype(np.float32)
    m = compile_graph(g, CompileConfig(calib=x))
    y_x86 = m.predict(x, mode="x86")
    y_aie = m.predict(x, mode="aie")
    np.testing.assert_array_equal(y_x86, y_aie)
    # against float reference
    h = x
    for n in g.compute_nodes():
        h = h @ n.params["weight"]
        if "bias" in n.params:
            h = h + n.params["bias"]
        if n.params.get("relu"):
            h = np.maximum(h, 0)
    rel = np.abs(h - y_x86).max() / (np.abs(h).max() + 1e-9)
    assert rel < 0.06


def test_user_overrides_honored():
    g = _mlp()
    g["dense_1"].overrides.update({"cas_len": 2, "cas_num": 2,
                                   "place": (10, 3)})
    run_passes(g, CompileConfig())
    n = g["dense_1"]
    assert n.cascade.cas_len == 2 and n.cascade.cas_num == 2
    assert (n.place.col, n.place.row) == (10, 3)


def test_mixed_precision_per_layer():
    g = _mlp()
    g["dense_1"].overrides["w_dtype"] = "int8"
    g["dense_0"].overrides["a_dtype"] = "int16"  # dense_0 emits int16
    run_passes(g, CompileConfig())
    assert g["dense_0"].quant["a_dtype"] == "int16"
    # dense_1 consumes int16 activations with int8 weights => <4,4,8> tiling
    assert (g["dense_1"].tile["M"], g["dense_1"].tile["K"],
            g["dense_1"].tile["N"]) == (4, 4, 8)


def test_analytic_ceilings_match_paper_table1():
    dev = AIEMLDevice()
    assert dev.peak_gops("int8", "int8") == pytest.approx(640.0)
    assert dev.peak_gops("int16", "int8") == pytest.approx(320.0)
    assert dev.peak_gops("int16", "int16") == pytest.approx(160.0)


from conftest import hypothesis_or_skip_stub  # noqa: E402

given, settings, st = hypothesis_or_skip_stub()


@given(
    batch=st.integers(1, 32),
    f_in=st.integers(1, 96),
    widths=st.lists(st.integers(1, 96), min_size=1, max_size=4),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=10, deadline=None)
def test_property_pipeline_bit_exact_any_mlp(batch, f_in, widths, seed):
    """System invariant: ANY mlp (ragged dims, any depth) compiles through
    the full pipeline and the two simulation modes are bit-exact."""
    rng = np.random.default_rng(seed)
    layers = [DenseSpec(w, activation="relu" if i % 2 == 0 else None,
                        bias=rng.standard_normal(w) * 0.1)
              for i, w in enumerate(widths)]
    g = build_mlp_graph(batch=batch, f_in=f_in, layers=layers, seed=seed)
    x = rng.uniform(-1, 1, (batch, f_in)).astype(np.float32)
    m = compile_graph(g, CompileConfig(calib=x))
    np.testing.assert_array_equal(m.predict(x, "x86"), m.predict(x, "aie"))
    # every placement legal, every memtile edge within capacity
    dev = g.meta["device"]
    assert g.meta["tiles_used"] <= dev.n_tiles
    assert g.meta["memtile_bytes"] <= dev.n_memtiles * dev.memtile_bytes


def test_oversized_model_raises():
    layers = [DenseSpec(8192, activation="relu") for _ in range(8)]
    g = build_mlp_graph(batch=128, f_in=8192, layers=layers)
    with pytest.raises(ValueError):
        run_passes(g, CompileConfig())
