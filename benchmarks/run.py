"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run as:
    PYTHONPATH=src python -m benchmarks.run [--only tableX]
"""

import argparse
import sys
import traceback

from benchmarks import (
    fig3_placement,
    fig4_scaling,
    roofline_table,
    serve_latency,
    table1_ceilings,
    table2_single_kernel,
    table3_models,
    table4_frameworks,
    table5_cross_device,
)

MODULES = [
    ("table1", table1_ceilings),
    ("table2", table2_single_kernel),
    ("fig3", fig3_placement),
    ("fig4", fig4_scaling),
    ("table3", table3_models),
    ("table4", table4_frameworks),
    ("table5", table5_cross_device),
    ("roofline", roofline_table),
    ("serve", serve_latency),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single benchmark group (e.g. table2)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.3f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.000,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
