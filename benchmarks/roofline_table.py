"""Assignment roofline table: read the dry-run sweep JSONs and emit the
per-(arch x shape x mesh x mode) roofline rows (EXPERIMENTS.md §Roofline)."""

import glob
import json
import os

RESULT_DIRS = [
    "results/sweep_sp_cascade",
    "results/sweep_sp_megatron",
    "results/sweep_mp_megatron",
    "results/sweep_sp_optimized",
]


def load_records(dirs=None):
    records = []
    for d in dirs or RESULT_DIRS:
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            records.extend(json.load(open(f)))
    return records


def run():
    rows = []
    records = load_records()
    if not records:
        return [{
            "name": "roofline_table",
            "us_per_call": 0.0,
            "derived": "no sweep results found; run scripts/sweep.sh first",
        }]
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    rows.append({
        "name": "dryrun_sweep_status",
        "us_per_call": 0.0,
        "derived": f"ok={n_ok} skipped={n_skip} errors={n_err} "
                   f"(every non-skip cell compiled on 16x16 and 2x16x16)",
    })
    for r in records:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        step = ro["step_time_bound_s"]
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}_{r['mode']}",
            "us_per_call": step * 1e6,
            "derived": (
                f"dom={ro['dominant']} C={ro['compute_s']:.3g}s "
                f"M={ro['memory_s']:.3g}s N={ro['collective_s']:.3g}s "
                f"useful={ro['useful_flops_ratio']*100:.1f}% "
                f"MFU_bound={ro['roofline_mfu']*100:.2f}%"
            ),
        })
    return rows
