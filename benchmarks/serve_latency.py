"""Warm-cache serving latency/throughput per bucket (debug mesh).

Dispatches request waves through ``repro.serve.ServeBatcher`` on the
1x1 debug mesh, drops the cold wave (compiles), and reports per-bucket
warm tokens/sec plus p50/p99 dispatch latency. Run standalone to emit
``BENCH_serve.json`` so future PRs have a perf trajectory to diff:

    PYTHONPATH=src python -m benchmarks.serve_latency [--out BENCH_serve.json]

The ``churn`` section races the schedulers on an identical mixed-length
request trace (every eighth request rides 14x longer than its
neighbours — the worst case for fixed FIFO groups, whose short requests
idle their slots until the long rider finishes): warm tokens/sec for
``schedule="fifo"`` vs ``schedule="continuous"`` at ``steps_per_dispatch``
(micro-run length) k in {1, 4, 8}, the speedup ratios, busy-slot
fractions, and p50/p99 per-slot idle time. ``k_sweep`` summarizes
tokens/s per k; ``speedup_k4_vs_k1`` is the micro-run amortization
headline (CI asserts k=4 >= k=1).

The ``paged`` section races the dense continuous scheduler against the
paged KV cache (``paged=True``) on one shared-prefix trace — every
request opens with the same 16-token system prompt — and reports the
memory headline: **concurrent requests per HBM byte**, i.e. dense slab
bytes over the paged pool's peak page footprint for the same live mix
(CI asserts the ratio >= 1), plus the prefill-skip rate from prefix
reuse (CI asserts > 0), paged-vs-dense tokens/sec, and zero
post-warmup lowerings. Paged token streams are asserted identical per
request id to the dense FIFO ground truth — paging runs every request
at local positions 0..n exactly like a fresh fifo slot, so it is a
memory-layout change, not a model change (see docs/memory_model.md).

The ``speculative`` section races speculative lanes (a 1-layer draft
prefix proposes k=8 tokens per dispatch, the 4-layer target verifies
them in one fused teacher-forced pass) against plain continuous decode
at the SAME ``steps_per_dispatch`` — the matching k-sweep point — on
params doctored so every post-draft block is a residual no-op (zero
attention out-projection and FFN down-projection). Near-perfect draft
agreement isolates the headline: **accepted tokens per dispatch** (CI
asserts > 1, i.e. the draft actually amortizes dispatches) and spec
tok/s >= baseline tok/s at zero post-warmup lowerings. Token COUNTS are
asserted equal; bit-exact per-request stream parity with plain decode
lives in ``tests/test_speculative.py`` on curated gap-robust traces.

The ``traffic`` section replays ONE seeded Poisson trace (heavy-tailed
lengths, priority classes, per-request deadlines — ``repro.serve.
traffic``) through each admission policy in **virtual time**: arrivals
are injected at micro-run boundaries with the scheduler's own step
counter as the clock, so TTFT and goodput-under-deadline (fraction of
all arrivals whose last token lands before their deadline) are
bit-deterministic and CI-gateable. Every policy replays the trace twice
— dense slabs and the shared page pool (half the arrivals open with the
trace's one-page system prompt) — and CI asserts each paged replay hits
the prefix cache AND loses no goodput (prefix hits skip prefill steps,
so shared-prefix requests finish earlier in virtual time; on this
overloaded trace the paged goodput gain is the memory-model paying rent
on the latency axis too). The headline is ``goodput_edf_minus_fifo``
(CI asserts >= 0: shedding already-expired requests and running the
tightest deadline first must not lose to arrival order under the same
overload). An ``async`` subsection replays
a second trace with abandonment through the real
:class:`~repro.serve.server.AsyncServeServer` in scaled wall-clock time
and records client-side p50/p99 TTFT and outcome counts.

Also exposes ``run()`` rows for the ``benchmarks.run`` CSV harness.
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import time

from repro.configs import reduced_config
from repro.plan import MeshSpec, build_plan
from repro.serve import (
    Bucket,
    BucketPolicy,
    DecodeRequest,
    TrafficSpec,
    generate_traffic,
    make_policy,
)
from repro.serve.batcher import quantile
from repro.serve.traffic import summarize

WAVES = 4          # warm waves measured (one cold wave discarded)
TOKENS = 8         # generated per request
ARCH = "yi_6b"

# churn trace: one long rider per eight requests, interleaved, so every
# FIFO group of 8 idles seven slots behind the rider
CHURN_BATCH = 8
CHURN_MAX_LEN = 64
CHURN_PATTERN = (28, 2, 2, 2, 2, 2, 2, 2)   # max_new_tokens mod 8
CHURN_REQUESTS = 24                # per wave


def churn_requests(tag: str, n: int = CHURN_REQUESTS):
    reqs = []
    for i in range(n):
        plen = 2 + (i % 3)
        reqs.append(DecodeRequest(
            f"{tag}-{i}", [1 + (i + j) % 7 for j in range(plen)],
            max_new_tokens=CHURN_PATTERN[i % len(CHURN_PATTERN)]))
    return reqs


def _sched_counters(s) -> dict:
    return {
        "dispatches": s.dispatches, "micro_runs": s.micro_runs,
        "steps": s.steps,
        "admissions": s.admissions, "slot_steps": s.slot_steps,
        "idle_slot_steps": s.idle_slot_steps, "refills": s.refills,
        "refill_gap_total": s.refill_gap_total,
    }


# (label, schedule, steps_per_dispatch): "continuous" stays the k=1
# entry so the fifo-vs-continuous speedup remains diffable across PRs
CHURN_CONFIGS = (
    ("fifo", "fifo", 1),
    ("continuous", "continuous", 1),
    ("continuous_k4", "continuous", 4),
    ("continuous_k8", "continuous", 8),
)


def measure_churn(waves: int = 3) -> dict:
    """Race fifo vs continuous micro-runs on one mixed-length trace."""
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    policy = BucketPolicy([Bucket(CHURN_MAX_LEN, CHURN_BATCH)])
    out = {}
    tokens_ref = None
    for label, schedule, k in CHURN_CONFIGS:
        plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
        with plan.activate():
            b = plan.make_batcher(policy=policy, schedule=schedule,
                                  steps_per_dispatch=k)
            b.init_demo_params(seed=0)
            for r in churn_requests("cold"):
                b.submit(r)
            b.run()                        # compile + warm the bucket
            b.metrics = {}                 # keep warm-path numbers only
            warm_cache = dict(b.cache.stats())
            cold_sched = (_sched_counters(b.scheduler)
                          if b.scheduler is not None else None)
            t0 = time.perf_counter()
            tokens = 0
            for w in range(waves):
                for r in churn_requests(f"warm{w}"):
                    b.submit(r)
                res = b.run()
                tokens += sum(len(r.tokens) for r in res.values())
            dt = time.perf_counter() - t0
        after = b.cache.stats()
        m = b.stats()["buckets"][policy.buckets[0].label]
        steps = m["slot_steps"] / CHURN_BATCH
        sec_per_step = dt / steps if steps else 0.0
        entry = {
            "schedule": schedule,
            "steps_per_dispatch": k,
            "tokens": tokens,
            "seconds": round(dt, 4),
            "tokens_per_second": round(tokens / dt, 2) if dt else 0.0,
            "busy_slot_fraction": m["busy_slot_fraction"],
            "p50_slot_idle_s": round(
                m["p50_slot_idle_steps"] * sec_per_step, 5),
            "p99_slot_idle_s": round(
                m["p99_slot_idle_steps"] * sec_per_step, 5),
            "new_lowerings_after_warmup":
                after["lowerings"] - warm_cache["lowerings"],
        }
        if b.scheduler is not None:
            # warm-only, like every sibling field: subtract the discarded
            # cold wave's counters before deriving the ratios
            warm = {k: v - cold_sched[k]
                    for k, v in _sched_counters(b.scheduler).items()}
            warm["busy_slot_fraction"] = round(
                1 - warm["idle_slot_steps"] / warm["slot_steps"], 4) \
                if warm["slot_steps"] else 0.0
            warm["mean_refill_gap"] = round(
                warm.pop("refill_gap_total") / warm["refills"], 3) \
                if warm["refills"] else 0.0
            entry["scheduler"] = warm
        out[label] = entry
        if tokens_ref is None:
            tokens_ref = tokens
        else:
            assert tokens == tokens_ref, (
                "schedulers generated different token counts for the "
                f"same trace: {tokens} vs {tokens_ref}")

    def ratio(a, b):
        return round(a / b, 3) if b else 0.0

    out["speedup"] = ratio(out["continuous"]["tokens_per_second"],
                           out["fifo"]["tokens_per_second"])
    out["k_sweep"] = {
        str(k): out[label]["tokens_per_second"]
        for label, schedule, k in CHURN_CONFIGS if schedule == "continuous"
    }
    out["speedup_k4_vs_k1"] = ratio(
        out["continuous_k4"]["tokens_per_second"],
        out["continuous"]["tokens_per_second"])
    out["speedup_k8_vs_k1"] = ratio(
        out["continuous_k8"]["tokens_per_second"],
        out["continuous"]["tokens_per_second"])
    return out


# paged section: every request opens with the same one-page system
# prompt, so prefix reuse kicks in from the second admission on; the
# tails diverge so the first private page is a genuine COW fork
PAGED_SYSTEM = tuple(((7 * j) % 50) + 1 for j in range(16))
PAGED_REQUESTS = 16                 # per wave
PAGED_K = 4                         # steps_per_dispatch for both racers


def paged_requests(tag: str, n: int = PAGED_REQUESTS):
    # tail values spread across the vocab so every decode step's top-2
    # logit gap clears float rounding noise (paged RoPE runs at LOCAL
    # positions — equal scores, not bitwise-equal floats), keeping the
    # dense-vs-paged token assert tie-free like the scheduler tests
    reqs = []
    for i in range(n):
        tail = [2 + (11 * i + 17 * j) % 50 for j in range(2 + i % 3)]
        reqs.append(DecodeRequest(f"{tag}-{i}", list(PAGED_SYSTEM) + tail,
                                  max_new_tokens=8))
    return reqs


def _kv_slab_bytes(model, batch: int, max_len: int) -> tuple:
    """(dense KV slab bytes for one bucket, bytes of ONE page)."""
    import numpy as np

    from repro.models.base import PAGED_STATE_KEYS, paged_state_specs

    def nbytes(spec):
        n = 1
        for d in spec.shape:
            n *= d
        return n * np.dtype(spec.dtype).itemsize

    sspecs = model.decode_state_specs(batch, max_len)
    page_size = 16
    one_page = paged_state_specs(sspecs, 1, page_size)
    dense = sum(nbytes(s) for k, s in sspecs.items()
                if k in PAGED_STATE_KEYS)
    page = sum(nbytes(s) for k, s in one_page.items()
               if k in PAGED_STATE_KEYS)
    return dense, page


# (label, batcher kwargs): fifo is the dense GROUND TRUTH — paged runs
# every request at local positions 0..n exactly like a fresh fifo slot,
# so its tokens must match fifo bit-for-bit even on tie-prone prompts;
# dense continuous evaluates RoPE at offset absolute positions (equal
# scores, different floats), so it only gets the count-parity gate here
# and keeps its exact-parity gate on the curated scheduler-test traces.
PAGED_CONFIGS = (
    ("fifo", {}),
    ("dense", dict(schedule="continuous", steps_per_dispatch=PAGED_K)),
    ("paged", dict(schedule="continuous", steps_per_dispatch=PAGED_K,
                   paged=True)),
)


def measure_paged(waves: int = 3) -> dict:
    """Race fifo / dense-continuous / paged on one shared-prefix trace."""
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    policy = BucketPolicy([Bucket(CHURN_MAX_LEN, CHURN_BATCH)])
    out = {}
    token_traces = {}
    for label, kw in PAGED_CONFIGS:
        plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
        with plan.activate():
            b = plan.make_batcher(policy=policy, **kw)
            b.init_demo_params(seed=0)
            trace = {}
            for r in paged_requests("cold"):
                b.submit(r)
            trace.update({rid: r.tokens
                          for rid, r in b.run().items()})
            warm_cache = dict(b.cache.stats())
            b.metrics = {}
            t0 = time.perf_counter()
            tokens = 0
            for w in range(waves):
                for r in paged_requests(f"warm{w}"):
                    b.submit(r)
                res = b.run()
                tokens += sum(len(r.tokens) for r in res.values())
                trace.update({rid: r.tokens for rid, r in res.items()})
            dt = time.perf_counter() - t0
        after = b.cache.stats()
        token_traces[label] = trace
        dense_bytes, page_bytes = _kv_slab_bytes(
            b.model, CHURN_BATCH, CHURN_MAX_LEN)
        entry = {
            "tokens": tokens,
            "seconds": round(dt, 4),
            "tokens_per_second": round(tokens / dt, 2) if dt else 0.0,
            "new_lowerings_after_warmup":
                after["lowerings"] - warm_cache["lowerings"],
            "dense_kv_slab_bytes": dense_bytes,
        }
        if label == "paged":
            p = b.stats()["paged"]
            entry["allocator"] = p
            entry["page_bytes"] = page_bytes
            # the pool bytes this mix ever actually touched — the paged
            # analogue of the dense slab (scratch pages included)
            entry["peak_kv_bytes"] = p["peak_pages"] * page_bytes
            entry["requests_per_kv_gib"] = round(
                CHURN_BATCH * 2**30 / entry["peak_kv_bytes"], 2)
        else:
            entry["peak_kv_bytes"] = dense_bytes
            entry["requests_per_kv_gib"] = round(
                CHURN_BATCH * 2**30 / dense_bytes, 2)
        out[label] = entry
    assert token_traces["paged"] == token_traces["fifo"], (
        "paged tokens diverged from the dense fifo ground truth: paging "
        "must be a pure memory-layout change (see docs/memory_model.md)")
    counts = {lbl: sorted((rid, len(t)) for rid, t in tr.items())
              for lbl, tr in token_traces.items()}
    assert counts["dense"] == counts["fifo"], (
        "dense continuous generated a different token count than fifo "
        "on the same trace")
    out["tokens_match"] = True
    out["speedup_paged_vs_dense"] = round(
        out["paged"]["tokens_per_second"]
        / out["dense"]["tokens_per_second"], 3) \
        if out["dense"]["tokens_per_second"] else 0.0
    # headline: concurrent requests per HBM byte, paged over dense —
    # both serve CHURN_BATCH concurrent requests, so the ratio reduces
    # to dense slab bytes over the paged pool's peak footprint
    out["hbm_capacity_ratio"] = round(
        out["paged"]["requests_per_kv_gib"]
        / out["dense"]["requests_per_kv_gib"], 3)
    out["prefill_skip_rate"] = \
        out["paged"]["allocator"]["prefill_skip_rate"]
    return out


# speculative section: a 1-layer draft prefix proposes SPEC_K tokens per
# dispatch and the full SPEC_LAYERS-layer target verifies them in ONE
# teacher-forced block pass. The params are doctored so every post-draft
# block contributes nothing to the residual stream (zero attention
# out-projection + zero FFN down-projection): the draft then agrees with
# the target almost everywhere, which isolates the DISPATCH-amortization
# headline — accepted tokens per dispatch — from model-quality noise.
# The baseline is plain continuous decode at the SAME steps_per_dispatch
# on the SAME doctored params and trace (the matching k-sweep point), so
# the tok/s ratio measures exactly what speculation buys: k sequential
# full-model steps traded for k draft steps plus one fused verify.
SPEC_LAYERS = 4
SPEC_DRAFT_LAYERS = 1
SPEC_K = 8
SPEC_REQUESTS = 12                  # per wave
SPEC_TOKENS = 12                    # generated per request


def spec_requests(tag: str, n: int = SPEC_REQUESTS):
    # gap-robust prompts (the tests/test_speculative.py family): every
    # decode step's top-2 logit gap clears float-reassociation noise, so
    # draft/target agreement is a model fact, not a tie accident
    reqs = []
    for i in range(n):
        plen = 2 + i % 3
        reqs.append(DecodeRequest(
            f"{tag}-{i}", [2 + (7 * i + 13 * j) % 50 for j in range(plen)],
            max_new_tokens=SPEC_TOKENS))
    return reqs


def _doctored_draft_params(plan):
    """Demo params whose layers >= SPEC_DRAFT_LAYERS are residual no-ops.

    Zeroing a block's attention out-projection and FFN down-projection
    zeroes both of its residual deltas, so the stream leaving the last
    draft layer IS the stream entering the final norm — the draft prefix
    computes exactly the target's logits (up to reassociation), and
    acceptance measures the lane machinery, not model agreement.
    """
    import jax

    params = plan.init_params(0)

    def zero_tail(tree):
        return jax.tree_util.tree_map(
            lambda w: w.at[SPEC_DRAFT_LAYERS:].set(0), tree)

    blocks = dict(params["blocks"])
    blocks["attn"] = dict(blocks["attn"],
                          wo=zero_tail(blocks["attn"]["wo"]))
    blocks["ffn"] = dict(blocks["ffn"],
                         down=zero_tail(blocks["ffn"]["down"]))
    return dict(params, blocks=blocks)


SPEC_CONFIGS = (
    ("baseline", dict(schedule="continuous", steps_per_dispatch=SPEC_K)),
    ("speculative", dict(schedule="continuous", steps_per_dispatch=SPEC_K,
                         speculative=SPEC_K,
                         draft=f"prefix:{SPEC_DRAFT_LAYERS}")),
)


def measure_speculative(waves: int = 3) -> dict:
    """Race plain continuous k=SPEC_K vs speculative lanes, same trace."""
    cfg = reduced_config(ARCH).with_(n_layers=SPEC_LAYERS, vocab=64)
    policy = BucketPolicy([Bucket(CHURN_MAX_LEN, CHURN_BATCH)])
    out = {}
    token_counts = {}
    for label, kw in SPEC_CONFIGS:
        plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
        with plan.activate():
            b = plan.make_batcher(policy=policy, **kw)
            b.load_params(_doctored_draft_params(plan))
            for r in spec_requests("cold"):
                b.submit(r)
            b.run()                    # compile + warm the bucket
            warm_cache = dict(b.cache.stats())
            cold_spec = dict(b.scheduler.stats().get("spec", {}))
            b.metrics = {}
            t0 = time.perf_counter()
            tokens = 0
            for w in range(waves):
                for r in spec_requests(f"warm{w}"):
                    b.submit(r)
                res = b.run()
                tokens += sum(len(r.tokens) for r in res.values())
            dt = time.perf_counter() - t0
        after = b.cache.stats()
        token_counts[label] = tokens
        entry = {
            "tokens": tokens,
            "seconds": round(dt, 4),
            "tokens_per_second": round(tokens / dt, 2) if dt else 0.0,
            "new_lowerings_after_warmup":
                after["lowerings"] - warm_cache["lowerings"],
        }
        if label == "speculative":
            s = b.scheduler.stats()["spec"]
            # warm-only, like every sibling field: subtract the cold wave
            verifies = s["verifies"] - cold_spec["verifies"]
            accepted = s["accepted_tokens"] - cold_spec["accepted_tokens"]
            drafted = s["draft_tokens"] - cold_spec["draft_tokens"]
            entry["spec"] = {
                "spec_k": s["spec_k"],
                "draft_layers": s["draft_layers"],
                "verifies": verifies,
                "draft_tokens": drafted,
                "accepted_tokens": accepted,
                "rollbacks": s["rollbacks"] - cold_spec["rollbacks"],
                "acceptance_rate": round(accepted / drafted, 4)
                if drafted else 0.0,
                "accepted_tokens_per_dispatch": round(accepted / verifies, 3)
                if verifies else 0.0,
            }
        out[label] = entry
    # count parity only: exact stream parity is pinned per request id by
    # tests/test_speculative.py on curated traces; the benchmark keeps
    # the cheap invariant that speculation never changes how much work
    # the trace represents
    assert token_counts["speculative"] == token_counts["baseline"], (
        "speculative decode generated a different token count than plain "
        f"continuous on the same trace: {token_counts}")
    out["tokens_match"] = True
    out["accepted_tokens_per_dispatch"] = \
        out["speculative"]["spec"]["accepted_tokens_per_dispatch"]
    out["speedup_spec_vs_baseline"] = round(
        out["speculative"]["tokens_per_second"]
        / out["baseline"]["tokens_per_second"], 3) \
        if out["baseline"]["tokens_per_second"] else 0.0
    return out


# spec_paged section: the ISSUE-10 composition — the SAME doctored-draft
# race as the speculative section, but the paged racer routes draft AND
# verify KV writes through revocable draft-page leases on the shared
# page pool. Every request opens with a one-page shared system prompt so
# the prefix cache stays observable: paging must keep its skip-rate rent
# while speculation borrows (and rolls back) pages at the micro-run
# boundary. Gates: token-count parity, paged spec tok/s >= 0.9x dense
# spec, acceptance rate within 0.05 of dense spec, prefill skip rate
# > 0, draft leases actually cycling, zero post-warmup lowerings.
SPEC_PAGED_SYSTEM = tuple(2 + (13 * j) % 50 for j in range(16))


def spec_paged_requests(tag: str, n: int = SPEC_REQUESTS):
    # one-page shared prefix + the gap-robust per-request tails of
    # spec_requests, so prefix reuse and draft/target agreement are both
    # model facts rather than tie accidents
    reqs = []
    for i in range(n):
        tail = [2 + (7 * (i + 1) + 13 * j) % 50 for j in range(2 + i % 3)]
        reqs.append(DecodeRequest(
            f"{tag}-{i}", list(SPEC_PAGED_SYSTEM) + tail,
            max_new_tokens=SPEC_TOKENS))
    return reqs


SPEC_PAGED_CONFIGS = (
    ("dense_spec", dict(schedule="continuous", steps_per_dispatch=SPEC_K,
                        speculative=SPEC_K,
                        draft=f"prefix:{SPEC_DRAFT_LAYERS}")),
    # page_size 4 (not the default 16): short benchmark sequences must
    # OUTGROW their lazily-admitted prompt pages, or every draft write
    # lands in already-owned pages and the lease machinery never runs
    ("paged_spec", dict(schedule="continuous", steps_per_dispatch=SPEC_K,
                        speculative=SPEC_K,
                        draft=f"prefix:{SPEC_DRAFT_LAYERS}",
                        paged=4)),
)


def measure_spec_paged(waves: int = 3) -> dict:
    """Race dense-state spec lanes vs paged spec lanes, same trace."""
    cfg = reduced_config(ARCH).with_(n_layers=SPEC_LAYERS, vocab=64)
    policy = BucketPolicy([Bucket(CHURN_MAX_LEN, CHURN_BATCH)])
    out = {}
    token_counts = {}
    for label, kw in SPEC_PAGED_CONFIGS:
        plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
        with plan.activate():
            b = plan.make_batcher(policy=policy, **kw)
            b.load_params(_doctored_draft_params(plan))
            for r in spec_paged_requests("cold"):
                b.submit(r)
            b.run()                    # compile + warm the bucket
            warm_cache = dict(b.cache.stats())
            cold_spec = dict(b.scheduler.stats().get("spec", {}))
            b.metrics = {}
            t0 = time.perf_counter()
            tokens = 0
            for w in range(waves):
                for r in spec_paged_requests(f"warm{w}"):
                    b.submit(r)
                res = b.run()
                tokens += sum(len(r.tokens) for r in res.values())
            dt = time.perf_counter() - t0
        after = b.cache.stats()
        token_counts[label] = tokens
        s = b.scheduler.stats()["spec"]
        accepted = s["accepted_tokens"] - cold_spec["accepted_tokens"]
        drafted = s["draft_tokens"] - cold_spec["draft_tokens"]
        verifies = s["verifies"] - cold_spec["verifies"]
        entry = {
            "tokens": tokens,
            "seconds": round(dt, 4),
            "tokens_per_second": round(tokens / dt, 2) if dt else 0.0,
            "new_lowerings_after_warmup":
                after["lowerings"] - warm_cache["lowerings"],
            "spec": {
                "spec_k": s["spec_k"],
                "verifies": verifies,
                "draft_tokens": drafted,
                "accepted_tokens": accepted,
                "rollbacks": s["rollbacks"] - cold_spec["rollbacks"],
                "acceptance_rate": round(accepted / drafted, 4)
                if drafted else 0.0,
                "accepted_tokens_per_dispatch": round(accepted / verifies, 3)
                if verifies else 0.0,
            },
        }
        if label == "paged_spec":
            entry["allocator"] = b.stats()["paged"]
        out[label] = entry
    assert token_counts["paged_spec"] == token_counts["dense_spec"], (
        "paged speculative decode generated a different token count than "
        f"dense speculative on the same trace: {token_counts}")
    out["tokens_match"] = True
    out["speedup_paged_spec_vs_dense_spec"] = round(
        out["paged_spec"]["tokens_per_second"]
        / out["dense_spec"]["tokens_per_second"], 3) \
        if out["dense_spec"]["tokens_per_second"] else 0.0
    out["acceptance_rate_delta"] = round(
        out["paged_spec"]["spec"]["acceptance_rate"]
        - out["dense_spec"]["spec"]["acceptance_rate"], 4)
    alloc = out["paged_spec"]["allocator"]
    out["prefill_skip_rate"] = alloc["prefill_skip_rate"]
    out["draft_pages_committed"] = alloc["draft_pages_committed"]
    out["draft_pages_rolled_back"] = alloc["draft_pages_rolled_back"]
    return out


# traffic section: one overloaded Poisson trace (arrival rate ~2x the
# bucket's service capacity) so admission order actually matters, replayed
# per policy in virtual time — on dense state AND again through the shared
# page pool (half the arrivals open with the trace's 16-token system
# prompt, one page, so paged replays hit the prefix cache); a second,
# lighter trace with abandonment drives the async wall-clock subsection
TRAFFIC_SEED = 7
TRAFFIC_N = 48
TRAFFIC_K = 4                       # steps_per_dispatch for all replays
TRAFFIC_POLICIES = ("fifo", "priority", "edf")
TRAFFIC_SPEC = TrafficSpec(rate=2.0, max_prompt=12, max_new_tokens=12,
                           deadline_slack=(1.2, 3.5),
                           shared_prefix_len=16, shared_prefix_prob=0.5)
ASYNC_SPEC = TrafficSpec(rate=2.0, max_prompt=12, max_new_tokens=12,
                         deadline_prob=0.0, abandon_prob=0.3,
                         patience_mean=8.0)
ASYNC_N = 24
ASYNC_TICK_S = 0.02                 # wall-clock seconds per trace tick


def _pct(vals, p):
    return round(quantile(vals, p), 3)


def _traffic_batcher(admission_name=None, paged: bool = False):
    """Fresh warm continuous batcher on the churn bucket; returns it plus
    the post-warmup lowering count (the zero-lowerings baseline)."""
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    policy = BucketPolicy([Bucket(CHURN_MAX_LEN, CHURN_BATCH)])
    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    admission = make_policy(admission_name) if admission_name else None
    with plan.activate():
        b = plan.make_batcher(policy=policy, schedule="continuous",
                              steps_per_dispatch=TRAFFIC_K,
                              admission=admission, paged=paged)
        b.init_demo_params(seed=0)
        for i in range(2):
            b.submit(DecodeRequest(f"warm{i}", [1, 2, 3],
                                   max_new_tokens=4))
        b.run()
    b.metrics = {}
    return b, b.cache.stats()["lowerings"]


def _replay_virtual(trace, admission_name: str,
                    paged: bool = False) -> dict:
    """Replay one arrival trace under one policy, virtual time.

    The clock is the scheduler's global step counter: the ``on_boundary``
    hook releases every arrival whose tick has come, so a request lands
    in the SAME in-flight dispatch it would under a resident server, and
    the whole replay is deterministic. ``on_tokens`` timestamps first
    tokens and completions in the same tick domain as the trace's
    deadlines (when the queue drains before the next arrival, the replay
    jumps straight to it — overload keeps that rare past the first tick).
    """
    need = {tr.request.request_id: tr.request.max_new_tokens
            for tr in trace}
    first_tick, done_tick = {}, {}
    got = collections.defaultdict(int)
    b, warm_lowerings = _traffic_batcher(admission_name, paged=paged)
    sched = b.scheduler
    idx = 0

    def release_due(pos=None, slots=None):
        nonlocal idx
        now = float(sched.steps)
        while idx < len(trace) and trace[idx].at <= now:
            b.submit(trace[idx].request)
            idx += 1

    def on_tokens(deltas):
        # called before the step counter advances: these tokens landed
        # during the micro-run that just ran, i.e. by steps + k
        tick = float(sched.steps + TRAFFIC_K)
        for rid, toks in deltas.items():
            first_tick.setdefault(rid, tick)
            got[rid] += len(toks)
            if got[rid] >= need.get(rid, 1 << 30):
                done_tick.setdefault(rid, tick)

    sched.on_boundary = release_due
    sched.on_tokens = on_tokens
    shed = set()
    with b.plan.activate():
        try:
            while idx < len(trace) or b._pending:
                if not b._pending:      # idle: jump to the next arrival
                    b.submit(trace[idx].request)
                    idx += 1
                b.run()
                shed |= b.last_shed
        finally:
            sched.on_boundary = None
            sched.on_tokens = None
    ttfts, good, late = [], 0, 0
    for tr in trace:
        rid = tr.request.request_id
        if rid in first_tick:
            ttfts.append(max(0.0, first_tick[rid] - tr.at))
        if rid in done_tick:
            dl = tr.request.deadline
            if dl is None or done_tick[rid] <= dl:
                good += 1
            else:
                late += 1
    out = {
        "requests": len(trace),
        "completed": len(done_tick),
        "shed": len(shed),
        "deadline_misses": late,
        "goodput": round(good / len(trace), 4),
        "p50_ttft_ticks": _pct(ttfts, 0.50),
        "p99_ttft_ticks": _pct(ttfts, 0.99),
        "steps": sched.steps,
        "new_lowerings_after_warmup":
            b.cache.stats()["lowerings"] - warm_lowerings,
    }
    if paged:
        a = b.stats()["paged"]
        out["allocator"] = {
            "prefix_hits": a["prefix_hits"],
            "skipped_prefill_tokens": a["skipped_prefill_tokens"],
            "prefill_skip_rate": a["prefill_skip_rate"],
            "peak_pages": a["peak_pages"],
        }
    return out


def _measure_async(trace) -> dict:
    """The same load through the real asyncio front-end, wall clock.

    Arrivals are scheduled at ``at * ASYNC_TICK_S`` seconds; impatient
    users abandon their stream if the first token misses their patience
    window (disconnect -> boundary cancellation). Client-side TTFT
    percentiles come from the server's own stats.
    """
    import asyncio

    from repro.serve import AsyncServeServer, RequestShed

    b, warm_lowerings = _traffic_batcher()

    async def drive():
        async with AsyncServeServer(b) as server:
            loop = asyncio.get_running_loop()
            t0 = loop.time()

            async def one(tr):
                await asyncio.sleep(max(
                    0.0, tr.at * ASYNC_TICK_S - (loop.time() - t0)))
                gen = server.stream(tr.request)
                try:
                    if tr.patience is not None:
                        budget = max(0.001,
                                     (tr.patience - tr.at) * ASYNC_TICK_S)
                        try:
                            await asyncio.wait_for(gen.__anext__(), budget)
                        except asyncio.TimeoutError:
                            return "abandoned"
                        except StopAsyncIteration:
                            return "done"
                    async for _ in gen:
                        pass
                    return "done"
                except RequestShed:
                    return "shed"
                finally:
                    await gen.aclose()

            outcomes = await asyncio.gather(*[one(tr) for tr in trace])
            return list(outcomes), server.stats()

    with b.plan.activate():
        outcomes, sstats = asyncio.run(drive())
    return {
        "requests": len(trace),
        "tick_seconds": ASYNC_TICK_S,
        "client_outcomes": {o: outcomes.count(o)
                            for o in sorted(set(outcomes))},
        "p50_ttft_s": sstats["p50_ttft_s"],
        "p99_ttft_s": sstats["p99_ttft_s"],
        "p50_total_s": sstats["p50_total_s"],
        "cancellations": sstats["scheduler"]["cancellations"],
        "new_lowerings_after_warmup":
            b.cache.stats()["lowerings"] - warm_lowerings,
    }


def measure_traffic() -> dict:
    """Admission-policy shoot-out on one seeded trace + async replay.

    Every policy replays the SAME trace twice: dense slabs and the shared
    page pool. Prefix-cache hits on the trace's shared system prompt skip
    those prefill steps, so paged replays finish shared-prefix requests
    EARLIER in virtual time — goodput under deadline must not get worse
    (gated below), and on an overloaded trace it visibly improves.
    """
    trace = generate_traffic(TRAFFIC_SPEC, TRAFFIC_N, TRAFFIC_SEED)
    out = {
        "spec": dataclasses.asdict(TRAFFIC_SPEC),
        "load": summarize(trace),
        "policies": {name: _replay_virtual(trace, name)
                     for name in TRAFFIC_POLICIES},
        "policies_paged": {name: _replay_virtual(trace, name, paged=True)
                           for name in TRAFFIC_POLICIES},
    }
    out["goodput_paged_minus_dense"] = {
        n: round(out["policies_paged"][n]["goodput"]
                 - out["policies"][n]["goodput"], 4)
        for n in TRAFFIC_POLICIES}
    out["goodput_edf_minus_fifo"] = round(
        out["policies"]["edf"]["goodput"]
        - out["policies"]["fifo"]["goodput"], 4)
    out["async"] = _measure_async(
        generate_traffic(ASYNC_SPEC, ASYNC_N, TRAFFIC_SEED + 1, tag="a"))
    return out


def measure(waves: int = WAVES, tokens: int = TOKENS,
            traffic: bool = True) -> dict:
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    with plan.activate():
        batcher = plan.make_batcher().init_demo_params(seed=0)

        def wave(tag: str):
            for bucket in batcher.policy.buckets:
                for i in range(bucket.batch):
                    plen = 2 + (i % 3)
                    batcher.submit(DecodeRequest(
                        f"{tag}-{bucket.label}-{i}",
                        [1 + (i + j) % 7 for j in range(plen)],
                        max_new_tokens=tokens
                        if bucket == batcher.policy.buckets[0]
                        else bucket.max_len // 4))
            batcher.run()

        wave("cold")                      # compiles both executables/bucket
        cold_cache = dict(batcher.cache.stats())
        batcher.metrics = {}              # keep only warm-path numbers
        for w in range(waves):
            wave(f"warm{w}")

    stats = batcher.stats()
    buckets = {}
    for label, m in stats["buckets"].items():
        busy = m["prefill_seconds"] + m["decode_seconds"]
        buckets[label] = dict(
            m,
            us_per_token=round(busy / m["new_tokens"] * 1e6, 3)
            if m["new_tokens"] else 0.0,
        )
    out = {
        "arch": ARCH,
        "waves": waves,
        "tokens_per_request": tokens,
        "cold_compiles": cold_cache["compiles"],
        "warm_cache": stats["cache"],
        "buckets": buckets,
        "pool": stats["pool"],
        "churn": measure_churn(),
        "paged": measure_paged(),
        "speculative": measure_speculative(),
        "spec_paged": measure_spec_paged(),
    }
    if traffic:
        out["traffic"] = measure_traffic()
    return out


def run():
    """Rows for the benchmarks.run CSV harness."""
    data = measure(waves=2, tokens=4, traffic=False)
    rows = []
    for label, m in data["buckets"].items():
        rows.append({
            "name": f"serve_{label}",
            "us_per_call": m["us_per_token"],
            "derived": (f"{m['tokens_per_second']} tok/s; "
                        f"p50 {m['p50_latency_s']}s; "
                        f"p99 {m['p99_latency_s']}s; "
                        f"hits {data['warm_cache']['hits']}"),
        })
    return rows


def _report_paged(paged: dict) -> None:
    """Print + gate the paged section (shared by --only paged)."""
    for label, _ in PAGED_CONFIGS:
        p = paged[label]
        print(f"paged/{label}: {p['tokens_per_second']} tok/s, "
              f"{p['peak_kv_bytes']} peak KV bytes, "
              f"{p['requests_per_kv_gib']} requests/KV-GiB")
        assert p["new_lowerings_after_warmup"] == 0, \
            f"paged/{label} lowered after warmup"
    a = paged["paged"]["allocator"]
    print(f"paged: skip rate {paged['prefill_skip_rate']} "
          f"({a['skipped_prefill_tokens']} prompt tokens skipped, "
          f"{a['prefix_hits']} prefix hits), HBM capacity ratio "
          f"{paged['hbm_capacity_ratio']}x (gate: >= 1), "
          f"speedup {paged['speedup_paged_vs_dense']}x")
    assert paged["tokens_match"]
    assert paged["prefill_skip_rate"] > 0, (
        "shared-prefix trace produced no prefill skips — the prefix "
        "cache is not publishing or not matching")
    assert paged["hbm_capacity_ratio"] >= 1, (
        "paged KV held MORE concurrent requests' bytes than the dense "
        "slabs on a shared-prefix mix — paging lost its reason to exist")


def _report_speculative(spec: dict) -> None:
    """Print + gate the speculative section (shared by --only speculative)."""
    for label, _ in SPEC_CONFIGS:
        p = spec[label]
        print(f"speculative/{label}: {p['tokens_per_second']} tok/s "
              f"({p['tokens']} tokens in {p['seconds']}s)")
        assert p["new_lowerings_after_warmup"] == 0, \
            f"speculative/{label} lowered after warmup"
    s = spec["speculative"]["spec"]
    print(f"speculative: {s['accepted_tokens_per_dispatch']} accepted "
          f"tokens/dispatch at k={s['spec_k']} (gate: > 1), acceptance "
          f"rate {s['acceptance_rate']} over {s['draft_tokens']} drafts "
          f"({s['rollbacks']} rollbacks), speedup vs plain k={SPEC_K} "
          f"continuous: {spec['speedup_spec_vs_baseline']}x (gate: >= 1)")
    assert spec["tokens_match"]
    assert spec["accepted_tokens_per_dispatch"] > 1.0, (
        "speculative lanes committed <= 1 token per dispatch — the draft "
        "is not amortizing anything, so the fused scan is pure overhead")
    assert spec["speedup_spec_vs_baseline"] >= 1.0, (
        "speculative decode was SLOWER than plain continuous at the same "
        "steps_per_dispatch on a draft-friendly model — k draft steps + "
        "one fused verify must beat k full-model steps when acceptance "
        "is near-perfect")


def _report_spec_paged(sp: dict) -> None:
    """Print + gate the spec_paged section (shared by --only spec_paged)."""
    for label, _ in SPEC_PAGED_CONFIGS:
        p = sp[label]
        print(f"spec_paged/{label}: {p['tokens_per_second']} tok/s, "
              f"acceptance rate {p['spec']['acceptance_rate']} "
              f"({p['spec']['rollbacks']} rollbacks)")
        assert p["new_lowerings_after_warmup"] == 0, \
            f"spec_paged/{label} lowered after warmup"
    print(f"spec_paged: speedup paged/dense "
          f"{sp['speedup_paged_spec_vs_dense_spec']}x (gate: >= 0.9), "
          f"acceptance delta {sp['acceptance_rate_delta']} "
          f"(gate: |.| <= 0.05), prefix skip rate "
          f"{sp['prefill_skip_rate']} (gate: > 0), draft leases "
          f"{sp['draft_pages_committed']} pages committed / "
          f"{sp['draft_pages_rolled_back']} rolled back")
    assert sp["tokens_match"]
    assert sp["speedup_paged_spec_vs_dense_spec"] >= 0.9, (
        "paged speculative lanes ran < 0.9x the dense-state spec racer "
        "on the same trace — draft-page leasing must stay a memory-"
        "layout change, not a throughput regression")
    assert abs(sp["acceptance_rate_delta"]) <= 0.05, (
        "paged spec acceptance drifted from dense spec — draft KV twins "
        "riding the page table must see the same context as dense state")
    assert sp["prefill_skip_rate"] > 0, (
        "paged speculative replay produced no prefill skips on a shared-"
        "prefix trace — leasing draft pages must not break prefix reuse")
    assert sp["draft_pages_committed"] > 0, (
        "no draft pages were ever committed — the lease path never "
        "engaged, so this section measured nothing")


def _report_traffic(traffic: dict) -> None:
    """Print + gate the traffic section (shared by --only traffic)."""
    for name in TRAFFIC_POLICIES:
        p = traffic["policies"][name]
        print(f"traffic/{name}: goodput {p['goodput']}, "
              f"{p['completed']}/{p['requests']} completed "
              f"({p['shed']} shed, {p['deadline_misses']} late), "
              f"p50 TTFT {p['p50_ttft_ticks']} ticks, "
              f"p99 {p['p99_ttft_ticks']} ticks")
        assert p["new_lowerings_after_warmup"] == 0, \
            f"traffic/{name} lowered after warmup"
    print(f"traffic: EDF goodput - FIFO goodput = "
          f"{traffic['goodput_edf_minus_fifo']} (gate: >= 0)")
    assert traffic["goodput_edf_minus_fifo"] >= 0, (
        "EDF admission lost goodput-under-deadline to FIFO on the same "
        "trace — shedding expired requests must not hurt")
    for name in TRAFFIC_POLICIES:
        p = traffic["policies_paged"][name]
        a = p["allocator"]
        print(f"traffic/{name}+paged: goodput {p['goodput']} "
              f"(+{traffic['goodput_paged_minus_dense'][name]} vs dense), "
              f"{a['prefix_hits']} prefix hits, skip rate "
              f"{round(a['prefill_skip_rate'], 3)}, "
              f"peak pages {a['peak_pages']}")
        assert p["new_lowerings_after_warmup"] == 0, \
            f"traffic/{name}+paged lowered after warmup"
        assert a["prefix_hits"] > 0, (
            f"traffic/{name}+paged saw no prefix-cache hits on a trace "
            "where half the arrivals share a one-page system prompt")
        assert traffic["goodput_paged_minus_dense"][name] >= 0, (
            f"traffic/{name}+paged LOST goodput vs dense on the same "
            "trace — prefix reuse skips prefill steps, so shared-prefix "
            "requests must finish no later than their dense replays")
    a = traffic["async"]
    print(f"traffic/async: p50 TTFT {a['p50_ttft_s']}s, "
          f"p99 {a['p99_ttft_s']}s, outcomes {a['client_outcomes']}, "
          f"{a['cancellations']} boundary cancellations")
    assert a["new_lowerings_after_warmup"] == 0, \
        "async streaming replay lowered after warmup"


def main():
    ap = argparse.ArgumentParser(
        description="Warm-cache serve latency per bucket (debug mesh)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--waves", type=int, default=WAVES)
    ap.add_argument("--tokens", type=int, default=TOKENS)
    ap.add_argument("--only", default="all",
                    choices=["all", "traffic", "paged", "speculative",
                             "spec_paged"],
                    help="'traffic' runs just the admission-policy / "
                         "async replay section (the CI traffic-smoke job); "
                         "'paged' just the paged-vs-dense KV race; "
                         "'speculative' just the draft-lane race "
                         "(the CI spec-smoke job); 'spec_paged' just the "
                         "draft-lease race over the page pool (the CI "
                         "spec-smoke paged replay)")
    args = ap.parse_args()
    if args.only == "speculative":
        data = {"speculative": measure_speculative()}
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        _report_speculative(data["speculative"])
        print(f"wrote {args.out} (speculative section only)")
        return
    if args.only == "spec_paged":
        data = {"spec_paged": measure_spec_paged()}
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        _report_spec_paged(data["spec_paged"])
        print(f"wrote {args.out} (spec_paged section only)")
        return
    if args.only == "traffic":
        data = {"traffic": measure_traffic()}
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        _report_traffic(data["traffic"])
        print(f"wrote {args.out} (traffic section only)")
        return
    if args.only == "paged":
        data = {"paged": measure_paged()}
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        _report_paged(data["paged"])
        print(f"wrote {args.out} (paged section only)")
        return
    data = measure(waves=args.waves, tokens=args.tokens)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    hits = data["warm_cache"]["hits"]
    assert hits > 0, "warm waves never hit the executable cache"
    for label, m in data["buckets"].items():
        print(f"{label}: {m['tokens_per_second']} tok/s warm, "
              f"p50 {m['p50_latency_s']}s p99 {m['p99_latency_s']}s, "
              f"{m['us_per_token']} us/token")
    churn = data["churn"]
    for label, _, _ in CHURN_CONFIGS:
        c = churn[label]
        print(f"churn/{label}: {c['tokens_per_second']} tok/s, busy "
              f"slot fraction {c['busy_slot_fraction']}, p99 slot idle "
              f"{c['p99_slot_idle_s']}s")
    print(f"churn speedup continuous/fifo: {churn['speedup']}x; "
          f"k4/k1: {churn['speedup_k4_vs_k1']}x; "
          f"k8/k1: {churn['speedup_k8_vs_k1']}x")
    for label, schedule, _ in CHURN_CONFIGS:
        if schedule == "continuous":
            assert churn[label]["new_lowerings_after_warmup"] == 0, \
                f"{label} scheduler lowered after warmup under churn"
    _report_paged(data["paged"])
    _report_speculative(data["speculative"])
    _report_spec_paged(data["spec_paged"])
    _report_traffic(data["traffic"])
    print(f"wrote {args.out} (cache hits={hits}, "
          f"compiles={data['warm_cache']['compiles']})")


if __name__ == "__main__":
    main()
