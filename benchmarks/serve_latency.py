"""Warm-cache serving latency/throughput per bucket (debug mesh).

Dispatches request waves through ``repro.serve.ServeBatcher`` on the
1x1 debug mesh, drops the cold wave (compiles), and reports per-bucket
warm tokens/sec plus p50/p99 dispatch latency. Run standalone to emit
``BENCH_serve.json`` so future PRs have a perf trajectory to diff:

    PYTHONPATH=src python -m benchmarks.serve_latency [--out BENCH_serve.json]

The ``churn`` section races the schedulers on an identical mixed-length
request trace (every eighth request rides 14x longer than its
neighbours — the worst case for fixed FIFO groups, whose short requests
idle their slots until the long rider finishes): warm tokens/sec for
``schedule="fifo"`` vs ``schedule="continuous"`` at ``steps_per_dispatch``
(micro-run length) k in {1, 4, 8}, the speedup ratios, busy-slot
fractions, and p50/p99 per-slot idle time. ``k_sweep`` summarizes
tokens/s per k; ``speedup_k4_vs_k1`` is the micro-run amortization
headline (CI asserts k=4 >= k=1).

Also exposes ``run()`` rows for the ``benchmarks.run`` CSV harness.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.configs import reduced_config
from repro.plan import MeshSpec, build_plan
from repro.serve import Bucket, BucketPolicy, DecodeRequest

WAVES = 4          # warm waves measured (one cold wave discarded)
TOKENS = 8         # generated per request
ARCH = "yi_6b"

# churn trace: one long rider per eight requests, interleaved, so every
# FIFO group of 8 idles seven slots behind the rider
CHURN_BATCH = 8
CHURN_MAX_LEN = 64
CHURN_PATTERN = (28, 2, 2, 2, 2, 2, 2, 2)   # max_new_tokens mod 8
CHURN_REQUESTS = 24                # per wave


def churn_requests(tag: str, n: int = CHURN_REQUESTS):
    reqs = []
    for i in range(n):
        plen = 2 + (i % 3)
        reqs.append(DecodeRequest(
            f"{tag}-{i}", [1 + (i + j) % 7 for j in range(plen)],
            max_new_tokens=CHURN_PATTERN[i % len(CHURN_PATTERN)]))
    return reqs


def _sched_counters(s) -> dict:
    return {
        "dispatches": s.dispatches, "micro_runs": s.micro_runs,
        "steps": s.steps,
        "admissions": s.admissions, "slot_steps": s.slot_steps,
        "idle_slot_steps": s.idle_slot_steps, "refills": s.refills,
        "refill_gap_total": s.refill_gap_total,
    }


# (label, schedule, steps_per_dispatch): "continuous" stays the k=1
# entry so the fifo-vs-continuous speedup remains diffable across PRs
CHURN_CONFIGS = (
    ("fifo", "fifo", 1),
    ("continuous", "continuous", 1),
    ("continuous_k4", "continuous", 4),
    ("continuous_k8", "continuous", 8),
)


def measure_churn(waves: int = 3) -> dict:
    """Race fifo vs continuous micro-runs on one mixed-length trace."""
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    policy = BucketPolicy([Bucket(CHURN_MAX_LEN, CHURN_BATCH)])
    out = {}
    tokens_ref = None
    for label, schedule, k in CHURN_CONFIGS:
        plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
        with plan.activate():
            b = plan.make_batcher(policy=policy, schedule=schedule,
                                  steps_per_dispatch=k)
            b.init_demo_params(seed=0)
            for r in churn_requests("cold"):
                b.submit(r)
            b.run()                        # compile + warm the bucket
            b.metrics = {}                 # keep warm-path numbers only
            warm_cache = dict(b.cache.stats())
            cold_sched = (_sched_counters(b.scheduler)
                          if b.scheduler is not None else None)
            t0 = time.perf_counter()
            tokens = 0
            for w in range(waves):
                for r in churn_requests(f"warm{w}"):
                    b.submit(r)
                res = b.run()
                tokens += sum(len(r.tokens) for r in res.values())
            dt = time.perf_counter() - t0
        after = b.cache.stats()
        m = b.stats()["buckets"][policy.buckets[0].label]
        steps = m["slot_steps"] / CHURN_BATCH
        sec_per_step = dt / steps if steps else 0.0
        entry = {
            "schedule": schedule,
            "steps_per_dispatch": k,
            "tokens": tokens,
            "seconds": round(dt, 4),
            "tokens_per_second": round(tokens / dt, 2) if dt else 0.0,
            "busy_slot_fraction": m["busy_slot_fraction"],
            "p50_slot_idle_s": round(
                m["p50_slot_idle_steps"] * sec_per_step, 5),
            "p99_slot_idle_s": round(
                m["p99_slot_idle_steps"] * sec_per_step, 5),
            "new_lowerings_after_warmup":
                after["lowerings"] - warm_cache["lowerings"],
        }
        if b.scheduler is not None:
            # warm-only, like every sibling field: subtract the discarded
            # cold wave's counters before deriving the ratios
            warm = {k: v - cold_sched[k]
                    for k, v in _sched_counters(b.scheduler).items()}
            warm["busy_slot_fraction"] = round(
                1 - warm["idle_slot_steps"] / warm["slot_steps"], 4) \
                if warm["slot_steps"] else 0.0
            warm["mean_refill_gap"] = round(
                warm.pop("refill_gap_total") / warm["refills"], 3) \
                if warm["refills"] else 0.0
            entry["scheduler"] = warm
        out[label] = entry
        if tokens_ref is None:
            tokens_ref = tokens
        else:
            assert tokens == tokens_ref, (
                "schedulers generated different token counts for the "
                f"same trace: {tokens} vs {tokens_ref}")

    def ratio(a, b):
        return round(a / b, 3) if b else 0.0

    out["speedup"] = ratio(out["continuous"]["tokens_per_second"],
                           out["fifo"]["tokens_per_second"])
    out["k_sweep"] = {
        str(k): out[label]["tokens_per_second"]
        for label, schedule, k in CHURN_CONFIGS if schedule == "continuous"
    }
    out["speedup_k4_vs_k1"] = ratio(
        out["continuous_k4"]["tokens_per_second"],
        out["continuous"]["tokens_per_second"])
    out["speedup_k8_vs_k1"] = ratio(
        out["continuous_k8"]["tokens_per_second"],
        out["continuous"]["tokens_per_second"])
    return out


def measure(waves: int = WAVES, tokens: int = TOKENS) -> dict:
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    with plan.activate():
        batcher = plan.make_batcher().init_demo_params(seed=0)

        def wave(tag: str):
            for bucket in batcher.policy.buckets:
                for i in range(bucket.batch):
                    plen = 2 + (i % 3)
                    batcher.submit(DecodeRequest(
                        f"{tag}-{bucket.label}-{i}",
                        [1 + (i + j) % 7 for j in range(plen)],
                        max_new_tokens=tokens
                        if bucket == batcher.policy.buckets[0]
                        else bucket.max_len // 4))
            batcher.run()

        wave("cold")                      # compiles both executables/bucket
        cold_cache = dict(batcher.cache.stats())
        batcher.metrics = {}              # keep only warm-path numbers
        for w in range(waves):
            wave(f"warm{w}")

    stats = batcher.stats()
    buckets = {}
    for label, m in stats["buckets"].items():
        busy = m["prefill_seconds"] + m["decode_seconds"]
        buckets[label] = dict(
            m,
            us_per_token=round(busy / m["new_tokens"] * 1e6, 3)
            if m["new_tokens"] else 0.0,
        )
    return {
        "arch": ARCH,
        "waves": waves,
        "tokens_per_request": tokens,
        "cold_compiles": cold_cache["compiles"],
        "warm_cache": stats["cache"],
        "buckets": buckets,
        "pool": stats["pool"],
        "churn": measure_churn(),
    }


def run():
    """Rows for the benchmarks.run CSV harness."""
    data = measure(waves=2, tokens=4)
    rows = []
    for label, m in data["buckets"].items():
        rows.append({
            "name": f"serve_{label}",
            "us_per_call": m["us_per_token"],
            "derived": (f"{m['tokens_per_second']} tok/s; "
                        f"p50 {m['p50_latency_s']}s; "
                        f"p99 {m['p99_latency_s']}s; "
                        f"hits {data['warm_cache']['hits']}"),
        })
    return rows


def main():
    ap = argparse.ArgumentParser(
        description="Warm-cache serve latency per bucket (debug mesh)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--waves", type=int, default=WAVES)
    ap.add_argument("--tokens", type=int, default=TOKENS)
    args = ap.parse_args()
    data = measure(waves=args.waves, tokens=args.tokens)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    hits = data["warm_cache"]["hits"]
    assert hits > 0, "warm waves never hit the executable cache"
    for label, m in data["buckets"].items():
        print(f"{label}: {m['tokens_per_second']} tok/s warm, "
              f"p50 {m['p50_latency_s']}s p99 {m['p99_latency_s']}s, "
              f"{m['us_per_token']} us/token")
    churn = data["churn"]
    for label, _, _ in CHURN_CONFIGS:
        c = churn[label]
        print(f"churn/{label}: {c['tokens_per_second']} tok/s, busy "
              f"slot fraction {c['busy_slot_fraction']}, p99 slot idle "
              f"{c['p99_slot_idle_s']}s")
    print(f"churn speedup continuous/fifo: {churn['speedup']}x; "
          f"k4/k1: {churn['speedup_k4_vs_k1']}x; "
          f"k8/k1: {churn['speedup_k8_vs_k1']}x")
    for label, schedule, _ in CHURN_CONFIGS:
        if schedule == "continuous":
            assert churn[label]["new_lowerings_after_warmup"] == 0, \
                f"{label} scheduler lowered after warmup under churn"
    print(f"wrote {args.out} (cache hits={hits}, "
          f"compiles={data['warm_cache']['compiles']})")


if __name__ == "__main__":
    main()
