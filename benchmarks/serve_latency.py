"""Warm-cache serving latency/throughput per bucket (debug mesh).

Dispatches request waves through ``repro.serve.ServeBatcher`` on the
1x1 debug mesh, drops the cold wave (compiles), and reports per-bucket
warm tokens/sec plus p50/p99 dispatch latency. Run standalone to emit
``BENCH_serve.json`` so future PRs have a perf trajectory to diff:

    PYTHONPATH=src python -m benchmarks.serve_latency [--out BENCH_serve.json]

Also exposes ``run()`` rows for the ``benchmarks.run`` CSV harness.
"""

from __future__ import annotations

import argparse
import json

from repro.configs import reduced_config
from repro.plan import MeshSpec, build_plan
from repro.serve import DecodeRequest

WAVES = 4          # warm waves measured (one cold wave discarded)
TOKENS = 8         # generated per request
ARCH = "yi_6b"


def measure(waves: int = WAVES, tokens: int = TOKENS) -> dict:
    cfg = reduced_config(ARCH).with_(n_layers=2, vocab=64)
    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    with plan.activate():
        batcher = plan.make_batcher().init_demo_params(seed=0)

        def wave(tag: str):
            for bucket in batcher.policy.buckets:
                for i in range(bucket.batch):
                    plen = 2 + (i % 3)
                    batcher.submit(DecodeRequest(
                        f"{tag}-{bucket.label}-{i}",
                        [1 + (i + j) % 7 for j in range(plen)],
                        max_new_tokens=tokens
                        if bucket == batcher.policy.buckets[0]
                        else bucket.max_len // 4))
            batcher.run()

        wave("cold")                      # compiles both executables/bucket
        cold_cache = dict(batcher.cache.stats())
        batcher.metrics = {}              # keep only warm-path numbers
        for w in range(waves):
            wave(f"warm{w}")

    stats = batcher.stats()
    buckets = {}
    for label, m in stats["buckets"].items():
        busy = m["prefill_seconds"] + m["decode_seconds"]
        buckets[label] = dict(
            m,
            us_per_token=round(busy / m["new_tokens"] * 1e6, 3)
            if m["new_tokens"] else 0.0,
        )
    return {
        "arch": ARCH,
        "waves": waves,
        "tokens_per_request": tokens,
        "cold_compiles": cold_cache["compiles"],
        "warm_cache": stats["cache"],
        "buckets": buckets,
        "pool": stats["pool"],
    }


def run():
    """Rows for the benchmarks.run CSV harness."""
    data = measure(waves=2, tokens=4)
    rows = []
    for label, m in data["buckets"].items():
        rows.append({
            "name": f"serve_{label}",
            "us_per_call": m["us_per_token"],
            "derived": (f"{m['tokens_per_second']} tok/s; "
                        f"p50 {m['p50_latency_s']}s; "
                        f"p99 {m['p99_latency_s']}s; "
                        f"hits {data['warm_cache']['hits']}"),
        })
    return rows


def main():
    ap = argparse.ArgumentParser(
        description="Warm-cache serve latency per bucket (debug mesh)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--waves", type=int, default=WAVES)
    ap.add_argument("--tokens", type=int, default=TOKENS)
    args = ap.parse_args()
    data = measure(waves=args.waves, tokens=args.tokens)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    hits = data["warm_cache"]["hits"]
    assert hits > 0, "warm waves never hit the executable cache"
    for label, m in data["buckets"].items():
        print(f"{label}: {m['tokens_per_second']} tok/s warm, "
              f"p50 {m['p50_latency_s']}s p99 {m['p99_latency_s']}s, "
              f"{m['us_per_token']} us/token")
    print(f"wrote {args.out} (cache hits={hits}, "
          f"compiles={data['warm_cache']['compiles']})")


if __name__ == "__main__":
    main()
