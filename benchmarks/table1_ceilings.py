"""Paper Table I: single AIE-ML tile ceilings for the selected native
aie::mmul tilings — reproduced from the analytical device model."""

from repro.core.device import AIEMLDevice, NATIVE_TILINGS

PAPER_TABLE1 = {
    ("int8", "int8"): dict(tiling=(4, 8, 8), mac_cyc=256, gmacs=320, gops=640),
    ("int16", "int8"): dict(tiling=(4, 4, 8), mac_cyc=128, gmacs=160, gops=320),
    ("int16", "int16"): dict(tiling=(4, 4, 4), mac_cyc=64, gmacs=80, gops=160),
}


def run():
    dev = AIEMLDevice()
    rows = []
    for (da, db), want in PAPER_TABLE1.items():
        t = NATIVE_TILINGS[(da, db)]
        got_gops = dev.peak_gops(da, db)
        got_gmacs = dev.peak_macs_per_s(da, db) / 1e9
        ok = (
            (t.M, t.K, t.N) == want["tiling"]
            and t.macs_per_cycle == want["mac_cyc"]
            and abs(got_gmacs - want["gmacs"]) < 1e-6
            and abs(got_gops - want["gops"]) < 1e-6
        )
        rows.append({
            "name": f"table1_{da}x{db}",
            "us_per_call": 0.0,  # analytic
            "derived": f"gops={got_gops:.0f} paper={want['gops']} "
                       f"match={'yes' if ok else 'NO'}",
        })
    return rows
