"""Paper Table IV: comparison with prior AIE-based frameworks.

Feature rows are from the paper (static); our reproduction's row is computed
live from the compiled 7-layer MLP (tiles used, on-chip residency, fused
bias/act, automatic placement), plus the Fig. 4 GEMM efficiency figure.
"""

import numpy as np

from repro.benchmarks_util import gemm_full_array_efficiency
from repro.core import CompileConfig, DenseSpec, build_mlp_graph, compile_graph

PRIOR = [
    # name, gen, eff%, fused, wts_on_aie, act_on_aie, multilayer, autoplace, tiles
    ("AutoMM", "AIE", 27.5, False, False, False, True, False, "192/400"),
    ("MaxEVA", "AIE", 58.0, False, False, False, False, False, "400/400"),
    ("GAMA", "AIEML", 85.0, False, False, False, False, False, "288/304"),
    ("CHARM", "AIE", 31.0, False, False, False, True, False, "192/400"),
    ("ARIES", "AIE", 45.0, False, False, False, True, True, "320/400"),
]


def run():
    rng = np.random.default_rng(0)
    layers = [DenseSpec(512, activation="relu",
                        bias=rng.standard_normal(512) * 0.05)
              for _ in range(7)]
    g = build_mlp_graph(batch=128, f_in=512, layers=layers, seed=1)
    m = compile_graph(g, CompileConfig())
    eff = gemm_full_array_efficiency()
    rows = [{
        "name": "table4_aie4ml_repro",
        "us_per_call": 0.0,
        "derived": (
            f"gen=AIEML eff={eff*100:.1f}%(paper 82.2) fused_bias_act=yes "
            f"wts_on_aie=yes act_on_aie=yes multilayer=yes autoplace=yes "
            f"tiles_7mlp={m.tiles_used}/304"
        ),
    }]
    for (name, gen, e, fused, w_on, a_on, ml, ap, tiles) in PRIOR:
        rows.append({
            "name": f"table4_{name.lower()}",
            "us_per_call": 0.0,
            "derived": f"gen={gen} eff={e}% fused={fused} wts={w_on} "
                       f"act={a_on} multilayer={ml} autoplace={ap} "
                       f"tiles={tiles} (paper-reported)",
        })
    return rows
