"""Paper Table V: cross-device INT8 throughput for the 7-layer 512x512 MLP.

Paper-reported rows are static; two rows are computed live:
  * the AIE-ML analytical model of our generated design;
  * the same workload's roofline on one TPU v5e chip (this framework's
    actual target), via the int8 peak and HBM bound.
"""

from repro.core.device import AIEMLDevice, TPUv5eTarget

PAPER = [
    ("versal_vek280_aie4ml", 113.4),
    ("vu13p_fpga_hls4ml", 3.7),
    ("rtx3060_tensorrt", 14.1),
    ("apple_m4_ane_coreml", 10.5),
]


def run():
    dev = AIEMLDevice()
    rows = []
    # our modeled AIE number for the same workload: each 512x512 layer over
    # a 4x4 cascade rectangle (128x128 per-tile slices), 7 layers pipelined
    # through memory tiles, block replicated to fill the array.
    batch = 128
    cyc = dev.kernel_cycles(batch, 128, 128, "int8", "int8",
                            use_bias=True, use_relu=True)
    interval_s = cyc / dev.clock_hz          # slowest layer = the interval
    ops_per_batch = 2 * 7 * 512 * 512 * batch
    tiles = 7 * 16
    replicas = 296 // tiles
    model_tops = ops_per_batch / interval_s / 1e12 * replicas
    rows.append({
        "name": "table5_aie4ml_model",
        "us_per_call": interval_s * 1e6,
        "derived": f"model={model_tops:.1f}TOPS "
                   f"({replicas}x replicated 112-tile pipelines) "
                   f"paper=113.4TOPS",
    })
    # TPU v5e roofline for the same workload (batch 128 int8)
    tpu = TPUv5eTarget()
    flops = ops_per_batch
    bytes_ = (7 * 512 * 512 * 1 + 2 * 128 * 512 * 7 * 1)  # weights + acts
    t_c = flops / tpu.peak_ops_int8
    t_m = bytes_ / tpu.hbm_bw
    t = max(t_c, t_m)
    rows.append({
        "name": "table5_tpu_v5e_roofline",
        "us_per_call": t * 1e6,
        "derived": f"tops={flops/t/1e12:.1f} bound="
                   f"{'compute' if t_c >= t_m else 'memory'} "
                   f"(peak_int8=394TOPS)",
    })
    for name, tops in PAPER:
        rows.append({
            "name": f"table5_{name}",
            "us_per_call": 0.0,
            "derived": f"tops={tops} (paper-reported)",
        })
    return rows
