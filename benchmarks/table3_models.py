"""Paper Table III: MLP-Mixer blocks and standalone MLPs compiled through
the full AIE4ML pipeline; interval/sample and TOPS from the cycle model.

Workloads (from the paper's footnotes):
  1. Token MLP S/16:   input [B*C, T] = [512, 196], layers 196->256->196
  2. Channel MLP S/16: input [B*T, C] = [196, 512], layers 512->2048->512
  3. Token MLP L/16:   input [B*C, T] = [1024, 196], layers 196->512->196
  4. 2-layer MLP:      input [256, 1024], hidden 1024
  5. 7-layer MLP:      input [1, 512], hidden 512
"""

import time

import numpy as np

from repro.core import CompileConfig, DenseSpec, build_mlp_graph, compile_graph

PAPER = [
    ("token_mlp_s16", 512, 196, [256, 196], 102, 1.2, 82.5),
    ("channel_mlp_s16", 196, 512, [2048, 512], 822, 10.4, 77.3),
    ("token_mlp_l16", 1024, 196, [512, 196], 411, 7.5, 55.0),
    ("mlp_2layer", 256, 1024, [1024, 1024], 1074, 8.2, 129.7),
    ("mlp_7layer", 1, 512, [512] * 7, 3.7, 0.03, 113.4),
]

RNG = np.random.default_rng(0)


def run():
    rows = []
    for name, batch, f_in, widths, mops, paper_int, paper_tops in PAPER:
        layers = [DenseSpec(w, activation="relu",
                            bias=RNG.standard_normal(w) * 0.05)
                  for w in widths]
        def build(slice_override):
            g = build_mlp_graph(batch=min(batch, 128), f_in=f_in,
                                layers=layers, seed=1)
            if slice_override:
                # paper-scale parallelization: 64-feature slices per tile
                for node in g.compute_nodes():
                    node.overrides.update(
                        {"f_in_slice": 64, "f_out_slice": 64})
            return g

        t0 = time.perf_counter()
        try:
            g = build(True)
            m = compile_graph(g, CompileConfig())
        except ValueError:  # 64-slices exceed the array: default resolve
            g = build(False)
            m = compile_graph(g, CompileConfig())
        compile_us = (time.perf_counter() - t0) * 1e6
        # bit-exact check on a small slice
        x = RNG.uniform(-1, 1, (min(batch, 16), f_in)).astype(np.float32)
        exact = bool(np.array_equal(m.predict(x, "x86"), m.predict(x, "aie")))
        # Steady state: layers pipeline through memory tiles, so the
        # interval between consecutive outputs = the slowest layer's
        # full-batch time. The paper's "/sample" unit is per input TENSOR
        # for the batched mixer rows, per streamed row for the [1,512] MLP.
        eff_batch = max(batch, 128) if batch == 1 else batch
        cyc = m.estimated_cycles(batch=min(eff_batch, 512))
        interval_us = cyc / 1.25e9 * 1e6
        if batch == 1:  # streaming rate per sample
            interval_us /= min(eff_batch, 512)
        total_mops = 2 * sum(
            a * b for a, b in zip([f_in] + widths[:-1], widths)) * batch / 1e6
        # paper: "the MLP block can be replicated across the array"; the
        # reported interval/TOPS are at full-array utilization
        replicas = max(1, 296 // max(m.tiles_used, 1))
        interval_eff = interval_us / replicas
        tops = total_mops / interval_eff  # MOP/us == TOP/s
        rows.append({
            "name": f"table3_{name}",
            "us_per_call": compile_us,
            "derived": (
                f"mops={total_mops:.0f}(paper {mops}) "
                f"interval={interval_eff:.2f}us(paper {paper_int}) "
                f"model_tops={tops:.1f}(paper {paper_tops}) "
                f"tiles={m.tiles_used}x{replicas}repl bit_exact={exact}"
            ),
        })
    return rows
