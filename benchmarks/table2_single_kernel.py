"""Paper Table II: single-kernel throughput/latency for the fused linear.

Two parts:
  * the calibrated VLIW cycle model reproduces the paper's GOPS/efficiency
    and micro-batch latency numbers (AIE-ML is the target, not the runtime);
  * the Pallas kernel (interpret mode) is timed for a us_per_call and its
    bit-exactness against the oracle re-asserted on the Table II workload.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core.device import AIEMLDevice
from repro.kernels.qmatmul.ops import qlinear
from repro.kernels.qmatmul.ref import qlinear_ref

# paper Table II (base kernel GOPS, +bias+relu GOPS, latency us at B=8)
PAPER_TABLE2 = {
    ("int8", "int8"): dict(workload=(128, 128), base=613, fused=520, lat=0.5),
    ("int16", "int8"): dict(workload=(128, 128), base=314, fused=287, lat=3.3),
    ("int16", "int16"): dict(workload=(64, 64), base=138, fused=114, lat=2.5),
}


def run():
    dev = AIEMLDevice()
    rows = []
    for (da, db), want in PAPER_TABLE2.items():
        f_in, f_out = want["workload"]
        base = dev.kernel_gops(128, f_in, f_out, da, db)
        fused = dev.kernel_gops(128, f_in, f_out, da, db,
                                use_bias=True, use_relu=True)
        lat_us = dev.kernel_latency_s(8, f_in, f_out, da, db,
                                      use_bias=True, use_relu=True) * 1e6
        peak = dev.peak_gops(da, db)
        rows.append({
            "name": f"table2_model_{da}x{db}",
            "us_per_call": lat_us,
            "derived": (
                f"base={base:.0f}GOPS({base/peak*100:.1f}%) "
                f"fused={fused:.0f}GOPS({fused/peak*100:.1f}%) "
                f"paper_base={want['base']} paper_fused={want['fused']} "
                f"paper_lat={want['lat']}us"
            ),
        })

    # Pallas kernel on the Table II i8 workload: bit-exactness + wall time
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
    b = jnp.asarray(rng.integers(-(2**16), 2**16, (128,)), jnp.int32)
    y = qlinear(x, w, b, shift=7, relu=True)  # compile
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        y = qlinear(x, w, b, shift=7, relu=True)
        y.block_until_ready()
    dt = (time.perf_counter() - t0) / n * 1e6
    exact = bool(np.array_equal(
        np.asarray(y), np.asarray(qlinear_ref(x, w, b, shift=7, relu=True))))
    rows.append({
        "name": "table2_pallas_i8_interpret",
        "us_per_call": dt,
        "derived": f"bit_exact={exact} (interpret-mode on CPU; perf model "
                   f"above is the AIE-ML number)",
    })
    return rows
