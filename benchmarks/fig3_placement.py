"""Paper Fig. 3: B&B placement vs greedy baselines on a 38x8 AIE array
(start (0,0), lambda=1.0, mu=0.05)."""

import time

from repro.core.placement import Block, Placer


def run():
    placer = Placer(38, 8, lam=1.0, mu=0.05, beam=64)
    # an 8-layer network with heterogeneous cascade rectangles
    blocks = [Block(4, 4), Block(4, 2), Block(8, 2), Block(4, 4),
              Block(2, 2), Block(8, 4), Block(4, 2), Block(2, 1)]
    t0 = time.perf_counter()
    bnb = placer.branch_and_bound(blocks, start=(0, 0))
    dt = (time.perf_counter() - t0) * 1e6
    gr = placer.greedy_right(blocks)
    gu = placer.greedy_up(blocks)
    rows = [{
        "name": "fig3_bnb",
        "us_per_call": dt,
        "derived": f"J={bnb.cost:.2f} expanded={bnb.nodes_expanded} "
                   f"placement={bnb.as_tuples()}",
    }, {
        "name": "fig3_greedy_right",
        "us_per_call": 0.0,
        "derived": f"J={gr.cost:.2f} (vs B&B {bnb.cost:.2f}: "
                   f"{gr.cost/bnb.cost:.2f}x)",
    }, {
        "name": "fig3_greedy_up",
        "us_per_call": 0.0,
        "derived": f"J={gu.cost:.2f} (vs B&B {bnb.cost:.2f}: "
                   f"{gu.cost/bnb.cost:.2f}x)",
    }]
    # deeper network (16 graphs): still "a few seconds" claim of the paper
    # (anytime budget + narrow beam keeps the search bounded)
    placer16 = Placer(38, 8, lam=1.0, mu=0.05, beam=8,
                      max_expansions=80_000)
    blocks16 = blocks + [Block(3, 2), Block(2, 2), Block(6, 2), Block(4, 1),
                         Block(2, 4), Block(5, 2), Block(3, 3), Block(2, 2)]
    t0 = time.perf_counter()
    bnb16 = placer16.branch_and_bound(blocks16, start=(0, 0))
    dt16 = time.perf_counter() - t0
    gr16 = placer16.greedy_right(blocks16)
    rows.append({
        "name": "fig3_bnb_16graphs",
        "us_per_call": dt16 * 1e6,
        "derived": f"J={bnb16.cost:.2f} vs greedy_right {gr16.cost:.2f} "
                   f"({gr16.cost/bnb16.cost:.2f}x) runtime={dt16:.2f}s",
    })
    return rows
