"""Paper Fig. 4: scaling a fused linear layer (bias+ReLU) from 1 tile to
296/304 tiles, per precision.

The scaling model: each cascade row of CAS_LEN tiles adds a pipeline-fill
of ~CAS_LEN cycles (512-bit cascade hop per stage) per macro step, and the
memory-tile DMA re-tiling is double-buffered (overlapped) but bounded by
the memtile port bandwidth. Input size grows proportionally with tiles
(weak scaling), as in the paper. Calibrated to land in the ballpark of the
paper's 97.3/98.6/97.1% full-array efficiencies.
"""

from repro.core.device import AIEMLDevice

PAPER_EFF = {("int8", "int8"): 97.3, ("int16", "int8"): 98.6,
             ("int16", "int16"): 97.1}

# full-array shape used by the paper: 296 of 304 tiles
CONFIGS = [1, 4, 16, 64, 148, 296]


def layer_throughput(dev, n_tiles, da, db, f_slice=128, batch=128):
    """GOPS for a layer spread over n_tiles (CAS_LEN x CAS_NUM rectangle)."""
    cas_len = min(n_tiles, 8)
    cas_num = max(1, n_tiles // cas_len)
    # per-tile kernel on its (f_in_slice x f_out_slice) slice
    cycles = dev.kernel_cycles(batch, f_slice, f_slice, da, db,
                               use_bias=True, use_relu=True)
    # cascade pipeline fill per macro step (one hop per stage)
    t = dev.kernel_cycles(batch, f_slice, f_slice, da, db)
    macro_steps = max(1.0, (batch / 8) * (f_slice / 16))
    cycles += cas_len * 1.0 * macro_steps / 8
    # memtile DMA: double-buffered; stalls only if kernel outruns the port
    bytes_per_iter = batch * f_slice  # activations int8-equivalent
    dma_cycles = bytes_per_iter / (dev.cascade_bits / 8)
    cycles += max(0.0, dma_cycles - cycles * 0.98) * 0.02
    ops = 2.0 * batch * f_slice * f_slice * n_tiles
    time_s = cycles / dev.clock_hz
    return ops / time_s / 1e9


def run():
    dev = AIEMLDevice()
    rows = []
    for (da, db), paper_eff in PAPER_EFF.items():
        single = layer_throughput(dev, 1, da, db)
        for n in CONFIGS:
            tput = layer_throughput(dev, n, da, db)
            eff = tput / (single * n) * 100
            if n == 296:
                rows.append({
                    "name": f"fig4_{da}x{db}_tiles{n}",
                    "us_per_call": 0.0,
                    "derived": f"model_tput={tput/1000:.1f}TOPS "
                               f"eff={eff:.1f}% paper_eff={paper_eff}% "
                               f"tiles=296/304(97.4%)",
                })
    # GEMM-only workload at full array: the 82.2%-of-INT8-peak headline
    gemm = layer_throughput(dev, 296, "int8", "int8", f_slice=256)
    peak = dev.peak_gops("int8", "int8") * 304
    rows.append({
        "name": "fig4_gemm_full_array",
        "us_per_call": 0.0,
        "derived": f"model={gemm/1000:.0f}TOPS peak={peak/1000:.0f}TOPS "
                   f"({gemm/peak*100:.1f}%; paper: 160TOPS=82.2%)",
    })
    return rows
