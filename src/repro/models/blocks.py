"""Reusable transformer blocks (pre-norm residual, GQA + SwiGLU/MoE)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act
from repro.layers.attention import (
    attention_spec,
    block_decode_self_attention,
    cross_attention,
    decode_self_attention,
    paged_block_decode_self_attention,
    paged_decode_self_attention,
    self_attention,
)
from repro.layers.mlp import swiglu, swiglu_spec
from repro.layers.moe import moe, moe_spec
from repro.layers.norm import rmsnorm, rmsnorm_spec
from repro.models.base import ArchConfig


def attn_block_spec(cfg: ArchConfig, *, use_moe: bool = False) -> dict:
    mode = cfg.sharding_mode
    spec = {
        "ln1": rmsnorm_spec(cfg.d_model),
        "attn": attention_spec(
            cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, mode,
            qkv_bias=cfg.qkv_bias,
        ),
        "ln2": rmsnorm_spec(cfg.d_model),
    }
    if use_moe:
        spec["moe"] = moe_spec(cfg.d_model, cfg.moe_d_ff, cfg.n_experts, mode)
        if cfg.n_shared_experts:
            spec["shared"] = swiglu_spec(
                cfg.d_model, cfg.n_shared_experts * cfg.moe_d_ff, mode
            )
    else:
        spec["ffn"] = swiglu_spec(cfg.d_model, cfg.d_ff, mode)
    return spec


def _ffn_part(params: dict, x: jnp.ndarray, cfg: ArchConfig):
    if "moe" in params:
        y, aux = moe(
            params["moe"], x,
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            n_groups=cfg.moe_groups or 1,
        )
        if "shared" in params:
            y = y + swiglu(params["shared"], x)
        return y, aux
    # int8 down-projection on serve plans (the plan's Quantize pass sets
    # quantized_mlp and calibrates the shifts per weight tensor)
    quant = ((cfg.mlp_x_shift, cfg.mlp_w_shift, cfg.mlp_out_shift)
             if cfg.quantized_mlp else None)
    return swiglu(params["ffn"], x, quant=quant), jnp.zeros((), jnp.float32)


def attn_block(
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ArchConfig,
    *,
    causal: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, moe aux loss)."""
    h = rmsnorm(params["ln1"], x)
    h = self_attention(
        params["attn"], h, positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=causal, q_chunk=cfg.q_chunk,
    )
    x = x + h
    x = shard_act(x, "batch", "seq", "act_embed")
    h = rmsnorm(params["ln2"], x)
    h, aux = _ffn_part(params, h, cfg)
    x = x + h
    x = shard_act(x, "batch", "seq", "act_embed")
    return x, aux


def attn_block_decode(
    params: dict,
    x: jnp.ndarray,              # [B, 1, d] (or [B, m, d] with ``local``)
    cache_k: jnp.ndarray,        # dense [B,S,KV,hd] or paged [P,ps,KV,hd]
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,
    cfg: ArchConfig,
    *,
    window_start: Optional[jnp.ndarray] = None,   # [B] int32 slot windows
    pages=None,                  # models.base.PageView: paged KV layout
    local: Optional[jnp.ndarray] = None,   # [B] int32: local block coords
):
    h = rmsnorm(params["ln1"], x)
    if local is not None and pages is not None:
        # paged local-coordinate block decode (speculative lanes over the
        # page pool): the PageView's local_pos is the block origin, so
        # ``local`` only selects this branch
        h, ck, cv = paged_block_decode_self_attention(
            params["attn"], h, cache_k, cache_v, pages,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
    elif local is not None:
        # dense local-coordinate block decode (speculative lanes): ``pos``
        # and ``window_start`` are unused — each slot indexes, rotates,
        # and masks at its own local positions [local[b], local[b]+m)
        h, ck, cv = block_decode_self_attention(
            params["attn"], h, cache_k, cache_v, local,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
    elif pages is not None:
        h, ck, cv = paged_decode_self_attention(
            params["attn"], h, cache_k, cache_v, pages,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
    else:
        h, ck, cv = decode_self_attention(
            params["attn"], h, cache_k, cache_v, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, window_start=window_start,
        )
    x = x + h
    h = rmsnorm(params["ln2"], x)
    h, _ = _ffn_part(params, h, cfg)
    return x + h, ck, cv


def cross_block_spec(cfg: ArchConfig, d_memory: Optional[int] = None) -> dict:
    """Cross-attention block (vision layers / enc-dec decoder)."""
    mode = cfg.sharding_mode
    d_mem = d_memory or cfg.d_model
    from repro.layers.linear import linear_spec

    return {
        "ln1": rmsnorm_spec(cfg.d_model),
        "xattn": {
            "wq": linear_spec(cfg.d_model, cfg.n_heads * cfg.head_dim, "col",
                              mode),
            "wk": linear_spec(d_mem, cfg.n_kv * cfg.head_dim, "kv", mode),
            "wv": linear_spec(d_mem, cfg.n_kv * cfg.head_dim, "kv", mode),
            "wo": linear_spec(cfg.n_heads * cfg.head_dim, cfg.d_model, "row",
                              mode),
        },
        "ln2": rmsnorm_spec(cfg.d_model),
        "ffn": swiglu_spec(cfg.d_model, cfg.d_ff, mode),
        "gate": None,  # populated below
    }


def make_cross_block_spec(cfg: ArchConfig, d_memory: Optional[int] = None):
    from repro.dist.sharding import ParamSpec

    spec = cross_block_spec(cfg, d_memory)
    # llama-3.2-V style tanh gate, initialized at zero
    spec["gate"] = ParamSpec((1,), (None,), jnp.bfloat16, init="zeros")
    return spec


def cross_block(
    params: dict,
    x: jnp.ndarray,
    memory: jnp.ndarray,
    cfg: ArchConfig,
    memory_valid: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    h = rmsnorm(params["ln1"], x)
    h = cross_attention(
        params["xattn"], h, memory,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
        memory_valid=memory_valid, q_chunk=cfg.q_chunk,
    )
    gate = jnp.tanh(params["gate"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * h
    h = rmsnorm(params["ln2"], x)
    x = x + swiglu(params["ffn"], h)
    return shard_act(x, "batch", "seq", "act_embed")
