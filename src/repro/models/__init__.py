from repro.models.base import (
    ArchConfig,
    ShapeSpec,
    SHAPES,
    build_model,
    supports_shape,
)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "build_model", "supports_shape"]
