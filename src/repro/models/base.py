"""Model API shared by all assigned architectures.

Every architecture is an ``ArchConfig`` (exact configs live in
``repro/configs/<id>.py``) consumed by a family-specific model class built
via :func:`build_model`. All models expose:

  * ``param_specs()``                 -> ParamSpec pytree
  * ``loss(params, batch)``           -> scalar (training objective)
  * ``forward(params, batch)``        -> logits (prefill entry point)
  * ``decode_state_specs(batch, S)``  -> ParamSpec pytree (KV cache / SSM state)
  * ``decode_step(params, state, tokens, pos)`` -> (logits, state)
  * ``input_specs(shape_name)``       -> ShapeDtypeStruct stand-ins (dry-run)

Modality frontends are stubs per the assignment: the vision/audio entries
take precomputed patch/frame embeddings as inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | encdec | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_groups: int = 0          # >1: group-limited dispatch (§Perf iter 3)
    # vlm (llama-3.2-vision): one cross-attn layer per `cross_attn_every`
    cross_attn_every: int = 0
    n_image_tokens: int = 4096
    # enc-dec (seamless): encoder depth; decoder length = seq // dec_ratio
    n_enc_layers: int = 0
    dec_ratio: int = 4
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0          # zamba2: shared attn block every k mamba layers
    is_rwkv: bool = False
    # execution
    sharding_mode: str = "megatron"   # "cascade" = paper-faithful baseline
    microbatches: int = 1             # gradient-accumulation factor
    remat: bool = True
    q_chunk: int = 512
    ssd_chunk: int = 128
    optimizer: str = "adamw"     # "adafactor" for the very large configs
    quantized: bool = False      # serve: int8 qmatmul LM head (--quantized)
    # serve: also route the MLP down-projection through the qmatmul kernel
    # (a16w8: int16 activations, int8 weights, int16 SRS out). The shifts
    # are per-tensor calibrated by the plan's Quantize pass
    # (repro.plan.passes.calibrate_mlp_shifts); the defaults below are the
    # analytic fallback for silu-gated activations on unit-RMS inputs
    # (absmax < 16 -> x_shift 11, fan-in-scaled weights -> w_shift 8).
    quantized_mlp: bool = False
    mlp_x_shift: int = 11
    mlp_w_shift: int = 8
    mlp_out_shift: int = 11
    notes: str = ""

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only SSM/hybrid/linear-attention
# families run it (see DESIGN.md §5 for the skip list).
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def supports_shape(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in _LONG_OK_FAMILIES:
        return False, (
            "pure full-attention architecture: 500k-token decode would need "
            "sub-quadratic attention (skip noted in DESIGN.md)"
        )
    return True, ""


def token_input_specs(batch: int, seq: int) -> Dict[str, Any]:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


def stackify(tree, n: int):
    """Prepend a scan-layer dim to every ParamSpec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical,
                            s.dtype, s.init, s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def state_batch_axes(sspecs):
    """Per-leaf index of the "batch" logical axis in a decode-state tree.

    Continuous batching resets ONE batch lane of a live state (a reused
    slot must not inherit its predecessor's KV/SSM); the specs name the
    batch axis logically, so the lookup works for KV caches and SSM/conv
    states alike. Shared by the in-step fresh lane
    (``make_masked_decode_step``) and the host-side
    ``StatePool.reset_slots`` so the two resets can never diverge.

    Leaves with no batch axis map to ``-1`` (a sentinel, not ``None`` —
    ``None`` is an empty pytree node and would break the tree.map) — the
    paged KV pool has a page axis instead of a batch axis, needs no
    per-slot wipe (stale pages are masked by the local-position validity
    window), and :func:`wipe_state_slots` skips them.
    """
    return jax.tree.map(
        lambda s: s.logical.index("batch") if "batch" in s.logical else -1,
        sspecs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def wipe_state_slots(state, slot_mask, batch_axes):
    """Zero the masked batch lanes of every state leaf.

    ``slot_mask`` is a [batch] bool vector; ``batch_axes`` comes from
    :func:`state_batch_axes` over the matching decode-state specs.
    Leaves whose axis entry is ``-1`` (the shared page pool) pass
    through untouched. Traceable (used inside the masked decode step)
    and jit-friendly with donation (used by the pool's per-slot reset).
    """
    batch = slot_mask.shape[0]

    def one(leaf, axis):
        if axis < 0:
            return leaf
        shape = [1] * leaf.ndim
        shape[axis] = batch
        return jnp.where(slot_mask.reshape(shape), jnp.zeros_like(leaf),
                         leaf)

    return jax.tree.map(one, state, batch_axes)


class PageView(NamedTuple):
    """Traceable view of the paged KV layout for one decode step.

    ``table`` [B, max_len // page_size] int32 maps each slot's logical
    page index to a physical page in the shared pool; ``local_pos`` [B]
    int32 is each slot's position in its OWN sequence (the page-local
    coordinate system — RoPE and cache indexing both use it, which is
    what makes prefix pages position-independent and bit-reusable);
    ``page_size`` is static. See ``docs/memory_model.md``.
    """

    table: Any
    local_pos: Any
    page_size: int


# Decode-state leaves that move to the paged layout. Cross-attention
# caches (``cross_k``/``cross_v``) and recurrent SSM/conv/RWKV state are
# per-slot by construction and stay dense.
PAGED_STATE_KEYS = ("cache_k", "cache_v")


def is_paged_state_key(name: str) -> bool:
    """True for leaves that live in the shared page pool: the
    self-attention KV caches and their ``draft_``-prefixed speculative
    twins (the draft's KV rides the SAME page tables — one page id
    indexes both pools at matching local positions)."""
    if name in PAGED_STATE_KEYS:
        return True
    return (name.startswith("draft_")
            and name[len("draft_"):] in PAGED_STATE_KEYS)


def paged_state_specs(sspecs, page_count: int, page_size: int):
    """Rewrite self-attention KV leaves to the shared-pool layout.

    A dense leaf ``[..., batch, max_len, kv, hd]`` (axes ``...,
    "batch", "seq", ...``) becomes ``[..., page_count, page_size, kv,
    hd]`` with both new axes replicated (logical ``None``): pages are
    shared between slots and buckets, so neither maps onto a mesh data
    axis. Head/hd sharding is preserved. ``draft_``-prefixed KV twins
    (speculative lanes) are rewritten the same way — they share the
    slot's page table, so their pool has the same page axes. All other
    leaves — cross caches, SSM/conv/RWKV state — pass through unchanged.
    """
    out = {}
    for name, s in sspecs.items():
        if not is_paged_state_key(name):
            out[name] = s
            continue
        b = s.logical.index("batch")
        q = s.logical.index("seq")
        assert q == b + 1, (name, s.logical)
        shape = s.shape[:b] + (page_count, page_size) + s.shape[q + 1:]
        logical = s.logical[:b] + (None, None) + s.logical[q + 1:]
        out[name] = ParamSpec(shape, logical, s.dtype, "zeros")
    return out


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense", "moe"):
        from repro.models.lm import DecoderLM
        return DecoderLM(cfg)
    if cfg.family == "vlm":
        from repro.models.vision_lm import VisionLM
        return VisionLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv_model import RWKVModel
        return RWKVModel(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridModel
        return HybridModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")


# ---------------------------------------------------------------------------
# Shared decode head: fp32 einsum, or the paper's int8 qmatmul path
# ---------------------------------------------------------------------------


def decode_head_logits(head_w: jnp.ndarray, x: jnp.ndarray,
                       cfg: ArchConfig) -> jnp.ndarray:
    """Final-token logits [B, V] from decode hiddens ``x`` [B, 1, d].

    With ``cfg.quantized`` the projection routes through the Pallas
    qmatmul kernel (int8 operands, int16 SRS output): the GEMV that
    dominates the decode step is exactly the op the paper quantizes.
    Shifts are sized to the observed ranges: rmsnorm'd activations (unit
    RMS, absmax just under 4 -> x_shift 5) and fan-in-scaled head weights
    (absmax just under 0.5 -> w_shift 8); out_shift 11 keeps ~5e-4 logit
    resolution over a +-16 range. Greedy argmax matches the float path on
    the debug configs; logit gaps below the ~0.05 quantization noise can
    still flip — that is the int8 contract, not a bug.
    """
    if cfg.quantized:
        from repro.layers.linear import quantized_linear

        return quantized_linear(
            {"w": head_w}, x[:, 0],
            x_shift=5, w_shift=8, out_shift=11, out_dtype="int16",
            out_float_dtype=jnp.float32,
        )
    return jnp.einsum("bsd,dv->bsv", x, head_w,
                      preferred_element_type=jnp.float32)[:, 0]


def decode_block_head_logits(head_w: jnp.ndarray, x: jnp.ndarray,
                             cfg: ArchConfig) -> jnp.ndarray:
    """Logits [B, m, V] for a block of m decode hiddens ``x`` [B, m, d].

    The block form of :func:`decode_head_logits` (same shifts, same int8
    contract on the quantized path) for speculative block verification:
    the target model scores every position of a drafted micro-run in one
    projection instead of m GEMVs.
    """
    if cfg.quantized:
        from repro.layers.linear import quantized_linear

        return quantized_linear(
            {"w": head_w}, x,
            x_shift=5, w_shift=8, out_shift=11, out_dtype="int16",
            out_float_dtype=jnp.float32,
        )
    return jnp.einsum("bsd,dv->bsv", x, head_w,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Self-speculative draft: an early-exit layer prefix of the target
# ---------------------------------------------------------------------------


def spec_state_specs(sspecs, draft_layers: int, prefix: str = "draft_"):
    """Draft-model decode-state leaves for the layer-prefix draft.

    Every target state leaf with a ``"layers"`` logical axis gets a
    ``draft_``-prefixed twin whose layers dim is ``draft_layers`` — the
    KV the self-speculative draft (the first ``draft_layers`` blocks of
    the target, sharing embed/final-norm/head) accumulates while it
    proposes tokens. Merging these into the target's state pytree keeps
    the whole StatePool lifecycle (acquire/release, donated per-slot
    wipes, batch-axis discovery) a single uniform tree.
    """
    out = {}
    for name, s in sspecs.items():
        li = s.logical.index("layers")
        shape = s.shape[:li] + (draft_layers,) + s.shape[li + 1:]
        out[prefix + name] = ParamSpec(shape, s.logical, s.dtype, s.init)
    return out


def split_spec_state(state, prefix: str = "draft_"):
    """Split a merged decode state into (target tree, draft tree).

    The draft tree's keys have the prefix stripped so the same
    ``decode_block`` consumes either half.
    """
    target = {k: v for k, v in state.items() if not k.startswith(prefix)}
    draft = {k[len(prefix):]: v for k, v in state.items()
             if k.startswith(prefix)}
    return target, draft


def draft_prefix_params(params, draft_layers: int):
    """The self-speculative draft's parameter view: the target's stacked
    blocks sliced to the first ``draft_layers`` layers, embed/ln_f/head
    shared verbatim. A pure (traceable) slice — no extra parameters, no
    extra host transfer."""
    out = dict(params)
    out["blocks"] = jax.tree.map(lambda a: a[:draft_layers],
                                 params["blocks"])
    return out


# ---------------------------------------------------------------------------
# Shared loss: chunked cross-entropy that never materializes [B,S,V] fp32
# ---------------------------------------------------------------------------


def lm_loss_chunked(
    head_w: jnp.ndarray,     # [d, V]
    x: jnp.ndarray,          # [B, S, d] final hidden states
    labels: jnp.ndarray,     # [B, S] int32 (next-token targets)
    chunk: int = 512,
) -> jnp.ndarray:
    """Mean next-token CE, computed per sequence chunk under remat so the
    full logits tensor is never resident (vocab can be 150k+)."""
    B, S, d = x.shape
    if S % chunk:
        chunk = S
    n = S // chunk
    xc = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(acc, inp):
        xb, lb = inp
        logits = jnp.einsum("bsd,dv->bsv", xb, head_w,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)
