"""Zamba2-2.7B-class hybrid: Mamba2 backbone + SHARED attention block.

54 Mamba2 layers in 9 groups of 6; after each group the same (weight-shared)
attention+MLP block is applied — the extreme case of the paper's
weights-resident-on-chip principle (one block's weights serve 9 call sites).
Decode keeps O(1) SSM state per layer plus one KV cache per shared-block
call site, so ``long_500k`` runs (linear per-token cost).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.embedding import embed, embedding_spec, lm_head_spec
from repro.layers.norm import rmsnorm, rmsnorm_spec
from repro.layers.ssm import mamba2, mamba2_decode, mamba2_spec
from repro.models.base import (
    ArchConfig,
    decode_head_logits,
    lm_loss_chunked,
    stackify,
    token_input_specs,
)
from repro.models.blocks import attn_block, attn_block_decode, attn_block_spec


class HybridModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
        self.n_groups = cfg.n_layers // cfg.attn_every
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.ssm_heads = self.d_inner // cfg.ssm_head_dim

    def _mamba_layer_spec(self):
        cfg = self.cfg
        return {
            "ln": rmsnorm_spec(cfg.d_model),
            "mamba": mamba2_spec(
                cfg.d_model, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                mode=cfg.sharding_mode,
            ),
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": embedding_spec(cfg.vocab, cfg.d_model),
            "mamba_blocks": stackify(
                stackify(self._mamba_layer_spec(), cfg.attn_every),
                self.n_groups,
            ),
            # ONE shared attention block (not stacked): reused by all groups
            "shared_attn": attn_block_spec(cfg),
            "ln_f": rmsnorm_spec(cfg.d_model),
            "head": lm_head_spec(cfg.d_model, cfg.vocab),
        }

    def backbone(self, params, tokens):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        shared = params["shared_attn"]

        def group(x, mamba_stack):
            def inner(x, layer_params):
                h = rmsnorm(layer_params["ln"], x)
                x = x + mamba2(
                    layer_params["mamba"], h,
                    head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                    chunk=cfg.ssd_chunk,
                )
                return shard_act(x, "batch", "seq", "act_embed"), None

            x, _ = jax.lax.scan(inner, x, mamba_stack)
            x, _ = attn_block(shared, x, positions, cfg)
            return x, None

        fn = jax.checkpoint(group) if cfg.remat else group
        x, _ = jax.lax.scan(fn, x, params["mamba_blocks"])
        return rmsnorm(params["ln_f"], x)

    def forward(self, params, batch: Dict) -> jnp.ndarray:
        x = self.backbone(params, batch["tokens"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
        return shard_act(logits, "batch", "seq", "vocab")

    def loss(self, params, batch: Dict) -> jnp.ndarray:
        x = self.backbone(params, batch["tokens"])
        return lm_loss_chunked(params["head"]["w"], x, batch["labels"])

    # -- decode ---------------------------------------------------------------

    def decode_state_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        G, E = self.n_groups, cfg.attn_every
        H, P, N = self.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        return {
            "ssm": ParamSpec((G, E, batch, H, P, N),
                             ("layers", "layers", "batch", "mlp", None, None),
                             jnp.float32, "zeros"),
            "conv": ParamSpec((G, E, batch, 3, self.d_inner),
                              ("layers", "layers", "batch", None, "act_mlp"),
                              jnp.float32, "zeros"),
            "cache_k": ParamSpec(
                (G, batch, max_len, cfg.n_kv, cfg.head_dim),
                ("layers", "batch", "seq", "cache_heads", "cache_hd"),
                jnp.bfloat16, "zeros"),
            "cache_v": ParamSpec(
                (G, batch, max_len, cfg.n_kv, cfg.head_dim),
                ("layers", "batch", "seq", "cache_heads", "cache_hd"),
                jnp.bfloat16, "zeros"),
        }

    def decode_step(self, params, state: Dict, tokens, pos, *,
                    window_start=None, pages=None):
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None])
        shared = params["shared_attn"]

        def group(x, inp):
            mamba_stack, ssm_states, conv_states, ck, cv = inp

            def inner(x, inp2):
                layer_params, s, c = inp2
                h = rmsnorm(layer_params["ln"], x)
                o, s, c = mamba2_decode(
                    layer_params["mamba"], h, s, c,
                    head_dim=cfg.ssm_head_dim,
                )
                return x + o, (s, c)

            x, (ssm_states, conv_states) = jax.lax.scan(
                inner, x, (mamba_stack, ssm_states, conv_states)
            )
            x, ck, cv = attn_block_decode(shared, x, ck, cv, pos, cfg,
                                          window_start=window_start,
                                          pages=pages)
            return x, (ssm_states, conv_states, ck, cv)

        x, (ssm, conv, ck, cv) = jax.lax.scan(
            group, x,
            (params["mamba_blocks"], state["ssm"], state["conv"],
             state["cache_k"], state["cache_v"]),
        )
        x = rmsnorm(params["ln_f"], x)
        logits = decode_head_logits(params["head"]["w"], x, cfg)
        return logits, {"ssm": ssm, "conv": conv, "cache_k": ck,
                        "cache_v": cv}

    def input_specs(self, shape) -> Dict:
        if shape.kind in ("train", "prefill"):
            return token_input_specs(shape.global_batch, shape.seq_len)
        return {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
