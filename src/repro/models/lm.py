"""Decoder-only LM (dense GQA and MoE variants).

Covers: yi-6b, qwen1.5-4b, qwen1.5-110b, mistral-large-123b,
phi3.5-moe-42b-a6.6b, kimi-k2-1t-a32b.

Layers run under a single lax.scan over stacked parameters (HLO size O(1)
in depth); each block is rematerialized when cfg.remat is set.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.embedding import embed, embedding_spec, lm_head_spec
from repro.layers.norm import rmsnorm, rmsnorm_spec
from repro.models.base import (
    ArchConfig,
    decode_block_head_logits,
    decode_head_logits,
    lm_loss_chunked,
    stackify,
    token_input_specs,
)
from repro.models.blocks import attn_block, attn_block_decode, attn_block_spec


class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.use_moe = cfg.family == "moe"

    # -- parameters -----------------------------------------------------------

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": embedding_spec(cfg.vocab, cfg.d_model),
            "blocks": stackify(
                attn_block_spec(cfg, use_moe=self.use_moe), cfg.n_layers
            ),
            "ln_f": rmsnorm_spec(cfg.d_model),
            "head": lm_head_spec(cfg.d_model, cfg.vocab),
        }

    # -- training / prefill ---------------------------------------------------

    def backbone(self, params, tokens: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(carry, layer_params):
            x, aux = carry
            x, a = attn_block(layer_params, x, positions, cfg)
            return (x, aux + a), None

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(
            fn, (x, jnp.zeros((), jnp.float32)), params["blocks"]
        )
        x = rmsnorm(params["ln_f"], x)
        return x, aux

    def forward(self, params, batch: Dict) -> jnp.ndarray:
        """Prefill entry point: full logits."""
        x, _ = self.backbone(params, batch["tokens"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
        return shard_act(logits, "batch", "seq", "vocab")

    def loss(self, params, batch: Dict) -> jnp.ndarray:
        x, aux = self.backbone(params, batch["tokens"])
        ce = lm_loss_chunked(params["head"]["w"], x, batch["labels"])
        return ce + 0.01 * aux

    # -- decode ---------------------------------------------------------------

    def decode_state_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
        axes = ("layers", "batch", "seq", "cache_heads", "cache_hd")
        return {
            "cache_k": ParamSpec(shape, axes, jnp.bfloat16, init="zeros"),
            "cache_v": ParamSpec(shape, axes, jnp.bfloat16, init="zeros"),
        }

    def decode_step(self, params, state: Dict, tokens: jnp.ndarray,
                    pos: jnp.ndarray, *, window_start=None, pages=None):
        """One token for every sequence. tokens [B] int32; pos [] int32.

        ``window_start`` ([B] int32, optional) limits each slot's
        attention to cache positions >= its own window start — the
        continuous-batching slot-reuse contract (see
        ``make_masked_decode_step``). With ``pages`` (a
        ``models.base.PageView``) the KV leaves are the shared page pool
        instead of per-slot slabs and ``window_start`` is unused: each
        slot indexes (and RoPE-rotates at) its own local position.
        """
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None])

        def body(x, inp):
            layer_params, ck, cv = inp
            x, ck, cv = attn_block_decode(layer_params, x, ck, cv, pos, cfg,
                                          window_start=window_start,
                                          pages=pages)
            return x, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            body, x, (params["blocks"], state["cache_k"], state["cache_v"])
        )
        x = rmsnorm(params["ln_f"], x)
        logits = decode_head_logits(params["head"]["w"], x, cfg)
        return logits, {"cache_k": ck, "cache_v": cv}

    def decode_block(self, params, state: Dict, tokens: jnp.ndarray,
                     local: jnp.ndarray, *, pages=None):
        """Score a block of m consecutive tokens per sequence in one pass.

        tokens [B, m] int32; ``local`` [B] int32 is each slot's LOCAL
        position for ``tokens[:, 0]`` (see
        ``block_decode_self_attention`` for the coordinate contract —
        RoPE, cache writes, and the per-query validity mask all use
        ``local[b] + j``). With ``pages`` (a ``models.base.PageView``
        whose ``local_pos`` equals ``local``) the KV leaves are the
        shared page pool and the writes land in the slot's page run.
        Returns (logits [B, m, V], state):
        ``logits[b, j]`` is the next-token distribution after consuming
        ``tokens[b, :j+1]``, exactly what ``m`` sequential
        ``decode_step`` calls would produce up to float re-association.
        This is both the speculative draft's step (m == 1) and the
        target's teacher-forced verify pass (m == micro-run length).
        """
        cfg = self.cfg
        x = embed(params["embed"], tokens)

        def body(x, inp):
            layer_params, ck, cv = inp
            x, ck, cv = attn_block_decode(layer_params, x, ck, cv, None,
                                          cfg, local=local, pages=pages)
            return x, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            body, x, (params["blocks"], state["cache_k"], state["cache_v"])
        )
        x = rmsnorm(params["ln_f"], x)
        logits = decode_block_head_logits(params["head"]["w"], x, cfg)
        return logits, {"cache_k": ck, "cache_v": cv}

    # -- dry-run input specs --------------------------------------------------

    def input_specs(self, shape) -> Dict:
        if shape.kind in ("train", "prefill"):
            return token_input_specs(shape.global_batch, shape.seq_len)
        return {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
