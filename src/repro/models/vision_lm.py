"""Llama-3.2-Vision-90B-class model: decoder LM with interleaved
cross-attention layers over precomputed vision patch embeddings.

100 layers = 20 super-blocks x (4 self-attn + 1 gated cross-attn). The
vision frontend is a stub per the assignment: ``input_specs`` supplies
patch embeddings [B, n_image_tokens, d_model].
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.attention import mha
from repro.layers.embedding import embed, embedding_spec, lm_head_spec
from repro.layers.linear import linear
from repro.layers.norm import rmsnorm, rmsnorm_spec
from repro.models.base import ArchConfig, lm_loss_chunked, stackify
from repro.models.blocks import (
    attn_block,
    attn_block_decode,
    attn_block_spec,
    cross_block,
    make_cross_block_spec,
)


class VisionLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.cross_attn_every > 1
        self.self_per_block = cfg.cross_attn_every - 1
        assert cfg.n_layers % cfg.cross_attn_every == 0
        self.n_super = cfg.n_layers // cfg.cross_attn_every

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": embedding_spec(cfg.vocab, cfg.d_model),
            "self_blocks": stackify(
                stackify(attn_block_spec(cfg), self.self_per_block),
                self.n_super,
            ),
            "cross_blocks": stackify(make_cross_block_spec(cfg), self.n_super),
            "ln_f": rmsnorm_spec(cfg.d_model),
            "head": lm_head_spec(cfg.d_model, cfg.vocab),
        }

    def backbone(self, params, tokens, vision):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        vision = shard_act(vision, "batch", "seq", "act_embed")

        def superblock(x, inp):
            selfs, cross = inp

            def inner(x, layer_params):
                x, _ = attn_block(layer_params, x, positions, cfg)
                return x, None

            x, _ = jax.lax.scan(inner, x, selfs)
            x = cross_block(cross, x, vision, cfg)
            return x, None

        fn = jax.checkpoint(superblock) if cfg.remat else superblock
        x, _ = jax.lax.scan(
            fn, x, (params["self_blocks"], params["cross_blocks"])
        )
        return rmsnorm(params["ln_f"], x)

    def forward(self, params, batch: Dict) -> jnp.ndarray:
        x = self.backbone(params, batch["tokens"], batch["vision"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
        return shard_act(logits, "batch", "seq", "vocab")

    def loss(self, params, batch: Dict) -> jnp.ndarray:
        x = self.backbone(params, batch["tokens"], batch["vision"])
        return lm_loss_chunked(params["head"]["w"], x, batch["labels"])

    # -- decode ---------------------------------------------------------------

    def decode_state_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        kv_shape = (self.n_super, self.self_per_block, batch, max_len,
                    cfg.n_kv, cfg.head_dim)
        kv_axes = ("layers", "layers", "batch", "seq", "cache_heads",
                   "cache_hd")
        xk_shape = (self.n_super, batch, cfg.n_image_tokens, cfg.n_kv,
                    cfg.head_dim)
        xk_axes = ("layers", "batch", "seq", "cache_heads", "cache_hd")
        return {
            "cache_k": ParamSpec(kv_shape, kv_axes, jnp.bfloat16, "zeros"),
            "cache_v": ParamSpec(kv_shape, kv_axes, jnp.bfloat16, "zeros"),
            "cross_k": ParamSpec(xk_shape, xk_axes, jnp.bfloat16, "zeros"),
            "cross_v": ParamSpec(xk_shape, xk_axes, jnp.bfloat16, "zeros"),
        }

    def init_cross_cache(self, params, vision):
        """Precompute per-superblock cross K/V from vision embeddings."""
        cfg = self.cfg
        B, M, _ = vision.shape

        def one(cross):
            k = linear(cross["xattn"]["wk"], vision).reshape(
                B, M, cfg.n_kv, cfg.head_dim)
            v = linear(cross["xattn"]["wv"], vision).reshape(
                B, M, cfg.n_kv, cfg.head_dim)
            return k, v

        ks, vs = jax.vmap(one)(params["cross_blocks"])
        return ks, vs

    def decode_step(self, params, state: Dict, tokens, pos, *,
                    window_start=None, pages=None):
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None])
        B = x.shape[0]

        def superblock(x, inp):
            selfs, cross, ck, cv, xk, xv = inp

            def inner(x, inp2):
                layer_params, k1, v1 = inp2
                x, k1, v1 = attn_block_decode(layer_params, x, k1, v1, pos,
                                              cfg, window_start=window_start,
                                              pages=pages)
                return x, (k1, v1)

            x, (ck, cv) = jax.lax.scan(inner, x, (selfs, ck, cv))
            # gated cross-attention against the precomputed vision cache
            h = rmsnorm(cross["ln1"], x)
            q = linear(cross["xattn"]["wq"], h).reshape(
                B, 1, cfg.n_heads, cfg.head_dim)
            o = mha(q, xk, xv, causal=False)
            h = linear(cross["xattn"]["wo"],
                       o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
            gate = jnp.tanh(cross["gate"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * h
            h = rmsnorm(cross["ln2"], x)
            from repro.layers.mlp import swiglu
            x = x + swiglu(cross["ffn"], h)
            return x, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            superblock, x,
            (params["self_blocks"], params["cross_blocks"],
             state["cache_k"], state["cache_v"],
             state["cross_k"], state["cross_v"]),
        )
        x = rmsnorm(params["ln_f"], x)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)[:, 0]
        state = dict(state, cache_k=ck, cache_v=cv)
        return logits, state

    def input_specs(self, shape) -> Dict:
        cfg = self.cfg
        B = shape.global_batch
        if shape.kind in ("train", "prefill"):
            return {
                "tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32),
                "vision": jax.ShapeDtypeStruct(
                    (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
