"""Seamless-M4T-v2-class encoder-decoder (audio frontend stubbed).

Encoder: bidirectional self-attn + GELU MLP over precomputed frame
embeddings. Decoder: causal self-attn + cross-attn + GELU MLP over text
tokens. LayerNorm (not RMSNorm) per the original architecture. Decoder
length = seq_len // dec_ratio for train/prefill shapes (frames dominate).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.attention import (
    attention_spec,
    cross_attention,
    decode_self_attention,
    mha,
    paged_decode_self_attention,
    self_attention,
)
from repro.layers.embedding import embed, embedding_spec, lm_head_spec
from repro.layers.linear import linear, linear_spec
from repro.layers.mlp import mlp, mlp_spec
from repro.layers.norm import layernorm, layernorm_spec
from repro.models.base import ArchConfig, lm_loss_chunked, stackify


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.n_enc = cfg.n_enc_layers or cfg.n_layers

    def _enc_block_spec(self):
        cfg = self.cfg
        return {
            "ln1": layernorm_spec(cfg.d_model),
            "attn": attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim, cfg.sharding_mode),
            "ln2": layernorm_spec(cfg.d_model),
            "ffn": mlp_spec(cfg.d_model, cfg.d_ff, cfg.sharding_mode),
        }

    def _dec_block_spec(self):
        cfg = self.cfg
        spec = self._enc_block_spec()
        spec["ln_x"] = layernorm_spec(cfg.d_model)
        spec["xattn"] = {
            "wq": linear_spec(cfg.d_model, cfg.n_heads * cfg.head_dim, "col",
                              cfg.sharding_mode),
            "wk": linear_spec(cfg.d_model, cfg.n_kv * cfg.head_dim, "kv",
                              cfg.sharding_mode),
            "wv": linear_spec(cfg.d_model, cfg.n_kv * cfg.head_dim, "kv",
                              cfg.sharding_mode),
            "wo": linear_spec(cfg.n_heads * cfg.head_dim, cfg.d_model, "row",
                              cfg.sharding_mode),
        }
        return spec

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": embedding_spec(cfg.vocab, cfg.d_model),
            "enc_blocks": stackify(self._enc_block_spec(), self.n_enc),
            "dec_blocks": stackify(self._dec_block_spec(), cfg.n_layers),
            "ln_enc": layernorm_spec(cfg.d_model),
            "ln_f": layernorm_spec(cfg.d_model),
            "head": lm_head_spec(cfg.d_model, cfg.vocab),
        }

    # -- encoder --------------------------------------------------------------

    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        B, S, _ = frames.shape
        x = shard_act(frames, "batch", "seq", "act_embed")
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, layer_params):
            h = layernorm(layer_params["ln1"], x)
            h = self_attention(
                layer_params["attn"], h, positions,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                causal=False, q_chunk=cfg.q_chunk,
            )
            x = x + h
            h = layernorm(layer_params["ln2"], x)
            x = x + mlp(layer_params["ffn"], h, act="gelu")
            return shard_act(x, "batch", "seq", "act_embed"), None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
        return layernorm(params["ln_enc"], x)

    # -- decoder --------------------------------------------------------------

    def decode_stack(self, params, tokens: jnp.ndarray, memory: jnp.ndarray):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, layer_params):
            h = layernorm(layer_params["ln1"], x)
            h = self_attention(
                layer_params["attn"], h, positions,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                causal=True, q_chunk=cfg.q_chunk,
            )
            x = x + h
            h = layernorm(layer_params["ln_x"], x)
            h = cross_attention(
                layer_params["xattn"], h, memory,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                q_chunk=cfg.q_chunk,
            )
            x = x + h
            h = layernorm(layer_params["ln2"], x)
            x = x + mlp(layer_params["ffn"], h, act="gelu")
            return shard_act(x, "batch", "seq", "act_embed"), None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
        return layernorm(params["ln_f"], x)

    def forward(self, params, batch: Dict) -> jnp.ndarray:
        memory = self.encode(params, batch["frames"])
        x = self.decode_stack(params, batch["tokens"], memory)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
        return shard_act(logits, "batch", "seq", "vocab")

    def loss(self, params, batch: Dict) -> jnp.ndarray:
        memory = self.encode(params, batch["frames"])
        x = self.decode_stack(params, batch["tokens"], memory)
        return lm_loss_chunked(params["head"]["w"], x, batch["labels"])

    # -- decode ---------------------------------------------------------------

    def decode_state_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        mem_len = max(max_len // cfg.dec_ratio, 128)
        kv = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
        xkv = (cfg.n_layers, batch, mem_len, cfg.n_kv, cfg.head_dim)
        axes = ("layers", "batch", "seq", "cache_heads", "cache_hd")
        return {
            "cache_k": ParamSpec(kv, axes, jnp.bfloat16, "zeros"),
            "cache_v": ParamSpec(kv, axes, jnp.bfloat16, "zeros"),
            "cross_k": ParamSpec(xkv, axes, jnp.bfloat16, "zeros"),
            "cross_v": ParamSpec(xkv, axes, jnp.bfloat16, "zeros"),
        }

    def decode_step(self, params, state: Dict, tokens, pos, *,
                    window_start=None, pages=None):
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None])
        B = x.shape[0]

        def body(x, inp):
            layer_params, ck, cv, xk, xv = inp
            h = layernorm(layer_params["ln1"], x)
            if pages is not None:
                h, ck, cv = paged_decode_self_attention(
                    layer_params["attn"], h, ck, cv, pages,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                    head_dim=cfg.head_dim,
                )
            else:
                h, ck, cv = decode_self_attention(
                    layer_params["attn"], h, ck, cv, pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                    head_dim=cfg.head_dim, window_start=window_start,
                )
            x = x + h
            h = layernorm(layer_params["ln_x"], x)
            q = linear(layer_params["xattn"]["wq"], h).reshape(
                B, 1, cfg.n_heads, cfg.head_dim)
            o = mha(q, xk, xv, causal=False)
            h = linear(layer_params["xattn"]["wo"],
                       o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
            x = x + h
            h = layernorm(layer_params["ln2"], x)
            x = x + mlp(layer_params["ffn"], h, act="gelu")
            return x, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            body, x,
            (params["dec_blocks"], state["cache_k"], state["cache_v"],
             state["cross_k"], state["cross_v"]),
        )
        x = layernorm(params["ln_f"], x)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)[:, 0]
        return logits, dict(state, cache_k=ck, cache_v=cv)

    def input_specs(self, shape) -> Dict:
        cfg = self.cfg
        B = shape.global_batch
        if shape.kind in ("train", "prefill"):
            dec_len = max(shape.seq_len // cfg.dec_ratio, 128)
            return {
                "frames": jax.ShapeDtypeStruct(
                    (B, shape.seq_len, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, dec_len), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, dec_len), jnp.int32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
