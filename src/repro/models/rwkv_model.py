"""RWKV6-7B (Finch): attention-free decoder LM.

Decode state is O(1) per layer (token-shift carries + the P x P wkv state),
so ``long_500k`` runs with constant memory — this is one of the two archs
where the assignment's long-context cell executes.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.embedding import embed, embedding_spec, lm_head_spec
from repro.layers.norm import layernorm, layernorm_spec
from repro.layers.rwkv import (
    rwkv6_channel_mix,
    rwkv6_spec,
    rwkv6_time_mix,
)
from repro.models.base import (
    ArchConfig,
    decode_head_logits,
    lm_loss_chunked,
    stackify,
    token_input_specs,
)


class RWKVModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.head_dim = cfg.ssm_head_dim or 64
        self.n_heads = cfg.d_model // self.head_dim

    def _layer_spec(self):
        cfg = self.cfg
        return {
            "ln1": layernorm_spec(cfg.d_model),
            "ln2": layernorm_spec(cfg.d_model),
            "mix": rwkv6_spec(cfg.d_model, cfg.d_ff, head_dim=self.head_dim,
                              mode=cfg.sharding_mode),
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": embedding_spec(cfg.vocab, cfg.d_model),
            "ln0": layernorm_spec(cfg.d_model),
            "blocks": stackify(self._layer_spec(), cfg.n_layers),
            "ln_f": layernorm_spec(cfg.d_model),
            "head": lm_head_spec(cfg.d_model, cfg.vocab),
        }

    def backbone(self, params, tokens):
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        x = layernorm(params["ln0"], x)

        def body(x, layer_params):
            h = layernorm(layer_params["ln1"], x)
            x = x + rwkv6_time_mix(layer_params["mix"], h,
                                   head_dim=self.head_dim)
            h = layernorm(layer_params["ln2"], x)
            x = x + rwkv6_channel_mix(layer_params["mix"], h)
            return shard_act(x, "batch", "seq", "act_embed"), None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["blocks"])
        return layernorm(params["ln_f"], x)

    def forward(self, params, batch: Dict) -> jnp.ndarray:
        x = self.backbone(params, batch["tokens"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
        return shard_act(logits, "batch", "seq", "vocab")

    def loss(self, params, batch: Dict) -> jnp.ndarray:
        x = self.backbone(params, batch["tokens"])
        return lm_loss_chunked(params["head"]["w"], x, batch["labels"])

    # -- decode (O(1) state; no KV cache) -------------------------------------

    def decode_state_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        L, D = cfg.n_layers, cfg.d_model
        H, P = self.n_heads, self.head_dim
        return {
            "tm_prev": ParamSpec((L, batch, D), ("layers", "batch", None),
                                 jnp.bfloat16, "zeros"),
            "cm_prev": ParamSpec((L, batch, D), ("layers", "batch", None),
                                 jnp.bfloat16, "zeros"),
            "wkv": ParamSpec((L, batch, H, P, P),
                             ("layers", "batch", "act_heads", None, None),
                             jnp.float32, "zeros"),
        }

    def decode_step(self, params, state: Dict, tokens, pos, *,
                    window_start=None, pages=None):
        cfg = self.cfg
        del pos, window_start, pages  # recurrent: position-free, and the
        # paged layout has no KV leaves here; slot reuse only needs the
        # fresh-lane state reset (no KV cache to window)
        x = embed(params["embed"], tokens[:, None])
        x = layernorm(params["ln0"], x)

        def body(x, inp):
            layer_params, tm_prev, cm_prev, wkv = inp
            h = layernorm(layer_params["ln1"], x)
            o, tm_new, wkv = rwkv6_time_mix(
                layer_params["mix"], h, head_dim=self.head_dim,
                tm_prev=tm_prev, wkv_state=wkv, return_state=True,
            )
            x = x + o
            h = layernorm(layer_params["ln2"], x)
            o, cm_new = rwkv6_channel_mix(
                layer_params["mix"], h, cm_prev=cm_prev, return_state=True,
            )
            x = x + o
            return x, (tm_new.astype(jnp.bfloat16),
                       cm_new.astype(jnp.bfloat16), wkv)

        x, (tm, cm, wkv) = jax.lax.scan(
            body, x,
            (params["blocks"], state["tm_prev"], state["cm_prev"],
             state["wkv"]),
        )
        x = layernorm(params["ln_f"], x)
        logits = decode_head_logits(params["head"]["w"], x, self.cfg)
        return logits, {"tm_prev": tm, "cm_prev": cm, "wkv": wkv}

    def input_specs(self, shape) -> Dict:
        if shape.kind in ("train", "prefill"):
            return token_input_specs(shape.global_batch, shape.seq_len)
        return {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
