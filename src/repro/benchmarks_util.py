"""Shared helpers for the benchmark suite."""

from repro.core.device import AIEMLDevice


def gemm_full_array_efficiency(n_tiles: int = 296) -> float:
    """Modeled GEMM-only efficiency at full-array utilization (the paper's
    82.2%-of-INT8-peak headline): per-tile kernel efficiency x spatial
    utilization, with cascade/memtile overheads from the cycle model."""
    dev = AIEMLDevice()
    kernel_gops = dev.kernel_gops(128, 256, 256, "int8", "int8")
    per_tile_eff = kernel_gops / dev.peak_gops("int8", "int8")
    spatial = n_tiles / (dev.n_cols * dev.n_rows)
    # cascade fill + re-tiling overhead at array scale (calibrated; see
    # benchmarks/fig4_scaling.py)
    array_overhead = 0.875
    return per_tile_eff * spatial * array_overhead
