"""Import-graph construction and re-export resolution.

The layering rule needs more than "does this file mention a banned
name": a thin client can launder a low-level import through a package
``__init__`` (``from repro.serve import X`` where ``repro.serve``
re-exports ``X`` from a banned module). This module builds the
module-level import graph over every analyzed file and resolves
``(module, name)`` pairs through chains of ``from A import B``
re-exports to the module that actually defines the name.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

from .engine import Module, SourceTree


@dataclasses.dataclass(frozen=True)
class ImportEdge:
    """One imported binding at module top level."""

    module: str            # source module ("repro.serve.cache"); "" for bare
    name: str              # imported symbol; "" for `import x` / `import *`
    bound_as: str          # local binding name
    line: int


def _resolve_relative(importer: str, module: Optional[str],
                      level: int) -> str:
    """Absolute module path for a (possibly relative) ImportFrom."""
    if level == 0:
        return module or ""
    parts = importer.split(".")
    # level 1 = current package: drop the module's own leaf name.
    base = parts[:-level] if len(parts) >= level else []
    if module:
        base = base + module.split(".")
    return ".".join(base)


class ImportGraph:
    """Top-level imports and re-exports of every module in the tree."""

    def __init__(self, tree: SourceTree):
        self.edges: Dict[str, List[ImportEdge]] = {}
        # (module, exported name) -> (source module, source name)
        self.reexports: Dict[Tuple[str, str], Tuple[str, str]] = {}
        for mod in tree:
            self.edges[mod.modname] = self._scan(mod)
        for mod in tree:
            for e in self.edges[mod.modname]:
                if e.name and e.name != "*":
                    self.reexports[(mod.modname, e.bound_as)] = (
                        e.module, e.name)

    @staticmethod
    def _scan(mod: Module) -> List[ImportEdge]:
        out: List[ImportEdge] = []
        for stmt in ast.walk(mod.tree):
            if isinstance(stmt, ast.Import):
                for a in stmt.names:
                    out.append(ImportEdge(
                        module=a.name, name="",
                        bound_as=a.asname or a.name.split(".")[0],
                        line=stmt.lineno))
            elif isinstance(stmt, ast.ImportFrom):
                src = _resolve_relative(mod.modname, stmt.module, stmt.level)
                for a in stmt.names:
                    out.append(ImportEdge(
                        module=src, name=a.name,
                        bound_as=a.asname or a.name, line=stmt.lineno))
        return out

    def resolve(self, module: str, name: str,
                _depth: int = 0) -> Tuple[str, str]:
        """Follow ``from A import B`` chains to the defining module.

        ``resolve("repro.serve", "make_policy")`` returns
        ``("repro.serve.policy", "make_policy")`` when the package
        ``__init__`` re-exports it. Unknown modules resolve to
        themselves (we only see files under the scan roots).
        """
        seen = set()
        cur = (module, name)
        while cur in self.reexports and cur not in seen:
            seen.add(cur)
            cur = self.reexports[cur]
        return cur

    def imports_of(self, modname: str) -> List[ImportEdge]:
        return self.edges.get(modname, [])
