"""repro.analysis — static analysis for the serving stack's compile
discipline.

The runtime gates (zero post-warmup lowerings, cache hit counters,
parity tests) prove the invariants *after* the fact; this package
proves them on every commit without running any jax. Five rules:

* RA101 ``retrace-hazard`` — no Python control flow on traced values,
  no concretization, no mutable closure capture in jitted/scanned
  bodies, no unhashable static args.
* RA201 ``cachekey-completeness`` — every compile-affecting parameter
  reaching an executable builder maps to a ``CacheKey`` field.
* RA301 ``donation-safety`` — donated buffers are rebound at the
  dispatch assignment and never read stale.
* RA401 ``hot-path-purity`` — no syncs/transfers/allocations in
  boundary callbacks, admission policies, or the server worker loop.
* RA501 ``layering`` — launchers/batcher/benchmarks stay thin
  ``repro.plan`` clients (import-graph-aware, resolves re-exports).

CLI: ``python -m repro.analysis [paths...] [--json out.json]``; see
``docs/static_analysis.md`` for the rule catalog and the baseline
workflow. The package is stdlib-only by design so the CI job runs in a
bare interpreter.
"""

from .engine import Finding, Module, Report, SourceTree, analyze, load_tree
from .baseline import Baseline, write_baseline
from .rules import ALL_RULES, RULES_BY_ID, get_rules

__all__ = [
    "Finding", "Module", "Report", "SourceTree", "analyze", "load_tree",
    "Baseline", "write_baseline",
    "ALL_RULES", "RULES_BY_ID", "get_rules",
]
