"""Shared AST helpers for the analysis rules (stdlib-only).

Every rule works on plain ``ast`` trees with parent links attached by
:func:`add_parents`; nothing in this package imports jax or executes the
code under analysis, so ``python -m repro.analysis`` runs in a bare
interpreter (the CI job installs nothing).
"""

from __future__ import annotations

import ast
import builtins
from typing import Iterator, List, Optional, Sequence, Set, Tuple, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]
FUNCTION_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
SCOPE_NODES = FUNCTION_NODES + (ast.Lambda, ast.ClassDef, ast.Module)

_BUILTIN_NAMES = frozenset(dir(builtins))


def add_parents(tree: ast.AST) -> ast.AST:
    """Attach a ``.parent`` attribute to every node (idempotent)."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node  # type: ignore[attr-defined]
    tree.parent = None  # type: ignore[attr-defined]
    return tree


def parents(node: ast.AST) -> Iterator[ast.AST]:
    """The chain of ancestors, nearest first (requires add_parents)."""
    cur = getattr(node, "parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "parent", None)


def enclosing(node: ast.AST, kinds) -> Optional[ast.AST]:
    """Nearest ancestor of one of ``kinds`` (requires add_parents)."""
    for p in parents(node):
        if isinstance(p, kinds):
            return p
    return None


def enclosing_statement(node: ast.AST) -> ast.stmt:
    """The statement that directly contains ``node``."""
    cur = node
    while not isinstance(cur, ast.stmt):
        cur = cur.parent  # type: ignore[attr-defined]
    return cur


def qualname(node: ast.AST) -> str:
    """Dotted path of enclosing defs/classes, e.g. ``Plan.serve_executable``.

    Requires :func:`add_parents`. Lambdas render as ``<lambda>``.
    """
    names: List[str] = []
    cur: Optional[ast.AST] = node
    while cur is not None:
        if isinstance(cur, FUNCTION_NODES + (ast.ClassDef,)):
            names.append(cur.name)
        elif isinstance(cur, ast.Lambda):
            names.append("<lambda>")
        cur = getattr(cur, "parent", None)
    return ".".join(reversed(names)) or "<module>"


def dotted(node: ast.AST) -> Optional[str]:
    """``Name``/``Attribute`` chains as a dotted string, else None.

    ``jax.lax.scan`` -> "jax.lax.scan"; calls and subscripts break the
    chain (returns None) — rules only match syntactically obvious uses.
    """
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted(call.func)


def param_names(fn: Union[FunctionNode, ast.Lambda]) -> List[str]:
    """All parameter names in order (pos-only, positional, kw-only,
    *args, **kwargs)."""
    a = fn.args
    out = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        out.append(a.vararg.arg)
    if a.kwarg:
        out.append(a.kwarg.arg)
    return out


def assigned_names(target: ast.AST) -> Set[str]:
    """Plain names bound by an assignment target (tuples unpacked;
    attribute/subscript targets are not name bindings)."""
    out: Set[str] = set()
    for node in ast.walk(target):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


def statement_bound_names(stmt: ast.stmt) -> Set[str]:
    """Names (re)bound by this statement, if it is an assignment."""
    if isinstance(stmt, ast.Assign):
        out: Set[str] = set()
        for t in stmt.targets:
            out |= assigned_names(t)
        return out
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return assigned_names(stmt.target)
    return set()


def local_names(fn: Union[FunctionNode, ast.Lambda]) -> Set[str]:
    """Parameters plus every name the function body binds (assignments,
    for-targets, with-as, comprehensions, nested defs, imports)."""
    out: Set[str] = set(param_names(fn))
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):  # type: ignore[arg-type]
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                out.add(node.id)
            elif isinstance(node, FUNCTION_NODES):
                out.add(node.name)
            elif isinstance(node, ast.ClassDef):
                out.add(node.name)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for alias in node.names:
                    out.add((alias.asname or alias.name).split(".")[0])
    return out


def is_builtin(name: str) -> bool:
    return name in _BUILTIN_NAMES


def module_level_names(tree: ast.Module) -> Set[str]:
    """Names bound at module top level (imports, defs, assignments)."""
    out: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, (ast.Import, ast.ImportFrom)):
            for alias in stmt.names:
                out.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(stmt, FUNCTION_NODES + (ast.ClassDef,)):
            out.add(stmt.name)
        else:
            out |= statement_bound_names(stmt)
    return out


# Attributes of a traced value that are static at trace time: branching
# on them cannot retrace (shapes/dtypes are part of the trace signature).
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})


class _RefVisitor(ast.NodeVisitor):
    def __init__(self, names: Set[str], skip_static_attrs: bool,
                 skip_is_comparisons: bool):
        self.names = names
        self.skip_static_attrs = skip_static_attrs
        self.skip_is = skip_is_comparisons
        self.hits: Set[str] = set()

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load) and node.id in self.names:
            self.hits.add(node.id)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self.skip_static_attrs and node.attr in STATIC_ATTRS:
            return  # x.shape / x.ndim / x.dtype are trace-static
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        if self.skip_is and all(isinstance(op, (ast.Is, ast.IsNot))
                                for op in node.ops):
            return  # `x is None` resolves at trace time, not per value
        self.generic_visit(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        shadowed = set(param_names(node)) & self.names
        if shadowed:
            inner = _RefVisitor(self.names - shadowed,
                                self.skip_static_attrs, self.skip_is)
            inner.visit(node.body)
            self.hits |= inner.hits
        else:
            self.generic_visit(node)


def references(expr: ast.AST, names: Set[str], *,
               skip_static_attrs: bool = False,
               skip_is_comparisons: bool = False) -> Set[str]:
    """Which of ``names`` the expression reads (loads)."""
    if not names:
        return set()
    v = _RefVisitor(names, skip_static_attrs, skip_is_comparisons)
    v.visit(expr)
    return v.hits


def const_index_set(node: ast.AST) -> Optional[Set[int]]:
    """A literal int or tuple/list of ints as a set, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[int] = set()
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, int)):
                return None
            out.add(elt.value)
        return out
    return None


def const_str_set(node: ast.AST) -> Optional[Set[str]]:
    """A literal str or tuple/list of strs as a set, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[str] = set()
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.add(elt.value)
        return out
    return None


def keyword_value(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None
