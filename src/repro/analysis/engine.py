"""Core of ``repro.analysis``: source tree, findings, reports.

The engine parses every ``.py`` file under the requested roots with the
stdlib ``ast`` module, hands the whole tree to each registered rule, and
folds the findings through the baseline into a :class:`Report`. Nothing
here imports jax — the analyzer must run (and the CI job does run) in an
interpreter with no accelerator stack installed.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Union

from .astutil import add_parents

PathLike = Union[str, pathlib.Path]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``key`` is a line-number-free token chosen by the rule (e.g.
    ``"import:repro.launch.steps"`` or ``"branch:step:x"``); together
    with the rule id and file it forms :attr:`ident`, the stable handle
    a baseline entry suppresses. Line renumbering does not invalidate a
    baseline; moving the offending code to another file does.
    """

    rule: str
    file: str
    line: int
    message: str
    symbol: str = ""
    key: str = ""

    @property
    def ident(self) -> str:
        return f"{self.rule}:{self.file}:{self.key or self.symbol or 'module'}"

    def as_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "file": self.file,
            "line": self.line,
            "message": self.message,
            "symbol": self.symbol,
            "ident": self.ident,
        }

    def render(self) -> str:
        where = f"{self.file}:{self.line}"
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{where}: {self.rule}{sym}: {self.message}"


@dataclasses.dataclass
class Module:
    """A parsed source file plus the names rules address it by."""

    path: pathlib.Path
    rel: str       # display/baseline path: repo-relative when possible
    modname: str   # dotted module name used by the import graph
    source: str
    tree: ast.Module


class SourceTree:
    """Every parsed module under the scan roots, with lookup helpers."""

    def __init__(self, modules: List[Module], parse_errors: List[Finding]):
        self.modules = modules
        self.parse_errors = parse_errors
        self.by_modname: Dict[str, Module] = {m.modname: m for m in modules}

    def __iter__(self):
        return iter(self.modules)

    def __len__(self) -> int:
        return len(self.modules)


def _modname(path: pathlib.Path, root: pathlib.Path) -> str:
    """Dotted module name for the import graph.

    Files under a ``src`` directory get their canonical installed name
    (``src/repro/serve/cache.py`` -> ``repro.serve.cache``); anything
    else is named relative to its scan root, which is what fixture
    trees and ``benchmarks/`` want.
    """
    parts = list(path.with_suffix("").parts)
    if "src" in parts:
        i = len(parts) - 1 - parts[::-1].index("src")
        mod = parts[i + 1:]
    elif root.is_dir():
        try:
            mod = list(path.with_suffix("").relative_to(root).parts)
        except ValueError:
            mod = [path.stem]
    else:
        mod = [path.stem]
    if mod and mod[-1] == "__init__":
        mod = mod[:-1]
    return ".".join(mod) or path.stem


def _display_path(path: pathlib.Path) -> str:
    cwd = pathlib.Path.cwd()
    try:
        return path.relative_to(cwd).as_posix()
    except ValueError:
        return path.as_posix()


def load_tree(paths: Sequence[PathLike]) -> SourceTree:
    modules: List[Module] = []
    errors: List[Finding] = []
    seen = set()
    for raw in paths:
        root = pathlib.Path(raw).resolve()
        if root.is_dir():
            files = sorted(p for p in root.rglob("*.py")
                           if "__pycache__" not in p.parts)
        elif root.suffix == ".py":
            files = [root]
        else:
            errors.append(Finding(
                rule="PARSE", file=_display_path(root), line=0,
                message="not a python file or directory", key="missing"))
            continue
        for f in files:
            if f in seen:
                continue
            seen.add(f)
            source = f.read_text(encoding="utf-8")
            try:
                tree = ast.parse(source, filename=str(f))
            except SyntaxError as e:
                errors.append(Finding(
                    rule="PARSE", file=_display_path(f),
                    line=e.lineno or 0,
                    message=f"syntax error: {e.msg}", key="syntax"))
                continue
            add_parents(tree)
            modules.append(Module(
                path=f, rel=_display_path(f), modname=_modname(f, root),
                source=source, tree=tree))
    return SourceTree(modules, errors)


@dataclasses.dataclass
class Report:
    """The analyzer's output: what fired, what the baseline absorbed.

    ``ok`` is the CI contract — true iff there are no unbaselined
    findings, no parse failures, and no baseline hygiene errors (stale
    entries, missing justifications).
    """

    findings: List[Finding]
    baselined: List[Finding]
    errors: List[str]
    rule_meta: List[Dict[str, str]]
    files: int

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def to_json(self) -> Dict[str, object]:
        return {
            "tool": "repro.analysis",
            "ok": self.ok,
            "counts": {
                "files": self.files,
                "findings": len(self.findings),
                "baselined": len(self.baselined),
                "errors": len(self.errors),
            },
            "rules": self.rule_meta,
            "findings": [f.as_dict() for f in self.findings],
            "baselined": [f.as_dict() for f in self.baselined],
            "errors": list(self.errors),
        }


def analyze(paths: Sequence[PathLike], *,
            rules: Optional[Iterable[str]] = None,
            baseline: Optional[PathLike] = None) -> Report:
    """Run the rule suite over ``paths`` and apply the baseline.

    ``rules`` filters by rule id (default: all registered rules).
    ``baseline`` is a path to an ``analysis_baseline.json`` file; pass
    None to run without suppressions.
    """
    from .baseline import Baseline
    from .rules import get_rules

    active = get_rules(rules)
    tree = load_tree(paths)
    findings: List[Finding] = list(tree.parse_errors)
    for rule in active:
        findings.extend(rule.run(tree))
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))

    base = Baseline.load(baseline) if baseline is not None else Baseline()
    kept, suppressed, errors = base.apply(findings)
    return Report(
        findings=kept,
        baselined=suppressed,
        errors=errors,
        rule_meta=[{"id": r.id, "name": r.name, "rationale": r.rationale}
                   for r in active],
        files=len(tree),
    )
