"""CLI for the static-analysis suite.

Usage::

    python -m repro.analysis [paths...] [options]

Defaults to scanning ``src/repro`` and ``benchmarks`` (when they exist
under the current directory) with the baseline at
``analysis_baseline.json``. Exits 0 iff there are no unbaselined
findings and no baseline hygiene errors. ``--json`` writes the report
in the same shape ``scripts/check_docs.py --json`` uses, so CI uploads
both as one artifact family.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List

from .engine import analyze
from .rules import ALL_RULES

DEFAULT_BASELINE = "analysis_baseline.json"


def _default_paths() -> List[str]:
    out = [p for p in ("src/repro", "benchmarks")
           if pathlib.Path(p).exists()]
    return out or ["."]


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of the serving stack's compile "
                    "discipline (retrace hazards, cache-key "
                    "completeness, donation safety, hot-path purity, "
                    "layering).")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to scan (default: src/repro "
                         "and benchmarks)")
    ap.add_argument("--json", metavar="FILE",
                    help="write the JSON report to FILE ('-' for stdout)")
    ap.add_argument("--baseline", metavar="FILE", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE}; "
                         f"missing file = empty baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline entirely")
    ap.add_argument("--rules", metavar="ID", nargs="+",
                    help="run only these rule ids (e.g. RA501)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.name}: {r.rationale}")
        return 0

    paths = args.paths or _default_paths()
    baseline = None if args.no_baseline else args.baseline
    try:
        report = analyze(paths, rules=args.rules, baseline=baseline)
    except KeyError as e:
        print(f"repro.analysis: {e.args[0]}", file=sys.stderr)
        return 2

    if args.json:
        payload = json.dumps(report.to_json(), indent=2) + "\n"
        if args.json == "-":
            sys.stdout.write(payload)
        else:
            pathlib.Path(args.json).write_text(payload, encoding="utf-8")

    for f in report.findings:
        print(f.render())
    for e in report.errors:
        print(f"error: {e}")
    n_base = len(report.baselined)
    base_note = f", {n_base} baselined" if n_base else ""
    if report.ok:
        print(f"repro.analysis: OK ({report.files} files, "
              f"{len(ALL_RULES) if not args.rules else len(args.rules)} "
              f"rule(s){base_note})")
        return 0
    print(f"repro.analysis: {len(report.findings)} finding(s), "
          f"{len(report.errors)} error(s) across {report.files} "
          f"file(s){base_note}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
