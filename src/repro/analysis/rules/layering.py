"""RA501 — layering/altitude enforcement.

Launchers, the batcher, and the benchmarks are thin ``repro.plan``
clients: they describe *what* to run and let the plan pipeline decide
meshes, shardings, step construction, and compilation. The moment a
thin client builds a mesh, imports a step builder, or calls ``jax.jit``
directly, the zero-post-warmup-lowerings counters stop seeing part of
the compilation surface.

Unlike the old grep test this rule works on the import graph: a
``from repro.serve import X`` is resolved through package ``__init__``
re-exports to the module that defines ``X``, so banned symbols cannot
be laundered through a shim module.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from ..engine import Finding, Module, SourceTree
from ..graph import ImportGraph
from .. import astutil as A

# Thin plan clients, matched by path suffix (fixture trees mirror the
# same shape under tests/analysis_fixtures/).
THIN_CLIENTS = (
    "launch/train.py",
    "launch/serve.py",
    "launch/dryrun.py",
    "serve/batcher.py",
    "benchmarks/serve_latency.py",
)

# module (prefix) -> why a thin client must not import from it
BANNED_MODULES: Dict[str, str] = {
    "repro.dist.sharding": "sharding rules are resolved by the plan's "
                           "ResolveSharding pass",
    "repro.launch.mesh": "meshes are built by the plan's ResolveMesh pass",
    "repro.launch.steps": "step builders are compiled by the plan's "
                          "Compile pass via the ExecutableCache",
    "repro.kernels": "kernels are an implementation detail of the layers",
    "repro.layers": "layers are consumed through the models/plan, not "
                    "directly",
}

# symbols banned regardless of which module re-exports them
BANNED_SYMBOLS = {
    "make_production_mesh", "make_debug_mesh", "rules_for_mode",
    "specs_to_shardings", "make_train_step", "make_serve_step",
    "make_prefill_step", "make_prefill_decode_step",
    "make_masked_decode_step",
}

BANNED_CALLS = {
    "jax.jit": "compiles outside the plan's ExecutableCache — invisible "
               "to the zero-post-warmup-lowerings counters",
    "jax.pjit": "compiles outside the plan's ExecutableCache",
    "pjit": "compiles outside the plan's ExecutableCache",
    "Mesh": "constructs a mesh outside the plan's ResolveMesh pass",
    "jax.make_mesh": "constructs a mesh outside the plan's ResolveMesh "
                     "pass",
}


def _banned_module(module: str) -> Optional[Tuple[str, str]]:
    for prefix, why in BANNED_MODULES.items():
        if module == prefix or module.startswith(prefix + "."):
            return prefix, why
    return None


class LayeringRule:
    id = "RA501"
    name = "layering"
    rationale = ("launchers, batcher, and benchmarks must stay thin "
                 "repro.plan clients — compilation, mesh, and sharding "
                 "decisions that bypass the plan escape its cache "
                 "counters and its pass pipeline")

    def run(self, tree: SourceTree) -> List[Finding]:
        graph = ImportGraph(tree)
        findings: List[Finding] = []
        for mod in tree:
            if not any(mod.rel.endswith(suffix)
                       for suffix in THIN_CLIENTS):
                continue
            findings.extend(self._check_imports(mod, graph))
            findings.extend(self._check_calls(mod))
        return findings

    def _check_imports(self, mod: Module,
                       graph: ImportGraph) -> List[Finding]:
        findings: List[Finding] = []
        for edge in graph.imports_of(mod.modname):
            if edge.name:  # from M import N — resolve re-exports
                origin_mod, origin_name = graph.resolve(edge.module,
                                                        edge.name)
                hit = _banned_module(origin_mod)
                laundered = origin_mod != edge.module
                via = (f" (imported via {edge.module}, defined in "
                       f"{origin_mod})") if laundered else ""
                if hit is not None:
                    findings.append(Finding(
                        rule=self.id, file=mod.rel, line=edge.line,
                        key=f"import:{origin_mod}:{origin_name}",
                        message=(f"thin client imports `{origin_name}` "
                                 f"from `{origin_mod}`{via}: {hit[1]}")))
                elif origin_name in BANNED_SYMBOLS:
                    findings.append(Finding(
                        rule=self.id, file=mod.rel, line=edge.line,
                        key=f"import-symbol:{origin_name}",
                        message=(f"thin client imports plan-internal "
                                 f"symbol `{origin_name}`{via} — go "
                                 f"through repro.plan instead")))
            else:  # import M
                hit = _banned_module(edge.module)
                if hit is not None:
                    findings.append(Finding(
                        rule=self.id, file=mod.rel, line=edge.line,
                        key=f"import:{edge.module}",
                        message=(f"thin client imports `{edge.module}`: "
                                 f"{hit[1]}")))
        return findings

    def _check_calls(self, mod: Module) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = A.qualname(node)
            name = A.call_name(node)
            if name in BANNED_CALLS:
                findings.append(Finding(
                    rule=self.id, file=mod.rel, line=node.lineno,
                    symbol=qn, key=f"call:{name}:{qn}",
                    message=(f"thin client calls `{name}`: "
                             f"{BANNED_CALLS[name]}")))
            elif name and name.split(".")[-1] in BANNED_SYMBOLS:
                findings.append(Finding(
                    rule=self.id, file=mod.rel, line=node.lineno,
                    symbol=qn, key=f"call:{name.split('.')[-1]}:{qn}",
                    message=(f"thin client calls plan-internal "
                             f"`{name}` — executables come from "
                             f"repro.plan")))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "lower"
                  and not isinstance(node.func.value, ast.Constant)):
                findings.append(Finding(
                    rule=self.id, file=mod.rel, line=node.lineno,
                    symbol=qn, key=f"call:.lower:{qn}",
                    message=("thin client calls `.lower(...)` — direct "
                             "lowering bypasses the plan's Compile "
                             "pass and its cache counters")))
        return findings
