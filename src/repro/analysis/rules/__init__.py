"""Rule registry for ``repro.analysis``.

Each rule is a small object with ``id``/``name``/``rationale`` metadata
and a ``run(tree) -> List[Finding]`` method. Rules are registered here
in id order; ``--rules`` on the CLI and the ``rules=`` kwarg of
:func:`repro.analysis.analyze` filter by id.
"""

from __future__ import annotations

from typing import Iterable, List, Optional

from ..engine import Finding, SourceTree
from .retrace import RetraceHazardRule
from .cachekey import CacheKeyCompletenessRule
from .donation import DonationSafetyRule
from .hotpath import HotPathPurityRule
from .layering import LayeringRule

ALL_RULES = (
    RetraceHazardRule(),
    CacheKeyCompletenessRule(),
    DonationSafetyRule(),
    HotPathPurityRule(),
    LayeringRule(),
)

RULES_BY_ID = {r.id: r for r in ALL_RULES}


def get_rules(ids: Optional[Iterable[str]] = None):
    if ids is None:
        return list(ALL_RULES)
    ids = list(ids)
    unknown = [i for i in ids if i not in RULES_BY_ID]
    if unknown:
        raise KeyError(f"unknown rule id(s): {', '.join(unknown)} "
                       f"(have: {', '.join(RULES_BY_ID)})")
    return [RULES_BY_ID[i] for i in ids]


__all__ = [
    "ALL_RULES", "RULES_BY_ID", "get_rules", "Finding", "SourceTree",
    "RetraceHazardRule", "CacheKeyCompletenessRule", "DonationSafetyRule",
    "HotPathPurityRule", "LayeringRule",
]
