"""RA101 — retrace hazards inside traced functions.

The runtime gate asserts zero post-warmup lowerings; this rule is its
static complement. It identifies every function that jax traces —
decorated with ``jax.jit``, passed to ``jax.jit(...)`` / ``jax.lax.scan``
/ ``LoweringBundle(fn=...)`` — and flags the patterns that silently
retrace or crash at trace time:

* ``if``/``while``/``for`` whose condition/iterable depends on a traced
  parameter (each distinct value retraces; data-dependent control flow
  belongs in ``jnp.where`` / ``lax.cond`` / ``lax.scan``);
* concretization of a traced value (``int``/``bool``/``float``/
  ``.item()``) and host round-trips (``np.asarray``/``np.array``);
* mutable closure capture: a traced body reading a list/dict/set that
  the enclosing scope mutates — the trace freezes the value at trace
  time and later mutations are silently ignored;
* non-hashable static arguments: a list/dict/set literal passed at a
  ``static_argnums`` position (TypeError at call time, or an unkeyed
  trace if wrapped).

Trace-static escapes are recognized and not flagged: ``x.shape`` /
``x.ndim`` / ``x.dtype`` branching, ``is None`` checks, and anything
listed in ``static_argnums``/``static_argnames``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple, Union

from ..engine import Finding, Module, SourceTree
from .. import astutil as A

JIT_NAMES = {"jax.jit", "jit"}
SCAN_NAMES = {"jax.lax.scan", "lax.scan", "scan"}
PARTIAL_NAMES = {"functools.partial", "partial"}
CONCRETIZE = {"bool", "int", "float"}
HOST_ROUNDTRIP = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                  "jax.device_get"}
MUTABLE_CTORS = {"list", "dict", "set", "collections.defaultdict",
                 "defaultdict", "collections.deque", "deque"}
MUTATORS = {"append", "extend", "add", "update", "insert", "setdefault",
            "pop", "popleft", "appendleft", "remove", "clear"}

FnNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def _is_jit_call(call: ast.Call) -> bool:
    name = A.call_name(call)
    if name in JIT_NAMES:
        return True
    # functools.partial(jax.jit, ...) used as a decorator factory
    if name in PARTIAL_NAMES and call.args:
        return A.dotted(call.args[0]) in JIT_NAMES
    return False


def _static_params(call: Optional[ast.Call], fn: FnNode) -> Set[str]:
    """Parameter names excluded from tracing by static_arg{nums,names}."""
    if call is None:
        return set()
    out: Set[str] = set()
    params = A.param_names(fn)
    nums_node = A.keyword_value(call, "static_argnums")
    if nums_node is not None:
        nums = A.const_index_set(nums_node)
        if nums:
            out |= {params[i] for i in nums if 0 <= i < len(params)}
    names_node = A.keyword_value(call, "static_argnames")
    if names_node is not None:
        names = A.const_str_set(names_node)
        if names:
            out |= names
    return out


class _Traced:
    def __init__(self, fn: FnNode, via: str,
                 jit_call: Optional[ast.Call]):
        self.fn = fn
        self.via = via
        self.statics = _static_params(jit_call, fn)


def _local_defs(scope: ast.AST) -> Dict[str, FnNode]:
    """Function defs declared directly in a scope's body."""
    body = getattr(scope, "body", [])
    if not isinstance(body, list):
        return {}
    return {s.name: s for s in body if isinstance(s, A.FUNCTION_NODES)}


def _resolve_fn_ref(node: ast.AST) -> Optional[FnNode]:
    """The function a reference points at: a lambda literal, or a def
    with the same name in an enclosing scope."""
    if isinstance(node, ast.Lambda):
        return node
    if not isinstance(node, ast.Name):
        return None
    for scope in A.parents(node):
        if isinstance(scope, A.FUNCTION_NODES + (ast.Module,)):
            defs = _local_defs(scope)
            if node.id in defs:
                return defs[node.id]
    return None


def _find_traced(mod: Module) -> List[_Traced]:
    traced: Dict[int, _Traced] = {}

    def add(fn: Optional[FnNode], via: str, call: Optional[ast.Call]):
        if fn is not None and id(fn) not in traced:
            traced[id(fn)] = _Traced(fn, via, call)

    for node in ast.walk(mod.tree):
        if isinstance(node, A.FUNCTION_NODES):
            for dec in node.decorator_list:
                if A.dotted(dec) in JIT_NAMES:
                    add(node, "jit-decorator", None)
                elif isinstance(dec, ast.Call) and _is_jit_call(dec):
                    add(node, "jit-decorator", dec)
        elif isinstance(node, ast.Call):
            name = A.call_name(node)
            if name in JIT_NAMES and node.args:
                add(_resolve_fn_ref(node.args[0]), "jax.jit", node)
            elif name in SCAN_NAMES and node.args:
                add(_resolve_fn_ref(node.args[0]), "lax.scan", None)
            elif name and name.split(".")[-1] == "LoweringBundle":
                target = A.keyword_value(node, "fn")
                if target is None and node.args:
                    target = node.args[0]
                if target is not None:
                    add(_resolve_fn_ref(target), "LoweringBundle", None)
    return list(traced.values())


class RetraceHazardRule:
    id = "RA101"
    name = "retrace-hazard"
    rationale = ("traced bodies must not branch on, concretize, or "
                 "capture mutable host state — each violation retraces "
                 "or silently freezes, defeating the zero-post-warmup-"
                 "lowerings guarantee")

    def run(self, tree: SourceTree) -> List[Finding]:
        findings: List[Finding] = []
        for mod in tree:
            traced = _find_traced(mod)
            traced_ids = {id(t.fn) for t in traced}
            for t in traced:
                findings.extend(self._check_fn(mod, t, traced_ids))
            findings.extend(self._check_static_callsites(mod))
        return findings

    # -- per-function analysis ------------------------------------------

    def _check_fn(self, mod: Module, t: _Traced,
                  traced_ids: Set[int]) -> List[Finding]:
        fn = t.fn
        qn = A.qualname(fn)
        findings: List[Finding] = []

        tainted = set(A.param_names(fn)) - t.statics
        # Parameters of enclosing traced functions are traced too when
        # read through the closure (scan bodies nested in jitted fns).
        for scope in A.parents(fn):
            if id(scope) in traced_ids and not isinstance(scope, ast.Lambda):
                tainted |= set(A.param_names(scope))

        def refs(expr: ast.AST) -> Set[str]:
            return A.references(expr, tainted, skip_static_attrs=True,
                                skip_is_comparisons=True)

        def emit(kind: str, line: int, names: Set[str], msg: str):
            findings.append(Finding(
                rule=self.id, file=mod.rel, line=line, message=msg,
                symbol=qn,
                key=f"{kind}:{qn}:{'+'.join(sorted(names)) or '-'}"))

        def check_expr(expr: ast.AST):
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                name = A.call_name(node)
                hit = set()
                for a in list(node.args) + [k.value for k in node.keywords]:
                    hit |= refs(a)
                if name in CONCRETIZE and hit:
                    emit("concretize", node.lineno, hit,
                         f"`{name}()` concretizes traced value(s) "
                         f"{sorted(hit)} — forces a trace-time constant "
                         f"or a ConcretizationTypeError")
                elif name in HOST_ROUNDTRIP and hit:
                    emit("host-roundtrip", node.lineno, hit,
                         f"`{name}()` pulls traced value(s) {sorted(hit)} "
                         f"to the host inside a traced body")
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr == "item"
                      and refs(node.func.value)):
                    emit("concretize", node.lineno, refs(node.func.value),
                         "`.item()` concretizes a traced value inside a "
                         "traced body")

        def walk_stmts(stmts: List[ast.stmt]):
            for stmt in stmts:
                if isinstance(stmt, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                    value = stmt.value
                    if value is not None:
                        check_expr(value)
                        if refs(value):
                            tainted.update(A.statement_bound_names(stmt))
                elif isinstance(stmt, (ast.If, ast.While)):
                    hit = refs(stmt.test)
                    if hit:
                        word = ("if" if isinstance(stmt, ast.If)
                                else "while")
                        emit("branch", stmt.lineno, hit,
                             f"python `{word}` on traced value(s) "
                             f"{sorted(hit)} — retraces per distinct "
                             f"value; use jnp.where/lax.cond")
                    check_expr(stmt.test)
                    walk_stmts(stmt.body)
                    walk_stmts(stmt.orelse)
                elif isinstance(stmt, ast.For):
                    hit = refs(stmt.iter)
                    if hit:
                        emit("loop", stmt.lineno, hit,
                             f"python `for` over traced value(s) "
                             f"{sorted(hit)} — unrolls/retraces per "
                             f"shape; use lax.scan/fori_loop")
                    check_expr(stmt.iter)
                    if refs(stmt.iter):
                        tainted.update(A.assigned_names(stmt.target))
                    walk_stmts(stmt.body)
                    walk_stmts(stmt.orelse)
                elif isinstance(stmt, (ast.Return, ast.Expr)):
                    if stmt.value is not None:
                        check_expr(stmt.value)
                elif isinstance(stmt, ast.With):
                    walk_stmts(stmt.body)
                elif isinstance(stmt, A.FUNCTION_NODES):
                    pass  # nested defs analyzed separately if traced
                elif isinstance(stmt, ast.Try):
                    walk_stmts(stmt.body)
                    for h in stmt.handlers:
                        walk_stmts(h.body)
                    walk_stmts(stmt.orelse)
                    walk_stmts(stmt.finalbody)

        if isinstance(fn, ast.Lambda):
            check_expr(fn.body)  # lambdas are a single expression
        else:
            walk_stmts(fn.body)

        findings.extend(self._check_mutable_closure(mod, t, qn))
        return findings

    # -- mutable closure capture ----------------------------------------

    def _check_mutable_closure(self, mod: Module, t: _Traced,
                               qn: str) -> List[Finding]:
        fn = t.fn
        encl = A.enclosing(fn, A.FUNCTION_NODES)
        if encl is None:
            return []
        local = A.local_names(fn) if not isinstance(fn, ast.Lambda) \
            else set(A.param_names(fn))
        module_names = A.module_level_names(mod.tree)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        captured: Set[str] = set()
        for stmt in body:
            for node in ast.walk(stmt):  # type: ignore[arg-type]
                if (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id not in local
                        and not A.is_builtin(node.id)
                        and node.id not in module_names):
                    captured.add(node.id)
        if not captured:
            return []

        mutable_in_encl: Dict[str, int] = {}
        mutated_in_encl: Set[str] = set()
        for node in ast.walk(encl):
            if node is fn or A.enclosing(node, A.FUNCTION_NODES) is not encl:
                continue
            if isinstance(node, ast.Assign):
                v = node.value
                is_mutable = isinstance(v, (ast.List, ast.Dict, ast.Set,
                                            ast.ListComp, ast.DictComp,
                                            ast.SetComp)) or (
                    isinstance(v, ast.Call)
                    and A.call_name(v) in MUTABLE_CTORS)
                if is_mutable:
                    for name in A.statement_bound_names(node):
                        mutable_in_encl[name] = node.lineno
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in MUTATORS
                  and isinstance(node.func.value, ast.Name)):
                mutated_in_encl.add(node.func.value.id)
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                pass
            elif isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, (ast.Store, ast.Del)) \
                    and isinstance(node.value, ast.Name):
                mutated_in_encl.add(node.value.id)

        out: List[Finding] = []
        for name in sorted(captured & set(mutable_in_encl)
                           & mutated_in_encl):
            out.append(Finding(
                rule=self.id, file=mod.rel,
                line=getattr(fn, "lineno", 0),
                symbol=qn, key=f"mutable-closure:{qn}:{name}",
                message=(f"traced function captures mutable `{name}` "
                         f"which the enclosing scope mutates — the trace "
                         f"freezes its value; later mutations are "
                         f"silently ignored")))
        return out

    # -- non-hashable static arguments at call sites --------------------

    def _check_static_callsites(self, mod: Module) -> List[Finding]:
        out: List[Finding] = []
        # var -> static positions, from `v = jax.jit(f, static_argnums=...)`
        static_vars: Dict[str, Set[int]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                call = node.value
                if A.call_name(call) in JIT_NAMES:
                    nums_node = A.keyword_value(call, "static_argnums")
                    nums = (A.const_index_set(nums_node)
                            if nums_node is not None else None)
                    if nums:
                        for name in A.statement_bound_names(node):
                            static_vars[name] = nums
        if not static_vars:
            return out
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in static_vars):
                continue
            qn = A.qualname(node)
            for pos in static_vars[node.func.id]:
                if pos < len(node.args) and isinstance(
                        node.args[pos], (ast.List, ast.Dict, ast.Set)):
                    out.append(Finding(
                        rule=self.id, file=mod.rel, line=node.lineno,
                        symbol=qn,
                        key=(f"unhashable-static:{qn}:"
                             f"{node.func.id}@{pos}"),
                        message=(f"non-hashable literal at static "
                                 f"position {pos} of `{node.func.id}` — "
                                 f"static args key the trace cache and "
                                 f"must be hashable")))
        return out
