"""RA301 — donation safety.

The serve/train step builders donate their state buffer at positional
index 1 (``donate_argnums=(1,)``; train also donates 0 — both rebound
by convention). After dispatch, XLA may alias the donated buffer's
memory for the outputs: reading the old Python name afterwards is
use-after-free at the buffer level. The safe idiom rebinds the donated
name in the same assignment::

    toks, prev, state = exe.compiled(params, state, feed, prev)

This rule flags, in host code:

* a ``<x>.compiled(...)`` call whose positional arg 1 is a plain name
  that the call's own statement does **not** rebind, when that name is
  read later in the function (or anywhere in the enclosing loop — the
  read happens on the next iteration, after donation);
* the same pattern for locally jitted functions whose construction
  site names ``donate_argnums`` literally
  (``f = jax.jit(g, donate_argnums=0)``).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..engine import Finding, Module, SourceTree
from .. import astutil as A

JIT_NAMES = {"jax.jit", "jit"}
# By repo convention every *.compiled executable donates its state at
# positional index 1 (see launch/steps.py builders).
COMPILED_DONATED_POSITIONS = (1,)


class DonationSafetyRule:
    id = "RA301"
    name = "donation-safety"
    rationale = ("a buffer passed at a donated position may be aliased "
                 "by XLA immediately after dispatch; host code must "
                 "rebind the name in the same assignment and never read "
                 "the stale reference")

    def run(self, tree: SourceTree) -> List[Finding]:
        findings: List[Finding] = []
        for mod in tree:
            for fn in ast.walk(mod.tree):
                if isinstance(fn, A.FUNCTION_NODES):
                    findings.extend(self._check_scope(mod, fn))
        return findings

    def _check_scope(self, mod: Module, fn) -> List[Finding]:
        findings: List[Finding] = []
        donate_vars = self._local_donators(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if A.enclosing(node, A.FUNCTION_NODES) is not fn:
                continue  # belongs to a nested def; checked there
            positions: Optional[Set[int]] = None
            label = ""
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "compiled"):
                positions = set(COMPILED_DONATED_POSITIONS)
                label = (A.dotted(node.func) or ".compiled")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in donate_vars):
                positions = donate_vars[node.func.id]
                label = node.func.id
            if positions is None:
                continue
            for pos in sorted(positions):
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                if not isinstance(arg, ast.Name):
                    continue
                finding = self._check_read_after(mod, fn, node, arg,
                                                 pos, label)
                if finding is not None:
                    findings.append(finding)
        return findings

    @staticmethod
    def _local_donators(fn) -> Dict[str, Set[int]]:
        """Vars assigned `jax.jit(..., donate_argnums=<literal>)`."""
        out: Dict[str, Set[int]] = {}
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and A.call_name(node.value) in JIT_NAMES):
                continue
            nums_node = A.keyword_value(node.value, "donate_argnums")
            if nums_node is None:
                continue
            nums = A.const_index_set(nums_node)
            if not nums:
                continue
            for name in A.statement_bound_names(node):
                out[name] = nums
        return out

    def _check_read_after(self, mod: Module, fn, call: ast.Call,
                          arg: ast.Name, pos: int,
                          label: str) -> Optional[Finding]:
        name = arg.id
        stmt = A.enclosing_statement(call)
        if name in A.statement_bound_names(stmt):
            return None  # rebound by the dispatch statement: safe

        qn = A.qualname(call)

        def mk(line: int, where: str) -> Finding:
            return Finding(
                rule=self.id, file=mod.rel, line=line, symbol=qn,
                key=f"read-after-donate:{qn}:{label}@{pos}:{name}",
                message=(f"`{name}` is donated at position {pos} of "
                         f"`{label}(...)` but {where} — rebind it in "
                         f"the dispatch assignment "
                         f"(`..., {name} = {label}(...)`)"))

        # Inside a loop without a same-statement rebind, the donated
        # name itself is re-read on the next iteration.
        loop = None
        for p in A.parents(call):
            if p is fn:
                break
            if isinstance(p, (ast.For, ast.While)):
                loop = p
                break
        if loop is not None:
            return mk(call.lineno,
                      "is re-read on the next loop iteration without "
                      "being rebound")

        # Straight-line code: any Load of the name after the statement,
        # up to the next Store.
        events = []
        for n in ast.walk(fn):
            if isinstance(n, ast.Name) and n.id == name and n is not arg:
                events.append(n)
        events.sort(key=lambda n: (n.lineno, n.col_offset))
        end = getattr(stmt, "end_lineno", stmt.lineno)
        for n in events:
            if n.lineno <= end:
                continue
            if isinstance(n.ctx, ast.Store):
                return None  # rebound before any read
            if isinstance(n.ctx, ast.Load):
                return mk(n.lineno, f"is read again at line {n.lineno}")
        return None
