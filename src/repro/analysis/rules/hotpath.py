"""RA401 — hot-path purity.

The scheduler's boundary callbacks, the admission policies, and the
async server's worker loop run between every micro-run dispatch. A
host sync (``block_until_ready``), a device transfer (``device_get`` /
``device_put`` / ``np.asarray`` of a device array), or a fresh ``jnp``
allocation there stalls the dispatch pipeline for every request in the
batch. All device work belongs in the sanctioned dispatch path
(``_dispatch`` / ``run``), not in the per-boundary host bookkeeping.

Hot scopes are identified structurally, so fixtures and future code are
covered without configuration:

* every non-dunder method of ``AdmissionPolicy`` and its subclasses;
* the boundary/bookkeeping methods of ``ContinuousScheduler`` and the
  worker-loop methods of ``AsyncServeServer`` (by name);
* any function or method assigned to an ``on_boundary`` /
  ``on_tokens`` / ``on_shed`` hook attribute.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from ..engine import Finding, Module, SourceTree
from .. import astutil as A

POLICY_BASE = "AdmissionPolicy"
HOT_METHODS: Dict[str, Set[str]] = {
    "ContinuousScheduler": {"_admit", "_free", "_now", "cancel",
                            "drain_shed"},
    "AsyncServeServer": {"_worker", "_drain_intake", "_apply",
                         "_boundary_hook", "_emit_tokens",
                         "_notify_shed", "_post", "_finish"},
}
HOOK_ATTRS = {"on_boundary", "on_tokens", "on_shed"}

BANNED_EXACT = {
    "jax.block_until_ready": "forces a host sync",
    "jax.device_get": "forces a device->host transfer",
    "jax.device_put": "forces a host->device transfer",
    "np.asarray": "may force a device->host transfer",
    "np.array": "may force a device->host transfer",
    "numpy.asarray": "may force a device->host transfer",
    "numpy.array": "may force a device->host transfer",
    "time.sleep": "blocks the dispatch thread",
}
BANNED_PREFIXES = {
    "jnp.": "allocates a fresh device array",
    "jax.numpy.": "allocates a fresh device array",
}


class HotPathPurityRule:
    id = "RA401"
    name = "hot-path-purity"
    rationale = ("boundary callbacks, admission policies, and the "
                 "server worker loop run between every dispatch — a "
                 "sync, transfer, or device allocation there stalls "
                 "the whole batch")

    def run(self, tree: SourceTree) -> List[Finding]:
        findings: List[Finding] = []
        for mod in tree:
            for fn, why in self._hot_scopes(mod):
                findings.extend(self._check(mod, fn, why))
        return findings

    # -- hot-scope discovery --------------------------------------------

    def _hot_scopes(self, mod: Module) -> List[Tuple[ast.AST, str]]:
        out: List[Tuple[ast.AST, str]] = []
        seen: Set[int] = set()

        def add(fn, why: str):
            if isinstance(fn, A.FUNCTION_NODES) and id(fn) not in seen:
                seen.add(id(fn))
                out.append((fn, why))

        classes = [n for n in ast.walk(mod.tree)
                   if isinstance(n, ast.ClassDef)]
        policy_like = self._policy_classes(classes)
        methods_by_class: Dict[str, Dict[str, ast.AST]] = {}
        for cls in classes:
            methods = {s.name: s for s in cls.body
                       if isinstance(s, A.FUNCTION_NODES)}
            methods_by_class[cls.name] = methods
            if cls.name in policy_like:
                for name, m in methods.items():
                    if not name.startswith("__"):
                        add(m, f"{POLICY_BASE} method")
            if cls.name in HOT_METHODS:
                for name in HOT_METHODS[cls.name] & set(methods):
                    add(methods[name], f"{cls.name} hot method")
        # f assigned to a boundary hook attribute is a hot callback.
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Attribute)
                            and t.attr in HOOK_ATTRS
                            for t in node.targets)):
                continue
            v = node.value
            if isinstance(v, ast.Attribute) and isinstance(v.value,
                                                           ast.Name) \
                    and v.value.id == "self":
                cls = A.enclosing(node, (ast.ClassDef,))
                if isinstance(cls, ast.ClassDef):
                    m = methods_by_class.get(cls.name, {}).get(v.attr)
                    if m is not None:
                        add(m, "boundary hook target")
            elif isinstance(v, ast.Name):
                target = self._resolve_local_def(node, v.id)
                if target is not None:
                    add(target, "boundary hook target")
        return out

    @staticmethod
    def _policy_classes(classes: List[ast.ClassDef]) -> Set[str]:
        """AdmissionPolicy plus everything that (transitively, within
        this module) inherits from it."""
        bases = {c.name: {A.dotted(b) or "" for b in c.bases}
                 for c in classes}
        hot = {c.name for c in classes
               if c.name == POLICY_BASE
               or any(b.split(".")[-1] == POLICY_BASE
                      for b in bases[c.name])}
        changed = True
        while changed:
            changed = False
            for c in classes:
                if c.name not in hot and any(
                        b.split(".")[-1] in hot for b in bases[c.name]):
                    hot.add(c.name)
                    changed = True
        return hot

    @staticmethod
    def _resolve_local_def(node: ast.AST, name: str):
        for scope in A.parents(node):
            if isinstance(scope, A.FUNCTION_NODES + (ast.Module,)):
                for s in getattr(scope, "body", []):
                    if isinstance(s, A.FUNCTION_NODES) and s.name == name:
                        return s
        return None

    # -- the check ------------------------------------------------------

    def _check(self, mod: Module, fn, why: str) -> List[Finding]:
        findings: List[Finding] = []
        qn = A.qualname(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = A.call_name(node)
            reason = None
            shown = name
            if name in BANNED_EXACT:
                reason = BANNED_EXACT[name]
            elif name:
                for prefix, r in BANNED_PREFIXES.items():
                    if name.startswith(prefix):
                        reason = r
                        break
            if reason is None and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "block_until_ready":
                reason = "forces a host sync"
                shown = ".block_until_ready"
            if reason is None:
                continue
            findings.append(Finding(
                rule=self.id, file=mod.rel, line=node.lineno, symbol=qn,
                key=f"impure:{qn}:{shown}",
                message=(f"`{shown}` in hot path ({why}): {reason}; "
                         f"device work belongs in the dispatch path, "
                         f"not per-boundary bookkeeping")))
        return findings
