"""RA201 — cache-key completeness.

Two executables that differ in any compile-affecting parameter must get
distinct ``CacheKey``s, or the ``ExecutableCache`` silently serves one
compilation for both. The ``steps`` (PR 5) and ``paged`` (PR 7) fields
were each added by hand after the parameter already existed; this rule
makes forgetting the next one a CI failure.

The rule is structural, not name-bound to ``ExecutionPlan``: for every
class it checks

1. **key constructor coverage** — in the class's key method (any method
   whose body constructs a ``CacheKey``), every parameter must be
   referenced in the ``CacheKey(...)`` call, and every keyword passed
   to ``CacheKey`` must be a real field of the ``CacheKey`` dataclass
   found in the tree;
2. **builder-parameter coverage** — in any method that both builds
   executables (contains lambdas or ``make_*`` builder calls) and calls
   ``self.<key method>(...)``, every method parameter consumed by a
   builder expression must also be passed to the key call. A parameter
   that shapes the compiled computation but not the key is exactly the
   cache-collision bug.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set

from ..engine import Finding, SourceTree
from .. import astutil as A

BUILDER_CALL_RE = re.compile(r"^make_\w+$")
KEY_CLASS = "CacheKey"


def _method_params(fn) -> Set[str]:
    return {p for p in A.param_names(fn) if p not in ("self", "cls")}


def _cachekey_calls(fn) -> List[ast.Call]:
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = A.call_name(node)
            if name and name.split(".")[-1] == KEY_CLASS:
                out.append(node)
    return out


def _call_refs(call: ast.Call, names: Set[str]) -> Set[str]:
    hit: Set[str] = set()
    for a in list(call.args) + [k.value for k in call.keywords]:
        hit |= A.references(a, names)
    return hit


class CacheKeyCompletenessRule:
    id = "RA201"
    name = "cachekey-completeness"
    rationale = ("every compile-affecting parameter that reaches an "
                 "executable builder must map to a CacheKey field — a "
                 "missing field makes two different compilations share "
                 "one cache entry")

    def run(self, tree: SourceTree) -> List[Finding]:
        fields = self._cachekey_fields(tree)
        findings: List[Finding] = []
        for mod in tree:
            for cls in ast.walk(mod.tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                findings.extend(self._check_class(mod, cls, fields))
        return findings

    @staticmethod
    def _cachekey_fields(tree: SourceTree) -> Optional[Set[str]]:
        """Field names of the CacheKey dataclass, if it is in the tree."""
        for mod in tree:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef) and node.name == KEY_CLASS:
                    return {s.target.id for s in node.body
                            if isinstance(s, ast.AnnAssign)
                            and isinstance(s.target, ast.Name)}
        return None

    def _check_class(self, mod, cls: ast.ClassDef,
                     fields: Optional[Set[str]]) -> List[Finding]:
        findings: List[Finding] = []
        methods = {s.name: s for s in cls.body
                   if isinstance(s, A.FUNCTION_NODES)}

        # The class's key method(s): any method that constructs CacheKey.
        key_methods: Dict[str, List[ast.Call]] = {}
        for name, fn in methods.items():
            calls = _cachekey_calls(fn)
            if calls:
                key_methods[name] = calls

        for name, calls in key_methods.items():
            fn = methods[name]
            params = _method_params(fn)
            referenced: Set[str] = set()
            for call in calls:
                referenced |= _call_refs(call, params)
                if fields is not None:
                    for kw in call.keywords:
                        if kw.arg and kw.arg not in fields:
                            findings.append(Finding(
                                rule=self.id, file=mod.rel,
                                line=call.lineno,
                                symbol=f"{cls.name}.{name}",
                                key=f"unknown-field:{cls.name}.{name}:"
                                    f"{kw.arg}",
                                message=(f"CacheKey has no field "
                                         f"`{kw.arg}` — keyword does "
                                         f"not match the dataclass in "
                                         f"serve/cache.py")))
            for p in sorted(params - referenced):
                findings.append(Finding(
                    rule=self.id, file=mod.rel, line=fn.lineno,
                    symbol=f"{cls.name}.{name}",
                    key=f"missing-from-key:{cls.name}.{name}:{p}",
                    message=(f"parameter `{p}` of {cls.name}.{name} "
                             f"never reaches the CacheKey constructor — "
                             f"executables differing only in `{p}` "
                             f"would collide")))

        # Builder methods: call a key method AND build executables.
        for name, fn in methods.items():
            if name in key_methods:
                continue
            key_calls = [
                node for node in ast.walk(fn)
                if isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in key_methods
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"]
            if not key_calls:
                continue
            params = _method_params(fn)
            builder_refs: Set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Lambda):
                    builder_refs |= A.references(node, params)
                elif isinstance(node, ast.Call):
                    cname = A.call_name(node)
                    base = cname.split(".")[-1] if cname else ""
                    if BUILDER_CALL_RE.match(base):
                        builder_refs |= _call_refs(node, params)
            keyed: Set[str] = set()
            for call in key_calls:
                keyed |= _call_refs(call, params)
            for p in sorted(builder_refs - keyed):
                findings.append(Finding(
                    rule=self.id, file=mod.rel, line=fn.lineno,
                    symbol=f"{cls.name}.{name}",
                    key=f"unkeyed-param:{cls.name}.{name}:{p}",
                    message=(f"compile-affecting parameter `{p}` of "
                             f"{cls.name}.{name} is consumed by an "
                             f"executable builder but never passed to "
                             f"the cache key — add it to the key method "
                             f"and a CacheKey field")))
        return findings
