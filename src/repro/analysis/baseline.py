"""Baseline (allowlist) handling for ``repro.analysis``.

A baseline entry suppresses exactly one finding ident and must carry a
written justification — the file is the audit trail for every invariant
we have consciously decided to waive. Two hygiene rules keep it honest:

* an entry with a missing/empty ``justification`` is an error, and
* an entry that no current finding matches is an error (stale
  suppressions would otherwise hide future regressions silently).

Schema (``analysis_baseline.json`` at the repo root)::

    {
      "version": 1,
      "suppressions": [
        {"ident": "RA501:benchmarks/foo.py:import:repro.layers",
         "justification": "reads layer shape tables only; no executables"}
      ]
    }
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .engine import Finding

PathLike = Union[str, pathlib.Path]

VERSION = 1


class Baseline:
    def __init__(self, entries: Optional[List[Dict[str, str]]] = None,
                 errors: Optional[List[str]] = None):
        self.entries = entries or []
        self.load_errors = errors or []

    @classmethod
    def load(cls, path: PathLike) -> "Baseline":
        p = pathlib.Path(path)
        if not p.exists():
            return cls()
        try:
            data = json.loads(p.read_text(encoding="utf-8"))
        except (json.JSONDecodeError, OSError) as e:
            return cls(errors=[f"baseline {p}: unreadable ({e})"])
        errors: List[str] = []
        if not isinstance(data, dict) or data.get("version") != VERSION:
            errors.append(f"baseline {p}: expected version {VERSION}")
            return cls(errors=errors)
        entries = data.get("suppressions", [])
        if not isinstance(entries, list):
            errors.append(f"baseline {p}: 'suppressions' must be a list")
            return cls(errors=errors)
        clean: List[Dict[str, str]] = []
        for i, e in enumerate(entries):
            if not isinstance(e, dict) or not e.get("ident"):
                errors.append(f"baseline {p}: entry {i} has no 'ident'")
                continue
            if not str(e.get("justification", "")).strip():
                errors.append(
                    f"baseline {p}: entry '{e['ident']}' has no "
                    f"justification — every suppression must say why")
                continue
            clean.append({"ident": str(e["ident"]),
                          "justification": str(e["justification"])})
        return cls(clean, errors)

    def apply(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[str]]:
        """Split findings into (kept, suppressed) and report hygiene
        errors (load problems + stale entries)."""
        idents = {e["ident"] for e in self.entries}
        kept: List[Finding] = []
        suppressed: List[Finding] = []
        used = set()
        for f in findings:
            if f.ident in idents:
                suppressed.append(f)
                used.add(f.ident)
            else:
                kept.append(f)
        errors = list(self.load_errors)
        for e in self.entries:
            if e["ident"] not in used:
                errors.append(
                    f"baseline: stale suppression '{e['ident']}' matches "
                    f"no current finding — remove it")
        return kept, suppressed, errors


def write_baseline(path: PathLike, findings: Sequence[Finding],
                   justification: str) -> None:
    """Write a baseline suppressing ``findings`` (test/tooling helper;
    production baselines are edited by hand with per-entry reasons)."""
    data = {
        "version": VERSION,
        "suppressions": [
            {"ident": f.ident, "justification": justification}
            for f in sorted({f.ident: f for f in findings}.values(),
                            key=lambda f: f.ident)
        ],
    }
    pathlib.Path(path).write_text(
        json.dumps(data, indent=2) + "\n", encoding="utf-8")
