"""Deterministic synthetic token pipeline (host-sharded).

Every batch is a pure function of (seed, step, host shard), so training is
reproducible and restart-safe: after a crash/restore at step k, the stream
continues bit-identically — the property the fault-tolerance tests assert.

The generated stream is a Zipf-ish unigram mix with short induction motifs
(repeated bigrams) so small models have learnable structure and losses
drop visibly in the e2e example.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = self.global_batch // self.n_hosts
        # fixed motif table: v -> successor (makes bigrams predictable)
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        self._succ = rng.integers(0, self.vocab, self.vocab, dtype=np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id
        )
        # zipf-ish unigram draw
        u = rng.random((self.host_batch, self.seq_len + 1))
        toks = (self.vocab * u**3).astype(np.int32) % self.vocab
        # 50% of positions follow the motif table (predictable structure)
        follow = rng.random((self.host_batch, self.seq_len)) < 0.5
        for t in range(1, self.seq_len + 1):
            prev = toks[:, t - 1]
            toks[:, t] = np.where(follow[:, t - 1], self._succ[prev],
                                  toks[:, t])
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }


def make_train_iterator(
    vocab: int,
    seq_len: int,
    global_batch: int,
    seed: int = 0,
    start_step: int = 0,
    host_id: int = 0,
    n_hosts: int = 1,
    extra: Optional[Dict] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite deterministic iterator, resumable at ``start_step``."""
    ds = SyntheticTokens(vocab, seq_len, global_batch, seed, host_id, n_hosts)
    step = start_step
    while True:
        b = ds.batch(step)
        if extra:
            b = {**b, **extra}
        yield b
        step += 1
