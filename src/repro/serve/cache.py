"""AOT compiled-executable cache for the serving layer.

The paper's serving regime never compiles on the hot path: every step the
array runs was scheduled ahead of time, and sustained throughput comes
from reusing those schedules across requests (DPUV4E makes the same
argument at the architecture level). Here the unit of reuse is a fully
lowered+compiled XLA executable produced by a ``LoweringBundle`` from
``repro.launch.steps``; this module holds them in a process-wide map keyed
by everything that changes the program:

    (arch, kind, batch, max_len, prefill_len, mode, mesh axes, quantized,
     stages, qsig, steps, paged, spec)

``ExecutableCache.get_or_build`` is the only entry point — the plan's
Compile pass routes every executable in the system (train, prefill,
decode) through it. On a miss it calls the supplied builder
(``make_serve_step(...)`` / ``make_prefill_decode_step(...)`` /
``make_train_step(...)``), runs ``.lower().compile()`` exactly
once, and records the cost; on a hit it returns the resident executable
untouched. The ``hits`` / ``misses`` / ``lowerings`` / ``compiles``
counters exist so tests and benchmarks can assert the hot path performs
ZERO new lowerings after warmup — the acceptance bar for this subsystem.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class CacheKey:
    """Identity of one compiled step executable.

    ``prefill_len`` is 0 for pure decode and train steps; ``mesh_axes``
    pins both the axis names and sizes (a 2x4 and a 4x2 mesh compile
    differently). ``stages`` and ``qsig`` separate plan variants: a
    stage-sharded layers axis or recalibrated quantization shifts change
    the program even when everything else matches. ``steps`` is the
    masked-decode micro-run length (``steps_per_dispatch``): a k-step
    scanned executable is a different program than the single-step one,
    so distinct k values must never collide (1 for every other kind).
    ``paged`` is ``()`` for dense state and ``(page_count, page_size)``
    for a paged-KV masked-decode executable — the paged program takes an
    extra page-table input and indexes a pooled cache, so it must never
    collide with the dense one even at identical bucket geometry.
    ``spec`` is ``()`` for plain decode and ``(spec_k, draft_layers)``
    for a speculative masked-decode executable — the draft signature:
    the fused program embeds a second (layer-prefix) model, carries
    draft state leaves, and returns a draft token lane, so two plans
    differing only in draft depth or spec_k must never share one
    executable.
    """

    arch: str
    kind: str                      # "decode" | "prefill" | "train"
    batch: int
    max_len: int
    prefill_len: int
    mode: str
    mesh_axes: Tuple[Tuple[str, int], ...]
    quantized: bool = False
    stages: int = 1
    qsig: Tuple[Tuple[Any, ...], ...] = ()
    steps: int = 1
    paged: Tuple[int, ...] = ()
    spec: Tuple[int, ...] = ()

    @staticmethod
    def mesh_signature(mesh: Mesh) -> Tuple[Tuple[str, int], ...]:
        return tuple(zip(mesh.axis_names, mesh.devices.shape))


@dataclasses.dataclass
class CachedExecutable:
    """A resident executable plus the bundle it was compiled from.

    The bundle is kept for its shardings (dispatch uses them to place
    host inputs) — never re-lowered. ``lower_seconds``/``compile_seconds``
    split the one-time build cost (the dry-run reports both).
    """

    key: CacheKey
    bundle: Any                    # LoweringBundle
    compiled: Any                  # jax.stages.Compiled
    compile_seconds: float
    lower_seconds: float = 0.0


class ExecutableCache:
    """Thread-safe map CacheKey -> CachedExecutable with reuse counters."""

    def __init__(self, max_entries: Optional[int] = None):
        self._lock = threading.Lock()
        self._entries: Dict[CacheKey, CachedExecutable] = {}
        self._building: Dict[CacheKey, threading.Event] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.lowerings = 0
        self.compiles = 0
        self.evictions = 0
        self.compile_seconds = 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        return key in self._entries

    def get_or_build(
        self, key: CacheKey, build: Callable[[], Any]
    ) -> CachedExecutable:
        """Return the executable for ``key``, compiling it on first use.

        ``build`` returns a LoweringBundle; it is only invoked on a miss.
        The global lock guards only the maps and counters — lowering and
        compiling happen outside it, so a warm bucket's hit never queues
        behind another bucket's minutes-long cold compile. Concurrent
        misses on the *same* key wait on a per-key event instead of
        compiling twice.
        """
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self.hits += 1
                    return entry
                pending = self._building.get(key)
                if pending is None:
                    self._building[key] = threading.Event()
                    self.misses += 1
                    break
            # someone else is compiling this key: wait, then re-check —
            # on their failure the retry loop makes us the builder
            pending.wait()
        try:
            bundle = build()
            t0 = time.perf_counter()
            lowered = bundle.lower()
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
            entry = CachedExecutable(key, bundle, compiled,
                                     compile_seconds=t2 - t1,
                                     lower_seconds=t1 - t0)
            with self._lock:
                self.lowerings += 1
                self.compiles += 1
                self.compile_seconds += t2 - t0
                if self.max_entries is not None and \
                        len(self._entries) >= self.max_entries:
                    # FIFO eviction: serving uses a small closed set of
                    # buckets, so reaching here means the policy is wrong —
                    # evict the oldest and keep counting so callers notice.
                    oldest = next(iter(self._entries))
                    del self._entries[oldest]
                    self.evictions += 1
                self._entries[key] = entry
            return entry
        finally:
            with self._lock:
                self._building.pop(key).set()

    def stats(self) -> Dict[str, Any]:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "lowerings": self.lowerings,
            "compiles": self.compiles,
            "evictions": self.evictions,
            "compile_seconds": round(self.compile_seconds, 3),
        }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
