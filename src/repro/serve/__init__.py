"""Serving subsystem: shape-bucketed batching over AOT compiled executables.

Public surface:

* :class:`~repro.serve.batcher.ServeBatcher` — admit
  :class:`~repro.serve.batcher.DecodeRequest`s, dispatch bucketed groups
  through cached prefill/decode executables (``schedule="fifo"``) or the
  continuous slot-reuse scheduler (``schedule="continuous"``).
* :class:`~repro.serve.scheduler.ContinuousScheduler` — iteration-level
  scheduling: freed slots are refilled inside an in-flight dispatch via
  the slot-masked decode executable, which scans ``steps_per_dispatch``
  masked steps per call (micro-runs: chunked prefill for long prompts,
  mid-scan self-masking, boundary-level cancellation).
* :class:`~repro.serve.cache.ExecutableCache` — process-wide
  ``lower().compile()`` cache with hit/miss/lowering/compile counters.
* :class:`~repro.serve.state_pool.StatePool` — per-bucket resident
  KV-cache/SSM state pools, with donated whole-state and per-slot resets;
  ``StatePool(plan, paged=(page_count, page_size))`` swaps the dense KV
  slabs for one shared physical page pool.
* :class:`~repro.serve.paging.PageAllocator` — host-side page
  accounting for paged KV: ref-counted acquire/release, content-hashed
  shared-prefix reuse (prefill skipping), LRU eviction. See
  docs/memory_model.md.
* :class:`~repro.serve.server.AsyncServeServer` — asyncio streaming
  front-end: concurrent arrivals, per-micro-run token streams,
  disconnect-driven cancellation, deadline shedding.
* ``repro.serve.policy`` — boundary-time admission policies
  (:class:`~repro.serve.policy.FifoPolicy`,
  :class:`~repro.serve.policy.PriorityPolicy`,
  :class:`~repro.serve.policy.DeadlinePolicy`) selected via
  ``ServeBatcher(admission=...)``.
* :func:`~repro.serve.traffic.generate_traffic` — seeded synthetic
  many-user load (Poisson arrivals, heavy-tailed lengths, priority
  classes, deadlines, abandonment) for benchmarks and load tests.

See docs/serving.md for the bucket policy, cache keys, and lifecycle.
"""

from repro.serve.batcher import (
    Bucket,
    BucketMetrics,
    BucketPolicy,
    DecodeRequest,
    RequestResult,
    ServeBatcher,
)
from repro.serve.cache import CachedExecutable, CacheKey, ExecutableCache
from repro.serve.paging import PageAllocator, SlotPages, prefix_page_hashes
from repro.serve.policy import (
    AdmissionPolicy,
    DeadlinePolicy,
    FifoPolicy,
    PriorityPolicy,
    make_policy,
)
from repro.serve.scheduler import ContinuousScheduler, SlotEvent
from repro.serve.server import AsyncServeServer, RequestShed
from repro.serve.state_pool import StatePool
from repro.serve.traffic import TrafficRequest, TrafficSpec, generate_traffic

__all__ = [
    "AdmissionPolicy",
    "AsyncServeServer",
    "Bucket",
    "BucketMetrics",
    "BucketPolicy",
    "CacheKey",
    "CachedExecutable",
    "ContinuousScheduler",
    "DeadlinePolicy",
    "DecodeRequest",
    "ExecutableCache",
    "FifoPolicy",
    "PageAllocator",
    "PriorityPolicy",
    "RequestResult",
    "RequestShed",
    "ServeBatcher",
    "SlotEvent",
    "SlotPages",
    "StatePool",
    "TrafficRequest",
    "TrafficSpec",
    "generate_traffic",
    "make_policy",
    "prefix_page_hashes",
]
