"""Serving subsystem: shape-bucketed batching over AOT compiled executables.

Public surface:

* :class:`~repro.serve.batcher.ServeBatcher` — admit
  :class:`~repro.serve.batcher.DecodeRequest`s, dispatch bucketed groups
  through cached prefill/decode executables.
* :class:`~repro.serve.cache.ExecutableCache` — process-wide
  ``lower().compile()`` cache with hit/miss/lowering/compile counters.
* :class:`~repro.serve.state_pool.StatePool` — per-bucket resident
  KV-cache/SSM state pools.

See docs/serving.md for the bucket policy, cache keys, and lifecycle.
"""

from repro.serve.batcher import (
    Bucket,
    BucketMetrics,
    BucketPolicy,
    DecodeRequest,
    RequestResult,
    ServeBatcher,
)
from repro.serve.cache import CachedExecutable, CacheKey, ExecutableCache
from repro.serve.state_pool import StatePool

__all__ = [
    "Bucket",
    "BucketMetrics",
    "BucketPolicy",
    "CacheKey",
    "CachedExecutable",
    "DecodeRequest",
    "ExecutableCache",
    "RequestResult",
    "ServeBatcher",
    "StatePool",
]
