"""Async streaming serve front-end over the continuous scheduler.

``ServeBatcher.run()`` is a blocking drain: admissions, cancels, and
results all live on the dispatching thread, which caps the system at
benchmark-shaped traffic. :class:`AsyncServeServer` turns it into a
resident serving loop without touching the compiled step or the
scheduler's determinism:

* **requests arrive concurrently** — ``stream()`` / ``generate()`` are
  called from any number of asyncio tasks; submissions land on a
  thread-safe intake queue and are fed to the batcher at micro-run
  boundaries (the scheduler's ``on_boundary`` hook), so a request that
  arrives while a dispatch is in flight is admitted into it mid-run,
  exactly like the continuous scheduler promises;
* **tokens stream back per micro-run boundary** — the scheduler's
  ``on_tokens`` hook fetches each micro-run's ``[k, slots]`` block at
  the boundary and routes every live request its newly generated tokens;
  ``stream()`` is an async generator yielding them as they arrive
  (time-to-first-token is a few micro-runs, not a full drain). Under
  speculative lanes (``speculative=k`` on the batcher) the deltas carry
  only ACCEPTED tokens — the host commits the verified draft prefix at
  each boundary before publishing, so a client never sees a token a
  rollback would retract, and greedy streams stay bit-exact with plain
  continuous decode;
* **client disconnect maps to cancellation** — a consumer that abandons
  its stream (``break``, task cancelled, connection dropped) enqueues a
  cancel that :meth:`ServeBatcher.cancel` applies at the next boundary:
  the slot is freed, its state lanes wiped, and the tokens never leave
  the device;
* **deadline shedding surfaces as** :class:`RequestShed` — when the
  batcher's admission policy (``repro.serve.policy``) drops a request
  whose deadline already passed, the waiting stream raises instead of
  hanging. Under the async server the scheduler's clock is
  ``time.monotonic``, so ``DecodeRequest.deadline`` is wall-clock
  seconds.

One worker thread owns ALL batcher/scheduler calls (their documented
single-thread contract): it blocks on intake when idle and drives
``batcher.run()`` when requests are queued; the asyncio side only ever
touches its own per-request queues. Every hot-path executable is the
same warm ``masked_decode`` the blocking path uses — streaming adds one
host fetch per micro-run and ZERO lowerings (pinned in
``tests/test_server.py`` along with token parity against ``run()``).
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import queue
import threading
import time
from typing import AsyncIterator, Deque, Dict, List, Optional

from repro.serve.batcher import (
    DecodeRequest,
    RequestResult,
    ServeBatcher,
    quantile,
)

_TTFT_WINDOW = 4096      # bounded: a resident server must not grow per-req


class RequestShed(RuntimeError):
    """The admission policy dropped this request (deadline already
    missed); it consumed no slot steps and produced no tokens."""


@dataclasses.dataclass
class _Stream:
    """Per-request plumbing between the worker thread and one consumer."""

    request: DecodeRequest
    queue: "asyncio.Queue"
    t_submit: float
    t_first: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    outcome: Optional[str] = None    # done | shed | cancelled | error
    result: Optional[RequestResult] = None


class AsyncServeServer:
    """Asyncio front-end for a continuous-schedule :class:`ServeBatcher`.

    Usage::

        server = AsyncServeServer(batcher)     # schedule="continuous"
        async with server:
            async for tok in server.stream(DecodeRequest("r0", [1, 2])):
                ...                            # per-micro-run tokens
            res = await server.generate(DecodeRequest("r1", [3, 4]))

    ``poll_s`` bounds the idle wake-up latency (how quickly the worker
    notices the first request of a quiet period); once traffic flows,
    admission latency is micro-run boundaries, not polls.
    """

    def __init__(self, batcher: ServeBatcher, *, poll_s: float = 0.005):
        if batcher.scheduler is None:
            raise ValueError(
                "AsyncServeServer needs schedule='continuous' — the "
                "fixed-group fifo path has no boundary seam to stream "
                "from or cancel into")
        self.batcher = batcher
        self.poll_s = poll_s
        self._intake: "queue.Queue" = queue.Queue()
        self._streams: Dict[str, _Stream] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = threading.Event()
        # aggregate client-side latency stats (bounded)
        self.ttfts: Deque[float] = collections.deque(maxlen=_TTFT_WINDOW)
        self.totals: Deque[float] = collections.deque(maxlen=_TTFT_WINDOW)
        self.outcomes: Dict[str, int] = collections.defaultdict(int)

    # -- lifecycle ------------------------------------------------------------

    async def start(self) -> "AsyncServeServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._stop_flag.clear()
        sched = self.batcher.scheduler
        sched.on_boundary = self._boundary_hook
        sched.on_tokens = self._emit_tokens
        sched.on_shed = self._notify_shed
        # wall-clock deadlines for the admission policy under async serving
        sched.clock = time.monotonic
        self._thread = threading.Thread(target=self._worker,
                                        name="serve-worker", daemon=True)
        self._thread.start()
        return self

    async def stop(self) -> None:
        """Drain nothing, stop now: in-flight streams end with an error."""
        if self._thread is None:
            return
        self._stop_flag.set()
        self._intake.put(("stop", None))
        await asyncio.get_running_loop().run_in_executor(
            None, self._thread.join)
        self._thread = None
        sched = self.batcher.scheduler
        sched.on_boundary = None
        sched.on_tokens = None
        sched.on_shed = None
        sched.clock = None
        for rid in list(self._streams):
            self._post(rid, ("error",
                             RuntimeError("server stopped mid-stream")))

    async def __aenter__(self) -> "AsyncServeServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- client API -----------------------------------------------------------

    def _register(self, request: DecodeRequest) -> _Stream:
        if self._thread is None:
            raise RuntimeError("server not started")
        rid = request.request_id
        if rid in self._streams:
            raise ValueError(f"duplicate request id {rid!r}: a stream "
                             "with this id is already open")
        s = _Stream(request, asyncio.Queue(), t_submit=time.monotonic())
        self._streams[rid] = s
        self._intake.put(("submit", request))
        return s

    async def _consume(self, s: _Stream) -> AsyncIterator[int]:
        rid = s.request.request_id
        try:
            while True:
                kind, payload = await s.queue.get()
                if kind == "tokens":
                    now = time.monotonic()
                    if s.t_first is None:
                        s.t_first = now
                        self.ttfts.append(now - s.t_submit)
                    s.tokens.extend(payload)
                    for tok in payload:
                        yield tok
                elif kind == "done":
                    s.outcome = "done"
                    s.result = payload
                    self.totals.append(time.monotonic() - s.t_submit)
                    return
                elif kind == "shed":
                    s.outcome = "shed"
                    raise RequestShed(
                        f"{rid}: deadline passed before admission")
                else:                      # "error"
                    s.outcome = "error"
                    raise payload
        finally:
            self._streams.pop(rid, None)
            if s.outcome is None:          # consumer walked away
                s.outcome = "cancelled"
                self._intake.put(("cancel", rid))
            self.outcomes[s.outcome] += 1

    async def stream(self, request: DecodeRequest) -> AsyncIterator[int]:
        """Submit and yield tokens as micro-run boundaries produce them.

        Abandoning the iterator (``break`` / cancellation / disconnect)
        cancels the request at the next boundary. Raises
        :class:`RequestShed` if the admission policy sheds it, and
        re-raises submission errors (duplicate id, unservable shape).
        """
        gen = self._consume(self._register(request))
        try:
            async for tok in gen:
                yield tok
        finally:
            # a consumer that abandons the outer iterator must close the
            # inner one NOW (not at GC) so the cancel reaches the intake
            # queue before the next micro-run boundary
            await gen.aclose()

    async def generate(self, request: DecodeRequest) -> RequestResult:
        """Consume the whole stream; returns the batcher's
        :class:`RequestResult` — the same record the blocking ``run()``
        path yields, so end-to-end parity is checkable. The streamed
        tokens and the result's tokens are the same list (asserted in
        tests, not here)."""
        s = self._register(request)
        async for _ in self._consume(s):
            pass
        return s.result

    # -- worker thread --------------------------------------------------------

    def _worker(self) -> None:
        batcher = self.batcher
        while True:
            try:
                item = self._intake.get(timeout=self.poll_s)
            except queue.Empty:
                item = None
            if item is not None:
                self._apply(item)
            self._drain_intake()
            if self._stop_flag.is_set():
                return
            if batcher._pending:
                results = batcher.run()
                for rid, res in results.items():
                    self._finish(rid, res)

    def _drain_intake(self) -> None:
        while True:
            try:
                self._apply(self._intake.get_nowait())
            except queue.Empty:
                return

    def _apply(self, item) -> None:
        kind, payload = item
        if kind == "submit":
            try:
                self.batcher.submit(payload)
            except Exception as exc:      # duplicate id, unservable shape
                self._post(payload.request_id, ("error", exc))
        elif kind == "cancel":
            self.batcher.cancel(payload)
        # "stop" only wakes the worker; the flag does the rest

    def _boundary_hook(self, pos, slots) -> None:
        # every micro-run boundary: let concurrently-arrived submissions
        # join the in-flight dispatch and disconnects cancel into it
        self._drain_intake()

    # -- worker -> asyncio handoff -------------------------------------------

    def _post(self, rid: str, event) -> None:
        s = self._streams.get(rid)
        if s is None or self._loop is None:
            return
        try:
            self._loop.call_soon_threadsafe(s.queue.put_nowait, event)
        except RuntimeError:
            pass                           # loop already closed (shutdown)

    def _emit_tokens(self, deltas: Dict[str, List[int]]) -> None:
        for rid, toks in deltas.items():
            self._post(rid, ("tokens", toks))

    def _notify_shed(self, rid: str) -> None:
        self._post(rid, ("shed", None))

    def _finish(self, rid: str, res: RequestResult) -> None:
        self._post(rid, ("done", res))

    # -- observability --------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        def pct(vals, p):
            # nearest-rank with small-sample clamping — the shared serve
            # definition (the old int(p * n) index overshot: p50 TTFT of
            # a two-request smoke run reported the SLOWER request)
            return round(quantile(vals, p), 4)

        return {
            "open_streams": len(self._streams),
            "outcomes": dict(self.outcomes),
            "p50_ttft_s": pct(self.ttfts, 0.50),
            "p99_ttft_s": pct(self.ttfts, 0.99),
            "p50_total_s": pct(self.totals, 0.50),
            "p99_total_s": pct(self.totals, 0.99),
            "scheduler": self.batcher.scheduler.stats(),
        }
