"""Continuous-batching scheduler: slot reuse inside an in-flight dispatch.

The FIFO batcher dispatches fixed groups: every slot in a bucket runs
until the LONGEST request finishes, so a short request's slot idles for
the remainder of the group — the utilization gap the paper's
sustained-throughput argument is about (peak single-dispatch numbers say
nothing about the fabric staying busy). :class:`ContinuousScheduler`
closes it with iteration-level scheduling over ONE shape-stable
executable per bucket (``make_masked_decode_step``):

* every batch lane ("slot") carries its own request lifecycle — teacher-
  forced eager prefill, greedy decode, finished — controlled by per-slot
  lanes (``feed``/``start``/``active``/``fresh``) that are plain inputs,
  so the compiled program never changes shape and a churning request mix
  performs ZERO lowerings after warmup;
* the moment a request finishes, its slot is freed and the next queued
  request is admitted at the CURRENT global position: the ``fresh`` lane
  zeroes the slot's KV/SSM state in-step (donated buffers — the
  StatePool per-slot reset contract), and the attention window
  ``[start, pos]`` guarantees the newcomer never sees its predecessor's
  cache. RoPE attention depends only on relative position, so a request
  admitted at position 37 decodes exactly as it would from 0;
* admission is capacity-checked: a request needing ``n`` positions joins
  an in-flight dispatch only while ``pos + n <= bucket.max_len``; when
  the bucket's positions run out the dispatch drains and a new one
  starts at position 0 on freshly reset pooled state.

Scheduling is deterministic: a request's finish step is known at
admission (``start + len(prompt) + max_new_tokens - 2``), so the host
never reads back tokens mid-dispatch — per-step outputs stay on device
and are fetched once when the dispatch drains.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import numpy as np

from repro.serve.batcher import (
    Bucket,
    BucketMetrics,
    BucketPolicy,
    DecodeRequest,
    RequestResult,
)
from repro.serve.state_pool import StatePool

_EVENT_WINDOW = 4096      # bounded: a resident server must not grow per-req


@dataclasses.dataclass(frozen=True)
class SlotEvent:
    """One admission or free, for tests and post-hoc traces."""

    kind: str             # "admit" | "free"
    step: int             # global position at which it happened
    slot: int
    request_id: str


@dataclasses.dataclass
class _Slot:
    """One in-flight request bound to a batch lane."""

    req: DecodeRequest
    start: int            # global position of the request's first token
    fed: int = 0          # prompt tokens teacher-forced so far

    @property
    def end_step(self) -> int:
        # the step that produces the request's last generated token
        return self.start + len(self.req.prompt) + self.req.max_new_tokens - 2


class ContinuousScheduler:
    """Admit queued requests into in-flight buckets as sequences finish.

    A thin state machine over the plan's ``masked_decode`` executable:
    the plan owns compilation, the :class:`StatePool` owns the resident
    KV/SSM buffers, and the scheduler only decides, per step, which slot
    runs which request. ``ServeBatcher(schedule="continuous")`` drives it;
    the fixed-group path stays available as the ``schedule="fifo"``
    fallback.
    """

    def __init__(self, plan, policy: BucketPolicy, pool: StatePool):
        self.plan = plan
        self.policy = policy
        self.pool = pool
        # counters (tests + benchmark): slot_steps counts every lane-step
        # of every dispatch; idle_slot_steps the lanes that ran inert
        self.dispatches = 0
        self.steps = 0
        self.admissions = 0
        self.slot_steps = 0
        self.idle_slot_steps = 0
        self.refills = 0
        self.refill_gap_total = 0
        self.max_refill_gap = 0
        self.events: Deque[SlotEvent] = collections.deque(
            maxlen=_EVENT_WINDOW)
        # per-dispatch [B] idle-step vectors (benchmark slot-idle p50/p99)
        self.dispatch_idle: Deque[List[int]] = collections.deque(maxlen=256)

    # -- admission ------------------------------------------------------------

    def _admit(self, pending: Deque[DecodeRequest], bucket: Bucket,
               slots: List[Optional[_Slot]], pos: int,
               freed_at: List[int]) -> List[int]:
        """Fill free slots from the queue; returns freshly admitted lanes.

        Queue order is preserved for requests that are skipped (wrong
        bucket or not enough positions left in this dispatch) — they stay
        for a later dispatch, exactly like the FIFO group former.
        """
        admitted: List[int] = []
        for b in range(bucket.batch):
            if slots[b] is not None or not pending:
                continue
            kept: Deque[DecodeRequest] = collections.deque()
            chosen = None
            while pending:
                req = pending.popleft()
                need = len(req.prompt) + req.max_new_tokens - 1
                if req.need_len <= bucket.max_len and \
                        pos + need <= bucket.max_len:
                    chosen = req
                    break
                kept.append(req)
            # splice the skipped prefix back in front, order intact
            pending.extendleft(reversed(kept))
            if chosen is None:
                break
            slots[b] = _Slot(chosen, start=pos)
            admitted.append(b)
            self.admissions += 1
            self.events.append(SlotEvent("admit", pos, b, chosen.request_id))
            if freed_at[b] >= 0:
                gap = pos - freed_at[b]
                self.refills += 1
                self.refill_gap_total += gap
                self.max_refill_gap = max(self.max_refill_gap, gap)
        return admitted

    # -- dispatch -------------------------------------------------------------

    def run(self, pending: Deque[DecodeRequest], params,
            metrics: Dict[str, BucketMetrics]) -> Dict[str, RequestResult]:
        """Drain the queue through successive continuous dispatches."""
        results: Dict[str, RequestResult] = {}
        while pending:
            results.update(self._dispatch(pending, params, metrics))
        return results

    def _dispatch(self, pending: Deque[DecodeRequest], params,
                  metrics: Dict[str, BucketMetrics]
                  ) -> Dict[str, RequestResult]:
        t0 = time.perf_counter()
        bucket = self.policy.bucket_for(pending[0].need_len)
        B, L = bucket.batch, bucket.max_len
        exe = self.plan.serve_executable("masked_decode", batch=B, max_len=L)
        lane_sh = exe.bundle.in_shardings[2]
        pos_sh = exe.bundle.in_shardings[4]

        state = self.pool.acquire(B, L)
        slots: List[Optional[_Slot]] = [None] * B
        freed_at = [-1] * B
        idle_steps = [0] * B
        ever_used = [False] * B
        done: List[tuple] = []        # (req, slot idx, start)
        outs = []                     # per-step device token vectors [B]
        prev = jax.device_put(np.zeros((B,), np.int32), lane_sh)
        pos = 0

        # lane inputs only change on admission/free events; between events
        # (the common steady state) reuse the resident device buffers
        lane_cache: Dict[str, tuple] = {}

        def lane(name, host):
            cached = lane_cache.get(name)
            if cached is not None and np.array_equal(cached[0], host):
                return cached[1]
            dev = jax.device_put(host, lane_sh)
            lane_cache[name] = (host, dev)
            return dev

        while pos < L:
            fresh = np.zeros((B,), bool)
            for b in self._admit(pending, bucket, slots, pos, freed_at):
                fresh[b] = True
                ever_used[b] = True
            if all(s is None for s in slots):
                break                  # drained, or out of positions

            feed = np.zeros((B,), np.int32)
            start = np.full((B,), pos, np.int32)
            active = np.zeros((B,), bool)
            for b, slot in enumerate(slots):
                if slot is None:
                    idle_steps[b] += 1
                    self.idle_slot_steps += 1
                    continue
                active[b] = True
                start[b] = slot.start
                if slot.fed < len(slot.req.prompt):
                    feed[b] = slot.req.prompt[slot.fed]
                    slot.fed += 1
                else:
                    feed[b] = -1       # continue from the slot's argmax
            tok, state = exe.compiled(
                params, state,
                lane("feed", feed), prev,
                jax.device_put(np.int32(pos), pos_sh),
                lane("start", start),
                lane("active", active),
                lane("fresh", fresh))
            prev = tok
            outs.append(tok)
            self.steps += 1
            self.slot_steps += B

            for b, slot in enumerate(slots):
                if slot is not None and pos == slot.end_step:
                    done.append((slot.req, b, slot.start))
                    slots[b] = None
                    freed_at[b] = pos
                    self.events.append(
                        SlotEvent("free", pos, b, slot.req.request_id))
            pos += 1

        if outs:
            jax.block_until_ready(outs[-1])
        self.pool.release(B, L, state)
        t_total = time.perf_counter() - t0
        self.dispatches += 1
        self.dispatch_idle.append(idle_steps)

        toks = (np.stack([np.asarray(jax.device_get(t)) for t in outs])
                if outs else np.zeros((0, B), np.int32))   # [steps, B]
        results: Dict[str, RequestResult] = {}
        for req, b, s in done:
            first = s + len(req.prompt) - 1
            results[req.request_id] = RequestResult(
                request_id=req.request_id,
                tokens=[int(t) for t in
                        toks[first:first + req.max_new_tokens, b]],
                bucket=bucket.label,
                prefill_seconds=0.0,   # prefill is folded into the steps
                total_seconds=t_total,
            )

        m = metrics.setdefault(bucket.label, BucketMetrics())
        m.dispatches += 1
        m.requests += len(results)
        # same unit as the fifo path: slots this dispatch never filled
        # (mid-dispatch idling lives in slot_steps/busy_slot_steps)
        m.padded_slots += B - sum(ever_used)
        m.new_tokens += sum(len(r.tokens) for r in results.values())
        m.decode_seconds += t_total
        m.latencies.extend([t_total] * len(results))
        span = len(outs)
        m.slot_steps += span * B
        for b in range(B):
            m.busy_slot_steps += span - idle_steps[b]
            m.slot_idle.append(idle_steps[b])
        return results

    # -- observability --------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        busy = self.slot_steps - self.idle_slot_steps
        return {
            "dispatches": self.dispatches,
            "steps": self.steps,
            "admissions": self.admissions,
            "slot_steps": self.slot_steps,
            "idle_slot_steps": self.idle_slot_steps,
            "busy_slot_fraction": round(busy / self.slot_steps, 4)
            if self.slot_steps else 0.0,
            "refills": self.refills,
            "mean_refill_gap": round(
                self.refill_gap_total / self.refills, 3)
            if self.refills else 0.0,
            "max_refill_gap": self.max_refill_gap,
        }
