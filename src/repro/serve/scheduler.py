"""Continuous-batching scheduler: slot reuse inside an in-flight dispatch.

The FIFO batcher dispatches fixed groups: every slot in a bucket runs
until the LONGEST request finishes, so a short request's slot idles for
the remainder of the group — the utilization gap the paper's
sustained-throughput argument is about (peak single-dispatch numbers say
nothing about the fabric staying busy). :class:`ContinuousScheduler`
closes it with iteration-level scheduling over ONE shape-stable
executable per (bucket, k) (``make_masked_decode_step``):

* every batch lane ("slot") carries its own request lifecycle — teacher-
  forced chunked prefill, greedy decode, finished — controlled by
  per-slot lane *schedules* (``feed``/``start``/``active``/``fresh``,
  shape ``[k, slots]``) that are plain inputs, so the compiled program
  never changes shape and a churning request mix performs ZERO lowerings
  after warmup;
* the event horizon is a **micro-run** of ``steps_per_dispatch`` (k)
  masked steps scanned inside one executable call: admission, refill,
  cancellation, and completion all land on micro-run boundaries, and the
  host precomputes the whole ``[k, slots]`` schedule ahead of each call
  (finish steps are known at admission, so mid-scan self-masking needs
  no device readback). k amortizes per-dispatch overhead k-fold and
  admits a long prompt as successive k-token feed-lane chunks — a
  512-token prompt costs ~512/k dispatches, not 512;
* the moment a request's micro-run completes, its slot is freed and the
  next queued request is admitted at the NEXT boundary (refill gap <= k
  steps, == 1 for k=1): the ``fresh`` lane zeroes the slot's KV/SSM
  state in-step (donated buffers — the StatePool per-slot reset
  contract), and the attention window ``[start, pos]`` guarantees the
  newcomer never sees its predecessor's cache. RoPE attention depends
  only on relative position, so a request admitted at position 37
  decodes exactly as it would from 0;
* admission is capacity-checked: a request needing ``n`` positions joins
  an in-flight dispatch only while ``pos + n <= bucket.max_len``; when
  the bucket's positions run out the dispatch drains and a new one
  starts at position 0 on freshly reset pooled state.

Scheduling is deterministic: a request's finish step is known at
admission (``start + len(prompt) + max_new_tokens - 2``), so the host
never reads back tokens mid-dispatch — per-step outputs stay on device
and are fetched once when the dispatch drains.

:meth:`ContinuousScheduler.cancel` marks an in-flight request for
removal; its slot is freed (and its state lanes wiped through
``StatePool.reset_slots``) at the next micro-run boundary, and it never
appears in the results.

Boundary seams (all host-side, none touch the compiled step):

* ``admission`` — an :class:`~repro.serve.policy.AdmissionPolicy` that
  picks which queued request takes each freed slot (FIFO by default;
  strict-priority with per-tenant fairness and EDF with deadline-miss
  shedding ship in ``repro.serve.policy``). Requests the policy sheds
  are reported through ``on_shed`` / :meth:`drain_shed` and never run.
* ``on_boundary`` — host hook invoked at every boundary before frees and
  admission (where the async server drains its intake queue and where
  tests inject cancels).
* ``on_tokens`` — streaming hook: when set, each micro-run's tokens are
  fetched at the boundary and delivered as ``{request_id: [tokens]}``
  deltas (the async server's per-request streams); when unset the
  scheduler keeps its fetch-once-at-drain behavior.
* ``clock`` — the admission policy's time source: ``None`` means the
  deterministic global step counter; the async server installs
  ``time.monotonic`` so deadlines are wall-clock.

Speculative mode (``spec=(spec_k, draft_layers)``): each micro-run
dispatches the FUSED draft-scan + block-verify executable (see
``make_masked_decode_step``) instead of the plain k-step scan. At
the boundary the host fetches the draft and verify token lanes, accepts
each lane's longest draft prefix the target agrees with, commits those
tokens (``_Slot.acc`` — results and streaming deltas publish only
accepted tokens, so greedy streams stay bit-exact), and rolls the rest
back by bumping ``_Slot.start`` — in the executable's local coordinates
a start bump replays the rejected cache positions for free. Rollbacks
consume extra bucket positions; when a request runs out, it requeues as
a *continuation* whose prompt carries everything committed so far (the
carry map merges legs into one result), preserving the plain-mode
invariant that a dispatch always terminates.

Speculative x paged composes through revocable **draft leases** (see
``PageAllocator.draft_lease`` and docs/memory_model.md): admission
leases only the prompt span (``lazy=True``), each micro-run extends the
lease's page run with draft pages covering the speculative write front
``[local0, local0 + live)``, and the boundary accept decision resolves
them — pages fully below the committed cursor splice into the run, the
rest roll back to the free list alongside the ``start`` bump that
replays their positions. Draft-lease demand is reserved at admission
(``can_admit(reserve=...)``) so speculation can never admit itself into
a pool too full to extend any lane's lease; if eviction pressure still
starves a lane mid-dispatch, the lane parks — it requeues as a
continuation, releasing its lease so the other lanes progress.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Set

import jax
import numpy as np

from repro.serve.batcher import (
    Bucket,
    BucketMetrics,
    BucketPolicy,
    DecodeRequest,
    RequestResult,
)
from repro.serve.state_pool import StatePool

_EVENT_WINDOW = 4096      # bounded: a resident server must not grow per-req


@dataclasses.dataclass(frozen=True)
class SlotEvent:
    """One admission, free, or cancellation, for tests and traces."""

    kind: str             # "admit" | "free" | "cancel"
    step: int             # global position at which it happened
    slot: int
    request_id: str


@dataclasses.dataclass
class _Slot:
    """One in-flight request bound to a batch lane.

    Paged mode: ``pages`` is the lane's
    :class:`~repro.serve.paging.SlotPages` lease, and a prefix-cache hit
    of ``shared_len`` tokens backdates ``start`` to ``pos - shared_len``
    (possibly negative) with ``fed`` starting at ``shared_len`` — the
    lane behaves exactly as if it had already teacher-forced the shared
    prefix, so every downstream formula (``end_step``, result slicing,
    streaming deltas) holds unchanged.
    """

    req: DecodeRequest
    start: int            # global position of the request's first token
    fed: int = 0          # prompt tokens teacher-forced so far
    pages: Optional[object] = None   # SlotPages lease (paged mode only)
    # speculative mode only: tokens committed (target-verified) so far
    # this admission, and the last committed token — the host rebuilds
    # the executable's ``prev`` input from it each micro-run, because a
    # boundary rollback makes the device-resident carry meaningless
    acc: Optional[List[int]] = None
    prev_tok: int = 0

    @property
    def end_step(self) -> int:
        # the step that produces the request's last generated token
        return self.start + len(self.req.prompt) + self.req.max_new_tokens - 2


class ContinuousScheduler:
    """Admit queued requests into in-flight buckets as sequences finish.

    A thin state machine over the plan's ``masked_decode`` executable:
    the plan owns compilation, the :class:`StatePool` owns the resident
    KV/SSM buffers, and the scheduler only decides, per micro-run, which
    slot runs which request. ``ServeBatcher(schedule="continuous")``
    drives it; the fixed-group path stays available as the
    ``schedule="fifo"`` fallback. ``steps_per_dispatch`` (k) is the
    micro-run length: every bucket's ``max_len`` must be a multiple of k
    so micro-runs tile the position space exactly.
    """

    def __init__(self, plan, policy: BucketPolicy, pool: StatePool,
                 steps_per_dispatch: int = 1, admission=None,
                 clock: Optional[Callable[[], float]] = None,
                 spec: Optional[tuple] = None):
        from repro.serve.policy import FifoPolicy

        if steps_per_dispatch < 1:
            raise ValueError(
                f"steps_per_dispatch must be >= 1, got {steps_per_dispatch}")
        for b in policy.buckets:
            if b.max_len % steps_per_dispatch:
                raise ValueError(
                    f"bucket {b.label}: max_len must be a multiple of "
                    f"steps_per_dispatch={steps_per_dispatch} so micro-runs "
                    "tile the position space")
        paged = getattr(pool, "paged", None)
        if paged is not None:
            for b in policy.buckets:
                if b.max_len % paged[1]:
                    raise ValueError(
                        f"bucket {b.label}: max_len must be a multiple of "
                        f"page_size={paged[1]} so page tables tile the "
                        "position space")
        spec = tuple(spec) if spec else None
        if spec is not None:
            from repro.serve.validation import (
                validate_paged_spec,
                validate_spec_geometry,
            )

            validate_spec_geometry(spec, steps_per_dispatch)
            if paged is not None:
                validate_paged_spec(spec, paged, policy.buckets)
        self.spec = spec
        self.plan = plan
        self.policy = policy
        self.pool = pool
        self.steps_per_dispatch = steps_per_dispatch
        self.admission = admission if admission is not None else FifoPolicy()
        self.clock = clock
        # counters (tests + benchmark): slot_steps counts every lane-step
        # of every dispatch; idle_slot_steps the lanes that ran inert
        self.dispatches = 0
        self.micro_runs = 0
        self.steps = 0
        self.admissions = 0
        self.cancellations = 0
        self.sheds = 0
        self.slot_steps = 0
        self.idle_slot_steps = 0
        self.refills = 0
        self.refill_gap_total = 0
        self.max_refill_gap = 0
        # speculative decode: (lane, micro-run) verify events, draft
        # tokens proposed/accepted across them, boundary rollbacks, and
        # continuations requeued when rollbacks exhaust a bucket's
        # position space mid-request
        self.spec_verifies = 0
        self.spec_draft_tokens = 0
        self.spec_accepted_tokens = 0
        self.spec_rollbacks = 0
        self.spec_continuations = 0
        self.spec_partial_results = 0
        # committed tokens of requeued continuations, by request id;
        # merged into the final result when the continuation finishes
        self._spec_carry: Dict[str, List[int]] = {}
        self.events: Deque[SlotEvent] = collections.deque(
            maxlen=_EVENT_WINDOW)
        # per-dispatch [B] idle-step vectors (benchmark slot-idle p50/p99)
        self.dispatch_idle: Deque[List[int]] = collections.deque(maxlen=256)
        # requests to drop at the next micro-run boundary (see cancel());
        # marks never survive a boundary, so a later request reusing a
        # canceled id can never be swallowed by a stale mark
        self._canceled: Set[str] = set()
        # cancels that arrived after their request already completed in
        # an EARLIER dispatch of the current run(); run() drops their
        # results before merging anything newer
        self._stale_cancels: Set[str] = set()
        # ids the admission policy shed (deadline already missed); they
        # never run and never appear in results — the batcher drains
        # this set after run() to free the ids, the async server is
        # notified per-id through on_shed at shed time
        self._shed_ids: Set[str] = set()
        # host hook run at every boundary BEFORE frees/admission — the
        # plug-in point for cancellation and admission-policy experiments
        self.on_boundary: Optional[Callable[[int, List[Optional[_Slot]]],
                                            None]] = None
        # streaming: per-micro-run {request_id: [new tokens]} deltas,
        # fetched at the boundary right after the executable call
        self.on_tokens: Optional[Callable[[Dict[str, List[int]]],
                                          None]] = None
        # per-id shed notification (async server stream termination)
        self.on_shed: Optional[Callable[[str], None]] = None

    # -- cancellation ---------------------------------------------------------

    def cancel(self, request_id: str) -> None:
        """Drop an in-flight request at the next micro-run boundary.

        The current micro-run finishes undisturbed (its schedule is
        already on device); at the boundary the slot is freed for the
        next queued request, its state lanes are wiped through the
        pool's donated per-slot reset, and the request never appears in
        the results. A cancel that races its request's completion still
        drops the tokens. Call from the dispatching thread (e.g. the
        ``on_boundary`` hook). Queued-but-unadmitted requests are the
        batcher's job (``ServeBatcher.cancel`` removes them from the
        queue before they reach the scheduler).
        """
        self._canceled.add(request_id)

    # -- shedding -------------------------------------------------------------

    def drain_shed(self) -> Set[str]:
        """Ids the admission policy shed since the last drain (EDF
        deadline misses). The batcher calls this after ``run()`` so the
        ids become reusable; they completed zero times."""
        shed, self._shed_ids = self._shed_ids, set()
        return shed

    # -- admission ------------------------------------------------------------

    def _now(self) -> float:
        """The admission policy's clock: global steps unless overridden."""
        return self.clock() if self.clock is not None else float(self.steps)

    def _admit(self, pending: Deque[DecodeRequest], bucket: Bucket,
               slots: List[Optional[_Slot]], pos: int,
               freed_at: List[int]) -> List[int]:
        """Fill free slots from the queue; returns freshly admitted lanes.

        Which request takes a slot is the admission policy's call (FIFO
        default: first queued request that fits, skipped-prefix order
        preserved). Requests a deadline policy sheds here never run:
        they are removed from the queue, counted, and reported through
        the shed channel.
        """
        now = self._now()
        for req in self.admission.shed(pending, now):
            self.sheds += 1
            # a shed speculative continuation delivers nothing: drop its
            # committed prefix too, so the carry map stays bounded
            self._spec_carry.pop(req.request_id, None)
            self._shed_ids.add(req.request_id)
            self.events.append(SlotEvent("shed", pos, -1, req.request_id))
            if self.on_shed is not None:
                self.on_shed(req.request_id)

        alloc = getattr(self.pool, "allocator", None)
        lazy = self.spec is not None

        def fits(req: DecodeRequest) -> bool:
            need = len(req.prompt) + req.max_new_tokens - 1
            if req.need_len > bucket.max_len:
                return False
            if alloc is None:
                return pos + need <= bucket.max_len
            # prefix-cache hits shrink the positions the request consumes
            # (its start is backdated by the shared span); admission also
            # requires the page budget to cover the private pages.
            # Speculative lanes lease lazily (prompt span only) but must
            # reserve draft-lease headroom for every live lane plus this
            # one, so speculation can never admit itself into a pool too
            # full to extend any lane's write front
            reserve = 0
            if lazy:
                occupied = 1 + sum(1 for s in slots if s is not None)
                reserve = alloc.spec_demand(self.steps_per_dispatch) \
                    * occupied
            shared = alloc.probe(req.prompt)
            return pos + (need - shared) <= bucket.max_len and \
                alloc.can_admit(req.prompt, need, reserve=reserve,
                                lazy=lazy)

        admitted: List[int] = []
        for b in range(bucket.batch):
            if slots[b] is not None or not pending:
                continue
            chosen = self.admission.select(pending, fits, now)
            if chosen is None:
                break
            if alloc is not None:
                need = len(chosen.prompt) + chosen.max_new_tokens - 1
                lease = alloc.admit(chosen.prompt, need, lazy=lazy)
                if lease is None:
                    # the page budget moved between fits and admit
                    # (eviction edge): requeue at the head, stop filling
                    pending.appendleft(chosen)
                    break
                slots[b] = _Slot(chosen, start=pos - lease.shared_len,
                                 fed=lease.shared_len, pages=lease,
                                 acc=[] if self.spec is not None else None)
            else:
                slots[b] = _Slot(chosen, start=pos,
                                 acc=[] if self.spec is not None else None)
            admitted.append(b)
            self.admissions += 1
            self.events.append(SlotEvent("admit", pos, b, chosen.request_id))
            if freed_at[b] >= 0:
                gap = pos - freed_at[b]
                self.refills += 1
                self.refill_gap_total += gap
                self.max_refill_gap = max(self.max_refill_gap, gap)
        return admitted

    # -- dispatch -------------------------------------------------------------

    def run(self, pending: Deque[DecodeRequest], params,
            metrics: Dict[str, BucketMetrics]) -> Dict[str, RequestResult]:
        """Drain the queue through successive continuous dispatches."""
        results: Dict[str, RequestResult] = {}
        while pending:
            res = self._dispatch(pending, params, metrics)
            # cancels that raced a completion from an EARLIER dispatch:
            # drop the old tokens BEFORE merging this dispatch's results,
            # so a request legitimately resubmitted under the same id
            # after the cancel keeps its fresh tokens
            for rid in self._stale_cancels:
                if results.pop(rid, None) is not None:
                    self.cancellations += 1
                if rid in self._spec_carry:
                    # the cancel raced a speculative continuation that was
                    # requeued at the last drain: drop it before the next
                    # dispatch re-admits it
                    self._spec_carry.pop(rid)
                    for req in list(pending):
                        if req.request_id == rid:
                            pending.remove(req)
                            self.cancellations += 1
                            break
            self._stale_cancels.clear()
            results.update(res)
        return results

    def _park(self, slots, b, pos, freed_at, done, requeues):
        """Requeue lane ``b``'s request as a continuation at ``pos``.

        Two callers: the end-of-dispatch drain (rollbacks pushed
        ``end_step`` past the bucket's positions) and the mid-dispatch
        draft-lease valve (the page pool could not cover the lane's
        speculative write front). The continuation's prompt carries
        everything committed so far; the page lease — if any — is
        published then released, so the prompt pages enter the prefix
        cache (the continuation's re-admission skips them) and the freed
        pages let the other lanes progress. If no bucket can hold the
        continuation, the committed prefix is delivered as a (counted)
        partial result instead.
        """
        slot = slots[b]
        rid = slot.req.request_id
        alloc = getattr(self.pool, "allocator", None)
        if alloc is not None and slot.pages is not None:
            alloc.publish(slot.pages, slot.fed)
            alloc.release(slot.pages)
        carry = self._spec_carry.pop(rid, []) + slot.acc
        cont = dataclasses.replace(
            slot.req,
            prompt=list(slot.req.prompt) + slot.acc,
            max_new_tokens=slot.req.max_new_tokens - len(slot.acc))
        if cont.need_len > max(bk.max_len for bk in self.policy.buckets):
            self.spec_partial_results += 1
            done.append((slot.req, b, slot.start, carry))
        else:
            self._spec_carry[rid] = carry
            requeues.append(cont)
            self.spec_continuations += 1
            self.events.append(SlotEvent("requeue", pos, b, rid))
        freed_at[b] = pos - 1
        slots[b] = None

    def _free(self, slots, b, pos, freed_at, done=None):
        """Release lane ``b`` at boundary ``pos`` (finish or cancel)."""
        slot = slots[b]
        alloc = getattr(self.pool, "allocator", None)
        if alloc is not None and slot.pages is not None:
            if done is not None:
                # a finished request has teacher-forced its whole prompt:
                # publish its full prompt pages to the prefix cache so a
                # follower sharing the prefix skips that prefill span
                alloc.publish(slot.pages, slot.fed)
            alloc.release(slot.pages)
        if done is not None:
            done.append((slot.req, b, slot.start, slot.acc))
            # the free happened when the request produced its last token
            self.events.append(
                SlotEvent("free", slot.end_step, b, slot.req.request_id))
            freed_at[b] = slot.end_step
        else:
            # a canceled speculative request forfeits its committed prefix
            self._spec_carry.pop(slot.req.request_id, None)
            self.events.append(
                SlotEvent("cancel", pos, b, slot.req.request_id))
            # the lane was occupied through the previous micro-run's end
            freed_at[b] = pos - 1
        slots[b] = None

    def _dispatch(self, pending: Deque[DecodeRequest], params,
                  metrics: Dict[str, BucketMetrics]
                  ) -> Dict[str, RequestResult]:
        t0 = time.perf_counter()
        k = self.steps_per_dispatch
        # the policy's top pick sizes the dispatch bucket (FIFO: queue
        # head — unchanged; priority/EDF: the most urgent request)
        head = self.admission.peek(pending, self._now())
        bucket = self.policy.bucket_for(head.need_len)
        B, L = bucket.batch, bucket.max_len
        paged = getattr(self.pool, "paged", None)
        alloc = getattr(self.pool, "allocator", None)
        kw = {"paged": paged} if paged is not None else {}
        if self.spec is not None:
            kw["spec"] = self.spec
        exe = self.plan.serve_executable("masked_decode", batch=B, max_len=L,
                                         steps_per_dispatch=k, **kw)
        sched_sh = exe.bundle.in_shardings[2]
        pos_sh = exe.bundle.in_shardings[4]
        prev_sh = exe.bundle.in_shardings[3]
        if paged is not None:
            table_sh = exe.bundle.in_shardings[8]
            n_tables = L // paged[1]
            # pinned per-lane scratch pages: empty and self-masked lanes
            # still execute the step, and their (masked, never read)
            # writes must land somewhere harmless
            scratch = alloc.scratch(B)

        state = self.pool.acquire(B, L)
        slots: List[Optional[_Slot]] = [None] * B
        freed_at = [-1] * B
        idle_steps = [0] * B
        ever_used = [False] * B
        done: List[tuple] = []        # (req, slot idx, start, acc-or-None)
        outs = []                     # per-micro-run device token blocks [k,B]
        prev_host = np.zeros((B,), np.int32)
        prev = jax.device_put(prev_host, prev_sh)
        pos = 0
        runs = 0                      # micro-runs this dispatch executed

        # lane schedules only change on admission/free/prefill events;
        # in the steady decode state reuse the resident device buffers
        lane_cache: Dict[str, tuple] = {}

        def lane(name, host, sh=sched_sh):
            cached = lane_cache.get(name)
            if cached is not None and np.array_equal(cached[0], host):
                return cached[1]
            dev = jax.device_put(host, sh)
            lane_cache[name] = (host, dev)
            return dev

        def drain_cancels():
            """Resolve every pending cancel mark against this dispatch's
            finished-but-unreturned requests; anything left completed in
            an earlier dispatch (or was bogus) and is handed to run().
            Marks never survive a boundary, so a future request reusing
            a canceled id cannot be swallowed."""
            for rid in list(self._canceled):
                self._canceled.discard(rid)
                idx = next((i for i, (req, _, _, _) in enumerate(done)
                            if req.request_id == rid), None)
                if idx is not None:
                    del done[idx]             # finished: drop the tokens
                    self._spec_carry.pop(rid, None)
                    self.cancellations += 1
                else:
                    self._stale_cancels.add(rid)

        while pos + k <= L:
            # ---- micro-run boundary: hook, cancels, frees, admission ----
            if self.on_boundary is not None:
                self.on_boundary(pos, slots)
            cancel_mask = np.zeros((B,), bool)
            for b, slot in enumerate(slots):
                if slot is None:
                    continue
                if slot.req.request_id in self._canceled:
                    self._canceled.discard(slot.req.request_id)
                    self.cancellations += 1
                    cancel_mask[b] = True
                    self._free(slots, b, pos, freed_at)
                elif slot.end_step < pos:
                    self._free(slots, b, pos, freed_at, done)
            if cancel_mask.any():
                # wipe the canceled lanes NOW: even if no successor is
                # admitted this dispatch, the state pytree must not carry
                # a dead request's KV/SSM past the boundary
                state = self.pool.reset_slots(B, L, state, cancel_mask)
            drain_cancels()
            if alloc is not None:
                # incremental publish: every fully teacher-forced prompt
                # page of a still-running request becomes a prefix-cache
                # entry NOW, so a follower admitted at this boundary can
                # already share it
                for slot in slots:
                    if slot is not None and slot.pages is not None:
                        alloc.publish(slot.pages, slot.fed)

            fresh = np.zeros((k, B), bool)
            for b in self._admit(pending, bucket, slots, pos, freed_at):
                fresh[0, b] = True
                ever_used[b] = True
            if self.spec is not None and alloc is not None:
                # extend every live lane's lease with revocable draft
                # pages covering this micro-run's write front BEFORE the
                # page table is built; a lane the pool cannot cover parks
                # (requeued as a continuation, lease released) so the
                # remaining lanes keep making progress — the deadlock
                # valve for eviction-pressure corner cases the admission
                # reserve does not see
                parked: List[DecodeRequest] = []
                for b, slot in enumerate(slots):
                    if slot is None:
                        continue
                    live = min(k, slot.end_step - pos + 1)
                    if alloc.draft_lease(slot.pages,
                                         pos - slot.start + live):
                        continue
                    if sum(1 for s in slots if s is not None) == 1:
                        raise RuntimeError(
                            "page pool cannot extend the sole speculative "
                            "lane's draft lease: page_count is too small "
                            "for spec mode (validate_paged_spec should "
                            "have rejected this geometry)")
                    self._park(slots, b, pos, freed_at, done, parked)
                    fresh[0, b] = False
                for cont in reversed(parked):
                    pending.appendleft(cont)
            if all(s is None for s in slots):
                break                  # drained, or out of positions

            # ---- precompute the [k, B] schedule for this micro-run ----
            feed = np.zeros((k, B), np.int32)
            # empty lanes window to their own single position: harmless
            start = np.broadcast_to(
                np.arange(pos, pos + k, dtype=np.int32)[:, None],
                (k, B)).copy()
            active = np.zeros((k, B), bool)
            lives = [0] * B           # spec acceptance re-walks live steps
            feeds_n = [0] * B         # prompt feeds among them (never rolled
            for b, slot in enumerate(slots):     # back: feeds come first)
                if slot is None:
                    idle_steps[b] += k
                    self.idle_slot_steps += k
                    continue
                # steps this request still runs inside the micro-run;
                # beyond them the slot self-masks (active False)
                live = min(k, slot.end_step - pos + 1)
                lives[b] = live
                active[:live, b] = True
                start[:, b] = slot.start
                idle_steps[b] += k - live
                self.idle_slot_steps += k - live
                for i in range(live):
                    if slot.fed < len(slot.req.prompt):
                        feed[i, b] = slot.req.prompt[slot.fed]
                        slot.fed += 1
                        feeds_n[b] += 1
                    else:
                        feed[i, b] = -1   # continue from the slot's argmax

            extra = ()
            if paged is not None:
                # [B, n_tables] page table: the lease's pages first, the
                # lane's pinned scratch page everywhere else (tail entries
                # absorb clamped post-end writes; gathers of them are
                # masked by kv_valid)
                table = np.empty((B, n_tables), np.int32)
                for b, slot in enumerate(slots):
                    table[b, :] = scratch[b]
                    if slot is not None and slot.pages is not None:
                        # speculative mode appends the revocable draft
                        # pages after the committed run, so the table
                        # covers the lane's whole write front this
                        # micro-run; ``draft`` is empty otherwise
                        pg = slot.pages.pages + slot.pages.draft
                        table[b, :len(pg)] = pg
                extra = (lane("table", table, table_sh),)
            if self.spec is not None:
                # fused draft-scan + block-verify: the host accepts the
                # longest draft prefix the target agrees with and rolls
                # the rest back by bumping the slot's window start (free
                # in the executable's local coordinates)
                verify, drafts, state = exe.compiled(
                    params, state,
                    lane("feed", feed),
                    jax.device_put(prev_host.copy(), prev_sh),
                    jax.device_put(np.int32(pos), pos_sh),
                    lane("start", start),
                    lane("active", active),
                    lane("fresh", fresh),
                    *extra)
                vt = np.asarray(jax.device_get(verify))
                dt = np.asarray(jax.device_get(drafts))
                deltas: Dict[str, List[int]] = {}
                for b, slot in enumerate(slots):
                    if slot is None:
                        continue
                    live = lives[b]
                    # step i consumed the right token iff it was a prompt
                    # feed, the host-correct prev (i == 0), or the draft
                    # matched the target at step i-1; validity is closed
                    # under prefixes, so the accepted set is {0..n-1}
                    n = 0
                    for i in range(live):
                        if feed[i, b] >= 0 or i == 0 or \
                                dt[i - 1, b] == vt[i - 1, b]:
                            n += 1
                        else:
                            break
                    n_dec = live - feeds_n[b]
                    if n_dec > 0:
                        self.spec_verifies += 1
                        self.spec_draft_tokens += n_dec
                        self.spec_accepted_tokens += n - feeds_n[b]
                    first = slot.start + len(slot.req.prompt) - 1
                    new = [int(vt[i, b]) for i in range(n)
                           if pos + i >= first]
                    slot.acc.extend(new)
                    slot.prev_tok = int(vt[n - 1, b])
                    prev_host[b] = slot.prev_tok
                    if n < live:
                        self.spec_rollbacks += 1
                    if alloc is not None and slot.pages is not None:
                        # resolve the lane's draft pages against the
                        # committed cursor IN THE CURRENT local frame —
                        # before the start bump below moves the origin:
                        # pages fully below ``local0 + n`` splice into
                        # the committed run, the rest roll back
                        alloc.resolve_draft(slot.pages,
                                            pos - slot.start + n)
                    # the universal bump k - n advances the slot's local
                    # cursor by exactly n: rejected steps replay next
                    # micro-run, and a fully-accepted short lane (live <
                    # k) still lands end_step at pos + live - 1 + (k -
                    # live) = pos + k - 1, so it frees at the boundary
                    slot.start += k - n
                    if new:
                        deltas[slot.req.request_id] = new
                if deltas and self.on_tokens is not None:
                    self.on_tokens(deltas)
                self.micro_runs += 1
                self.steps += k
                self.slot_steps += k * B
                pos += k
                runs += 1
                continue
            toks, prev, state = exe.compiled(
                params, state,
                lane("feed", feed), prev,
                jax.device_put(np.int32(pos), pos_sh),
                lane("start", start),
                lane("active", active),
                lane("fresh", fresh),
                *extra)
            if self.on_tokens is not None:
                # streaming: fetch this micro-run's block at the boundary
                # and hand each live request its newly GENERATED tokens
                # (prompt-echo steps are not part of any stream). The
                # fetched array replaces the device block in `outs`, so
                # drain-time assembly pays no second transfer.
                toks = np.asarray(jax.device_get(toks))
                deltas: Dict[str, List[int]] = {}
                for b, slot in enumerate(slots):
                    if slot is None:
                        continue
                    first = slot.start + len(slot.req.prompt) - 1
                    lo = max(pos, first)
                    hi = min(pos + k - 1, slot.end_step)
                    if lo <= hi:
                        deltas[slot.req.request_id] = [
                            int(t) for t in toks[lo - pos:hi - pos + 1, b]]
                if deltas:
                    self.on_tokens(deltas)
            outs.append(toks)
            self.micro_runs += 1
            self.steps += k
            self.slot_steps += k * B
            pos += k
            runs += 1

        # every admitted request ends inside the loop (admission bounds
        # end_step < L and micro-runs tile [0, L)), so drain the rest —
        # except in spec mode, where rollback bumps can push a request's
        # end_step past the bucket's positions: those requeue as
        # continuations whose prompt carries everything committed so far
        requeues: List[DecodeRequest] = []
        for b, slot in enumerate(slots):
            if slot is None:
                continue
            if self.spec is not None and slot.end_step >= pos:
                self._park(slots, b, pos, freed_at, done, requeues)
            else:
                self._free(slots, b, pos, freed_at, done)
        for cont in reversed(requeues):
            pending.appendleft(cont)
        drain_cancels()   # marks set during the final micro-run

        if outs:
            jax.block_until_ready(outs[-1])
        self.pool.release(B, L, state)
        t_total = time.perf_counter() - t0
        self.dispatches += 1
        self.dispatch_idle.append(idle_steps)

        toks = (np.concatenate(
            [np.asarray(jax.device_get(t)) for t in outs], axis=0)
            if outs else np.zeros((0, B), np.int32))   # [steps, B]
        results: Dict[str, RequestResult] = {}
        for req, b, s, acc in done:
            if acc is not None:
                # spec mode: host-committed tokens, prefixed by whatever
                # earlier continuation legs carried over
                tokens = self._spec_carry.pop(req.request_id, []) + acc
            else:
                first = s + len(req.prompt) - 1
                tokens = [int(t) for t in
                          toks[first:first + req.max_new_tokens, b]]
            results[req.request_id] = RequestResult(
                request_id=req.request_id,
                tokens=tokens,
                bucket=bucket.label,
                prefill_seconds=0.0,   # prefill is folded into the steps
                total_seconds=t_total,
            )

        m = metrics.setdefault(bucket.label, BucketMetrics())
        m.dispatches += 1
        m.requests += len(results)
        # same unit as the fifo path: slots this dispatch never filled
        # (mid-dispatch idling lives in slot_steps/busy_slot_steps)
        m.padded_slots += B - sum(ever_used)
        m.new_tokens += sum(len(r.tokens) for r in results.values())
        m.decode_seconds += t_total
        m.latencies.extend([t_total] * len(results))
        span = runs * k
        m.slot_steps += span * B
        for b in range(B):
            m.busy_slot_steps += span - idle_steps[b]
            m.slot_idle.append(idle_steps[b])
        if alloc is not None:
            # gauges, not sums: the page pool is shared process-wide
            m.pages_in_use = alloc.pages_in_use
            m.peak_pages = alloc.peak_pages
            m.prefix_hits = alloc.prefix_hits
        return results

    # -- observability --------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        busy = self.slot_steps - self.idle_slot_steps
        out = {
            "dispatches": self.dispatches,
            "micro_runs": self.micro_runs,
            "steps_per_dispatch": self.steps_per_dispatch,
            "steps": self.steps,
            "policy": self.admission.name,
            "admissions": self.admissions,
            "cancellations": self.cancellations,
            "sheds": self.sheds,
            "slot_steps": self.slot_steps,
            "idle_slot_steps": self.idle_slot_steps,
            "busy_slot_fraction": round(busy / self.slot_steps, 4)
            if self.slot_steps else 0.0,
            "refills": self.refills,
            "mean_refill_gap": round(
                self.refill_gap_total / self.refills, 3)
            if self.refills else 0.0,
            "max_refill_gap": self.max_refill_gap,
        }
        if self.spec is not None:
            out["spec"] = {
                "spec_k": self.spec[0],
                "draft_layers": self.spec[1],
                "verifies": self.spec_verifies,
                "draft_tokens": self.spec_draft_tokens,
                "accepted_tokens": self.spec_accepted_tokens,
                "rollbacks": self.spec_rollbacks,
                "continuations": self.spec_continuations,
                "partial_results": self.spec_partial_results,
                # the headline: committed tokens per (lane, micro-run)
                # verify event — > 1 means speculation beats one-at-a-time
                "accepted_tokens_per_dispatch": round(
                    self.spec_accepted_tokens / self.spec_verifies, 3)
                if self.spec_verifies else 0.0,
            }
        return out
