"""Single source of truth for speculative/paged serve validation.

The batcher and the continuous scheduler used to re-implement the
``spec`` checks independently, with error messages that drifted — which
is exactly how a lifted restriction (speculative x paged, PR 10) could
silently resurrect in one layer only. Every constraint on the
speculative geometry now lives here; ``ServeBatcher`` resolves the
user-facing ``speculative=``/``draft=`` arguments through
:func:`resolve_speculative`, and ``ContinuousScheduler`` re-checks the
resolved tuple through :func:`validate_spec_geometry` /
:func:`validate_paged_spec` (it can be constructed directly, so it must
not trust its caller).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple


def validate_spec_geometry(spec: Tuple[int, int],
                           steps_per_dispatch: int) -> None:
    """The invariants every resolved ``(spec_k, draft_layers)`` obeys."""
    spec_k, draft_layers = spec
    if spec_k != steps_per_dispatch:
        raise ValueError(
            f"spec_k ({spec_k}) must equal steps_per_dispatch "
            f"({steps_per_dispatch}): the draft proposes exactly one "
            "micro-run per dispatch")
    if draft_layers < 1:
        raise ValueError(
            f"draft_layers must be >= 1, got {draft_layers}")


def validate_paged_spec(spec: Tuple[int, int], paged: Tuple[int, int],
                        buckets: Sequence) -> None:
    """Speculative lanes over the page pool need headroom for draft
    leases: per live lane the allocator transiently holds up to
    ``ceil(spec_k / page_size) + 1`` revocable draft pages on top of the
    committed run. Require the pool to fully back at least one slot of
    every bucket plus that demand plus the per-lane scratch pages —
    otherwise a sole speculative lane could be unable to extend its
    lease and the dispatch could not make progress."""
    spec_k, _ = spec
    page_count, page_size = paged
    demand = -(-spec_k // page_size) + 1
    scratch = max(b.batch for b in buckets)
    for b in buckets:
        need = scratch + b.max_len // page_size + demand
        if page_count < need:
            raise ValueError(
                f"paged speculative decode needs page_count >= {need} "
                f"for bucket {b.label} (scratch {scratch} + "
                f"{b.max_len // page_size} slot pages + {demand} draft "
                f"lease pages), got {page_count}")


def resolve_speculative(speculative: int, draft: Optional[str], *,
                        schedule: str, steps_per_dispatch: int,
                        n_layers: int, model,
                        family: str) -> Optional[Tuple[int, int]]:
    """Resolve the batcher's ``speculative=``/``draft=`` arguments into a
    ``(spec_k, draft_layers)`` tuple (or None).

    ``draft`` names the draft model — ``"prefix:N"`` runs the first N
    layers of the target as a self-speculative draft (default: half the
    stack). Raises ValueError on every invalid combination; the messages
    are the contract ``tests/test_speculative.py`` pins.
    """
    if draft is not None and not speculative:
        raise ValueError(
            "draft only applies with speculative decode (speculative > 0)")
    if not speculative:
        return None
    if schedule != "continuous":
        raise ValueError(
            "speculative decode needs schedule='continuous' — only "
            "the masked-decode micro-run has a draft feed lane")
    if speculative != steps_per_dispatch:
        raise ValueError(
            f"speculative ({speculative}) must equal "
            f"steps_per_dispatch ({steps_per_dispatch}): the draft "
            "proposes exactly one micro-run per dispatch")
    draft_layers = max(1, n_layers // 2)
    if draft is not None:
        dkind, _, depth = draft.partition(":")
        if dkind != "prefix" or not depth.isdigit():
            raise ValueError(f"draft must be 'prefix:N', got {draft!r}")
        draft_layers = int(depth)
    if not 1 <= draft_layers <= n_layers:
        raise ValueError(
            f"draft depth must be in [1, {n_layers}], got {draft_layers}")
    if not hasattr(model, "decode_block"):
        raise ValueError(
            f"family {family!r} has no block-verify decode path "
            "(decode_block); speculative lanes need one")
    return (speculative, draft_layers)
