"""Synthetic many-user serving load, deterministic under one seed.

The serving claims this repo makes (continuous batching, micro-runs,
admission policies) only mean something under load shaped like real
traffic: requests do not arrive in tidy waves, lengths are heavy-tailed
(most chats are short, a few are very long), users carry priorities and
deadlines, and some hang up before the first token. ``generate_traffic``
produces exactly that, reproducibly:

* **Poisson arrivals** — i.i.d. exponential inter-arrival gaps at
  ``spec.rate`` requests per tick;
* **heavy-tailed lengths** — lognormal prompt lengths and Pareto output
  lengths, clipped to the serving bucket's bounds (the shapes production
  traces actually show: a short-request bulk and a long tail that ties
  up slots);
* **priority classes and tenants** — weighted priority sampling and
  uniform tenant assignment, feeding :class:`~repro.serve.policy.
  PriorityPolicy`'s strict-priority-with-fairness admission;
* **deadlines** — each deadlined request must finish within
  ``slack x`` its minimal service time (slack drawn per request), the
  input to EDF admission and the goodput-under-deadline benchmark
  headline;
* **abandonment** — a fraction of users lose patience and disconnect if
  the first token hasn't arrived within their patience window — the
  async server maps that to boundary-time cancellation;
* **shared system prompts** — with ``shared_prefix_prob`` a request opens
  with the trace's common ``shared_prefix_len``-token prefix, so the SAME
  trace can race admission policies on dense state and prefix-cache reuse
  on the paged pool (the PR 7 residual: traffic never touched paging).

The time unit is an abstract **tick**. The traffic benchmark replays
ticks as scheduler steps (virtual time: deterministic, CI-safe); the
async server replays them as scaled wall-clock seconds. Deadlines and
patience are absolute tick values on the same axis as ``at``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.batcher import DecodeRequest


@dataclasses.dataclass(frozen=True)
class TrafficRequest:
    """One arrival: when it lands, what it asks, when the user walks."""

    at: float                      # arrival tick
    request: DecodeRequest         # deadline (if any) is absolute, in ticks
    patience: Optional[float] = None   # abandon if no first token by this tick

    @property
    def min_service_ticks(self) -> int:
        """Steps a dedicated slot needs: prompt feed + decode - 1."""
        return len(self.request.prompt) + self.request.max_new_tokens - 1


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Knobs for one synthetic load shape (all distributions seeded)."""

    rate: float = 0.5              # mean arrivals per tick (Poisson)
    # heavy-tailed lengths: lognormal prompts, Pareto outputs
    prompt_log_mean: float = 1.1   # exp(1.1) ~ 3-token median prompt
    prompt_log_sigma: float = 0.8
    max_prompt: int = 24
    output_pareto_shape: float = 1.6   # smaller = heavier tail
    output_scale: float = 4.0
    max_new_tokens: int = 24
    vocab: int = 64                # token ids drawn from [1, vocab)
    # priority classes (value, weight); lower value = more urgent
    priorities: Tuple[Tuple[int, float], ...] = ((0, 0.2), (1, 0.3),
                                                 (2, 0.5))
    n_tenants: int = 4
    # deadlines: finish within slack x minimal service time of arrival
    deadline_prob: float = 1.0
    deadline_slack: Tuple[float, float] = (1.5, 6.0)
    # abandonment: disconnect if no first token within the patience window
    abandon_prob: float = 0.0
    patience_mean: float = 30.0
    # shared system prompt: with probability ``shared_prefix_prob`` a
    # request opens with the SAME ``shared_prefix_len`` tokens (drawn once
    # per trace) — the load shape that makes paged prefix reuse matter.
    # Align the length to the page size (16) for full-page prefix hits.
    shared_prefix_len: int = 0
    shared_prefix_prob: float = 0.0

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if self.shared_prefix_len < 0:
            raise ValueError("shared_prefix_len must be >= 0")
        if not 0 <= self.shared_prefix_prob <= 1:
            raise ValueError("shared_prefix_prob must be in [0, 1]")
        if not 0 <= self.deadline_prob <= 1:
            raise ValueError("deadline_prob must be in [0, 1]")
        if not 0 <= self.abandon_prob <= 1:
            raise ValueError("abandon_prob must be in [0, 1]")
        if abs(sum(w for _, w in self.priorities) - 1.0) > 1e-6:
            raise ValueError("priority weights must sum to 1")


def generate_traffic(spec: TrafficSpec, n: int, seed: int,
                     tag: str = "t") -> List[TrafficRequest]:
    """``n`` arrivals under ``spec``, bit-identical for the same seed."""
    rng = np.random.default_rng(seed)
    values = [p for p, _ in spec.priorities]
    weights = [w for _, w in spec.priorities]
    # the shared system prompt is drawn ONCE per trace (seed-stable); the
    # branch keeps prefix-free specs bit-identical to their old streams
    prefix: List[int] = []
    if spec.shared_prefix_len:
        prefix = [int(x) for x in rng.integers(
            1, spec.vocab, size=spec.shared_prefix_len)]
    out: List[TrafficRequest] = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / spec.rate))
        plen = int(np.clip(round(rng.lognormal(
            spec.prompt_log_mean, spec.prompt_log_sigma)), 1,
            spec.max_prompt))
        new = int(np.clip(1 + round(rng.pareto(spec.output_pareto_shape)
                                    * spec.output_scale), 1,
                          spec.max_new_tokens))
        prompt = [int(x) for x in rng.integers(1, spec.vocab, size=plen)]
        if prefix and rng.random() < spec.shared_prefix_prob:
            prompt = prefix + prompt
        priority = int(rng.choice(values, p=weights))
        tenant = f"tenant{int(rng.integers(spec.n_tenants))}"
        min_service = len(prompt) + new - 1
        deadline = None
        if rng.random() < spec.deadline_prob:
            slack = float(rng.uniform(*spec.deadline_slack))
            deadline = t + slack * min_service
        patience = None
        if rng.random() < spec.abandon_prob:
            patience = t + float(rng.exponential(spec.patience_mean))
        out.append(TrafficRequest(
            at=t,
            request=DecodeRequest(
                f"{tag}{i}", prompt, max_new_tokens=new,
                priority=priority, tenant=tenant, deadline=deadline),
            patience=patience,
        ))
    return out


def summarize(trace: Sequence[TrafficRequest]) -> dict:
    """Shape-of-load digest recorded next to benchmark numbers."""
    if not trace:
        return {"requests": 0}
    plens = [len(tr.request.prompt) for tr in trace]
    news = [tr.request.max_new_tokens for tr in trace]
    # longest prompt prefix shared by the largest same-first-token group:
    # >= a page (16 tokens) across many requests means paged prefix
    # reuse has something to hit on this trace
    prompts = [list(tr.request.prompt) for tr in trace]
    groups: dict = {}
    for p in prompts:
        groups.setdefault(p[0], []).append(p)
    biggest = max(groups.values(), key=len)
    shared_len = 0
    if len(biggest) >= 2:
        shared_len = min(len(p) for p in biggest)
        for j in range(shared_len):
            if len({p[j] for p in biggest}) > 1:
                shared_len = j
                break
    return {
        "requests": len(trace),
        "span_ticks": round(trace[-1].at, 2),
        "prompt_len": {"p50": int(np.median(plens)), "max": max(plens)},
        "new_tokens": {"p50": int(np.median(news)), "max": max(news)},
        "shared_prefix": {"len": shared_len,
                          "requests": len(biggest) if shared_len else 0},
        "deadlined": sum(tr.request.deadline is not None for tr in trace),
        "abandoning": sum(tr.patience is not None for tr in trace),
        "priorities": {
            str(p): sum(tr.request.priority == p for tr in trace)
            for p in sorted({tr.request.priority for tr in trace})},
    }
