"""Admission policies for the continuous scheduler's boundary seam.

Every micro-run boundary the :class:`~repro.serve.scheduler.
ContinuousScheduler` asks its admission policy which queued request
takes each freed slot. The policy sees the pending deque, a ``fits``
predicate (bucket + remaining-position capacity for this dispatch), and
the scheduler's clock, and answers by REMOVING its pick from the deque —
the queue itself is the only request store, so a policy can never leak
or duplicate a request. The paper's trigger-system framing is exactly
this decision made under a microsecond deadline: which event gets the
fabric next, decided ahead of the dispatch so the compiled step never
changes shape.

Three policies ship:

* :class:`FifoPolicy` — arrival order with capacity skips, byte-identical
  to the pre-policy scheduler (the default; pinned against a frozen
  oracle in ``tests/test_policies.py``);
* :class:`PriorityPolicy` — strict priority classes (lower value wins),
  per-tenant fairness inside a class (least-recently-admitted tenant
  first), and aging so sustained high-priority load cannot starve the
  lower classes;
* :class:`DeadlinePolicy` — earliest-deadline-first with shedding: a
  request whose deadline has already passed is never admitted (it is
  dropped at the boundary and reported through the scheduler's shed
  channel) — capacity goes to requests that can still meet their SLO.

Clock domain: ``now`` is whatever the scheduler's clock yields — the
global step counter by default (deterministic, what the property tests
and the virtual-time traffic benchmark use) or wall-clock seconds when
the async server installs ``time.monotonic``. ``DecodeRequest.deadline``
must be expressed in the same domain.
"""

from __future__ import annotations

import collections
from typing import Callable, Deque, Dict, List, Optional

from repro.serve.batcher import DecodeRequest

Fits = Callable[[DecodeRequest], bool]


class AdmissionPolicy:
    """Boundary-time request selection (see module docstring).

    Subclasses override :meth:`select`; :meth:`shed` and :meth:`peek`
    have neutral defaults. Policies are stateful per batcher (fairness
    stamps, first-seen times) but hold NO requests — the pending deque
    stays the single source of truth.
    """

    name = "base"

    def peek(self, pending: Deque[DecodeRequest],
             now: float) -> DecodeRequest:
        """The request the policy would serve next, capacity aside.

        The scheduler sizes a new dispatch's bucket from this pick, so a
        priority/deadline policy steers bucket choice too, not just slot
        fills.
        """
        return pending[0]

    def shed(self, pending: Deque[DecodeRequest],
             now: float) -> List[DecodeRequest]:
        """Remove and return queued requests that must not be admitted."""
        return []

    def select(self, pending: Deque[DecodeRequest], fits: Fits,
               now: float) -> Optional[DecodeRequest]:
        """Remove and return the next request for a free slot, or None."""
        raise NotImplementedError


class FifoPolicy(AdmissionPolicy):
    """Arrival order with capacity skips — the scheduler's historical
    behavior, kept as the default. A request skipped for lack of
    remaining positions keeps its queue rank."""

    name = "fifo"

    def select(self, pending, fits, now):
        kept: Deque[DecodeRequest] = collections.deque()
        chosen = None
        while pending:
            req = pending.popleft()
            if fits(req):
                chosen = req
                break
            kept.append(req)
        # splice the skipped prefix back in front, order intact
        pending.extendleft(reversed(kept))
        return chosen


class PriorityPolicy(AdmissionPolicy):
    """Strict priority with per-tenant fairness and aging.

    ``DecodeRequest.priority`` is the class (0 = most urgent; default 0).
    Selection key, most significant first:

    1. **effective priority** — ``priority - waited // aging_steps``:
       every ``aging_steps`` of queue wait promotes a request one class,
       so a class-2 request under a sustained class-0 flood is admitted
       within a bounded number of boundaries (``aging_steps * 2`` wait,
       plus one slot turnover). ``aging_steps=0`` disables aging and
       makes starvation possible — strict priority in its pure form;
    2. **tenant fairness** (``fairness=True``) — among the surviving
       class, the tenant admitted longest ago wins, so one chatty tenant
       cannot monopolize a class;
    3. **queue order** — FIFO among equals.

    Wait times are measured from the first boundary a request is seen at
    (the policy stamps them; the scheduler's clock is the domain).
    """

    name = "priority"

    def __init__(self, fairness: bool = True, aging_steps: int = 64):
        if aging_steps < 0:
            raise ValueError(f"aging_steps must be >= 0, got {aging_steps}")
        self.fairness = fairness
        self.aging_steps = aging_steps
        self._seen: Dict[str, float] = {}       # request id -> first seen
        self._last_admit: Dict[str, float] = {}  # tenant -> admit stamp
        self._admit_seq = 0

    def _key(self, idx: int, req: DecodeRequest, now: float):
        seen = self._seen.setdefault(req.request_id, now)
        eff = req.priority
        if self.aging_steps:
            eff -= int((now - seen) // self.aging_steps)
        lru = self._last_admit.get(req.tenant, float("-inf")) \
            if self.fairness else 0.0
        return (eff, lru, idx)

    def _prune(self, pending):
        # _seen must not grow with request history, only with queue depth
        if len(self._seen) > 2 * len(pending) + 64:
            live = {r.request_id for r in pending}
            self._seen = {k: v for k, v in self._seen.items() if k in live}

    def peek(self, pending, now):
        idx, _ = min(enumerate(pending),
                     key=lambda e: self._key(e[0], e[1], now))
        return pending[idx]

    def select(self, pending, fits, now):
        self._prune(pending)
        best = None
        for idx, req in enumerate(pending):
            key = self._key(idx, req, now)
            if fits(req) and (best is None or key < best[0]):
                best = (key, idx, req)
        if best is None:
            return None
        _, idx, req = best
        del pending[idx]
        self._seen.pop(req.request_id, None)
        self._admit_seq += 1
        # the sequence number (not `now`) breaks ties between tenants
        # admitted inside one boundary, where the clock does not move
        self._last_admit[req.tenant] = self._admit_seq
        return req


class DeadlinePolicy(AdmissionPolicy):
    """Earliest-deadline-first with expired-request shedding.

    ``DecodeRequest.deadline`` is an absolute time in the scheduler's
    clock domain (global steps by default, ``time.monotonic`` seconds
    under the async server) by which the request's LAST token must be
    out. Selection is by earliest deadline (deadline-less requests rank
    last, FIFO among themselves). A request whose deadline has already
    passed is never admitted: :meth:`shed` removes it at the boundary and
    the scheduler reports it through its shed channel — spending slot
    steps on a request that already missed its SLO only adds misses
    (goodput-under-deadline is the benchmark headline this defends).
    """

    name = "edf"

    @staticmethod
    def _deadline(req: DecodeRequest) -> float:
        return float("inf") if req.deadline is None else req.deadline

    def peek(self, pending, now):
        idx, _ = min(enumerate(pending),
                     key=lambda e: (self._deadline(e[1]), e[0]))
        return pending[idx]

    def shed(self, pending, now):
        expired = [req for req in pending
                   if req.deadline is not None and req.deadline <= now]
        for req in expired:
            pending.remove(req)
        return expired

    def select(self, pending, fits, now):
        best = None
        for idx, req in enumerate(pending):
            if req.deadline is not None and req.deadline <= now:
                continue                    # expired: shed's job, never admit
            key = (self._deadline(req), idx)
            if fits(req) and (best is None or key < best[0]):
                best = (key, idx, req)
        if best is None:
            return None
        _, idx, req = best
        del pending[idx]
        return req


_POLICIES = {
    "fifo": FifoPolicy,
    "priority": PriorityPolicy,
    "edf": DeadlinePolicy,
}


def make_policy(name: str) -> AdmissionPolicy:
    """CLI/benchmark factory: ``fifo`` | ``priority`` | ``edf``."""
    if name not in _POLICIES:
        raise ValueError(
            f"unknown admission policy {name!r}; "
            f"choose from {sorted(_POLICIES)}")
    return _POLICIES[name]()
