"""Request batcher: shape buckets, AOT executables, prefill->decode handoff.

The serving hot path must run only code that was compiled ahead of time
(ROADMAP open item #1). To make that possible with dynamic request sizes,
the batcher quantizes every request group onto a small closed set of
declared shape buckets:

* a ``Bucket(batch, max_len)`` fixes the decode executable's shapes —
  requests are padded up to the bucket batch with inert slots and their
  KV/SSM capacity to ``max_len``;
* the prompt block is padded to a power-of-two ``prefill_len`` (>= 8), so
  each bucket owns at most log2(max_len) prefill executables.

Dispatch then runs exactly two cached executables per group — one
``make_prefill_decode_step`` scan that teacher-forces prompts straight
into resident state while already generating for short sequences, and one
``make_serve_step`` single-token step looped for the remaining tokens —
both served from the process-wide :class:`ExecutableCache` and fed from
the per-bucket :class:`StatePool`. After warmup a dispatch performs zero
lowerings and zero compiles; the cache counters prove it.

This fixed-group FIFO path is the ``schedule="fifo"`` default;
``schedule="continuous"`` routes ``run()`` through the
:class:`~repro.serve.scheduler.ContinuousScheduler`, which reuses slots
INSIDE an in-flight dispatch (masked per-slot lane schedules over one
``make_masked_decode_step`` executable per (bucket, ``steps_per_dispatch``))
instead of idling them until the group's longest request finishes.
``steps_per_dispatch`` (k) scans k masked steps per executable call —
micro-runs that amortize dispatch overhead and chunk long prompts k
tokens at a time. See docs/serving.md.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Deque, Dict, List, Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.base import ArchConfig
from repro.serve.cache import CachedExecutable, ExecutableCache
from repro.serve.state_pool import StatePool

_MIN_PREFILL = 8


def _pow2ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def quantile(vals: Sequence[float], p: float) -> float:
    """Nearest-rank quantile of an (unsorted) sample; 0.0 when empty.

    The index is ``ceil(p * n) - 1`` clamped to ``[0, n - 1]`` — the
    classic nearest-rank definition. The previous ad-hoc ``int(p * n)``
    overshot on small samples (p50 of two values picked the LARGER one;
    p50 of [a, b, c] picked b only by accident of truncation), which is
    exactly where the serve smoke runs live. Every percentile the serve
    stack reports (bucket latencies, async TTFT, benchmark ticks) goes
    through here so the definitions cannot drift again.
    """
    if not vals:
        return 0.0
    v = sorted(vals)
    n = len(v)
    return v[max(0, min(n - 1, math.ceil(p * n) - 1))]


@dataclasses.dataclass
class DecodeRequest:
    """One sequence to continue: prompt token ids + how many to generate.

    The scheduling fields are read by the continuous scheduler's
    admission policy (``repro.serve.policy``) and ignored everywhere
    else: ``priority`` is the strict-priority class (0 = most urgent),
    ``tenant`` the fairness bucket inside a class, and ``deadline`` an
    absolute time in the scheduler's clock domain (global steps by
    default, wall-clock seconds under the async server) by which the
    last token must be produced — EDF sheds the request instead of
    admitting it once the deadline has passed.
    """

    request_id: str
    prompt: Sequence[int]
    max_new_tokens: int = 8
    priority: int = 0
    tenant: str = "default"
    deadline: Optional[float] = None

    def __post_init__(self):
        self.prompt = [int(t) for t in self.prompt]
        if not self.prompt:
            raise ValueError(f"{self.request_id}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"{self.request_id}: max_new_tokens must be >= 1")

    @property
    def need_len(self) -> int:
        """KV positions this request can consume under bucket padding."""
        return _pow2ceil(len(self.prompt)) + self.max_new_tokens


@dataclasses.dataclass
class RequestResult:
    request_id: str
    tokens: List[int]
    bucket: str
    prefill_seconds: float
    total_seconds: float


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One declared decode shape: padded batch x padded state capacity."""

    max_len: int
    batch: int

    @property
    def label(self) -> str:
        return f"b{self.batch}xl{self.max_len}"


class BucketPolicy:
    """Smallest-fit over a closed, sorted set of buckets."""

    def __init__(self, buckets: Sequence[Bucket]):
        if not buckets:
            raise ValueError("need at least one bucket")
        for b in buckets:
            # the prompt block is padded to >= _MIN_PREFILL positions, so
            # a smaller capacity could overrun the KV/SSM state
            if b.max_len <= _MIN_PREFILL:
                raise ValueError(
                    f"bucket {b.label}: max_len must exceed {_MIN_PREFILL}")
            if b.batch < 1:
                raise ValueError(f"bucket {b.label}: batch must be >= 1")
        self.buckets = sorted(buckets)

    @classmethod
    def debug(cls) -> "BucketPolicy":
        return cls([Bucket(64, 2), Bucket(256, 2)])

    @classmethod
    def production(cls, batch: int = 128, max_len: int = 32768
                   ) -> "BucketPolicy":
        # one decile of short-context buckets under the headline shape
        return cls([Bucket(max_len // 8, batch), Bucket(max_len, batch)])

    def bucket_for(self, need_len: int) -> Bucket:
        for b in self.buckets:
            if need_len <= b.max_len:
                return b
        raise ValueError(
            f"request needs {need_len} positions; largest bucket holds "
            f"{self.buckets[-1].max_len}")


_DEFAULT_PAGE_SIZE = 16


def auto_paged(policy: "BucketPolicy",
               page_size: int = _DEFAULT_PAGE_SIZE) -> tuple:
    """A ``(page_count, page_size)`` geometry sized so paged mode is never
    less capable than dense: enough pages to back every slot of every
    bucket at full length, plus one pinned scratch page per lane of the
    widest bucket. Real deployments size ``page_count`` to the HBM budget
    instead — the paged benchmark's requests-per-HBM-byte metric is about
    how few of these pages a live mix actually touches."""
    pages = sum(b.batch * (b.max_len // page_size) for b in policy.buckets)
    scratch = max(b.batch for b in policy.buckets)
    return (pages + scratch, page_size)


_LATENCY_WINDOW = 4096     # p50/p99 over the most recent N requests


@dataclasses.dataclass
class BucketMetrics:
    dispatches: int = 0
    requests: int = 0
    padded_slots: int = 0
    new_tokens: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    # slot occupancy: every (slot, step) of every dispatch is a lane-step;
    # busy lane-steps carried a request's prompt or generated token. The
    # gap between them is exactly what continuous batching reclaims.
    slot_steps: int = 0
    busy_slot_steps: int = 0
    # bounded: a resident server must not grow one float per request
    latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=_LATENCY_WINDOW))
    # per-slot idle steps, one entry per (dispatch, slot)
    slot_idle: Deque[int] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=_LATENCY_WINDOW))
    # paged-KV gauges (snapshot of the shared PageAllocator after the
    # bucket's most recent dispatch; all zero in dense mode)
    pages_in_use: int = 0
    peak_pages: int = 0
    prefix_hits: int = 0

    def summary(self) -> Dict[str, float]:
        lat = list(self.latencies)
        idle = list(self.slot_idle)
        pct = quantile
        busy = self.prefill_seconds + self.decode_seconds
        return {
            "dispatches": self.dispatches,
            "requests": self.requests,
            "padded_slots": self.padded_slots,
            "new_tokens": self.new_tokens,
            "prefill_seconds": round(self.prefill_seconds, 4),
            "decode_seconds": round(self.decode_seconds, 4),
            "p50_latency_s": round(pct(lat, 0.50), 4),
            "p99_latency_s": round(pct(lat, 0.99), 4),
            "tokens_per_second": round(self.new_tokens / busy, 2)
            if busy else 0.0,
            "slot_steps": self.slot_steps,
            "busy_slot_fraction": round(
                self.busy_slot_steps / self.slot_steps, 4)
            if self.slot_steps else 0.0,
            "p50_slot_idle_steps": pct(idle, 0.50),
            "p99_slot_idle_steps": pct(idle, 0.99),
            "pages_in_use": self.pages_in_use,
            "peak_pages": self.peak_pages,
            "prefix_hits": self.prefix_hits,
        }


class ServeBatcher:
    """Admit DecodeRequests, dispatch bucketed groups on AOT executables.

    A thin consumer of :class:`repro.plan.ExecutionPlan`: the plan owns
    the mesh, the rule table, quantization decisions, and every compiled
    executable; the batcher only groups requests into buckets and drives
    the dispatch loop. Construct from an existing plan
    (``plan.make_batcher(...)``), or pass ``(cfg, mesh)`` and one is built
    internally — ``quantized=True`` then routes the decode LM head *and*
    MLP down-projection through the Pallas qmatmul paths, with shifts
    calibrated from the loaded weights (separately keyed in the cache).
    """

    def __init__(self, plan_or_cfg: Union["ExecutionPlan", ArchConfig],  # noqa: F821
                 mesh: Optional[Mesh] = None, *,
                 quantized: bool = False,
                 policy: Optional[BucketPolicy] = None,
                 cache: Optional[ExecutableCache] = None,
                 schedule: str = "fifo",
                 steps_per_dispatch: int = 1,
                 admission=None,
                 paged=None,
                 speculative: int = 0,
                 draft: Optional[str] = None):
        from repro.plan import ExecutionPlan, build_plan

        if isinstance(plan_or_cfg, ExecutionPlan):
            if mesh is not None:
                raise ValueError("pass either a plan or (cfg, mesh), "
                                 "not both")
            if quantized or cache is not None:
                raise ValueError("quantized/cache are plan decisions: set "
                                 "them in build_plan, not on the batcher")
            self.plan = plan_or_cfg
        else:
            if mesh is None:
                raise ValueError("ServeBatcher(cfg, mesh) needs a mesh")
            self.plan = build_plan(plan_or_cfg, None, mesh_spec=mesh,
                                   quantized=quantized, cache=cache)
        if schedule not in ("fifo", "continuous"):
            raise ValueError(
                f"schedule must be 'fifo' or 'continuous', got {schedule!r}")
        if steps_per_dispatch < 1:
            raise ValueError(
                f"steps_per_dispatch must be >= 1, got {steps_per_dispatch}")
        if steps_per_dispatch > 1 and schedule != "continuous":
            raise ValueError(
                "steps_per_dispatch > 1 needs schedule='continuous' — the "
                "fifo path amortizes prompts through its prefill scan")
        if admission is not None and schedule != "continuous":
            raise ValueError(
                "admission policies need schedule='continuous' — the "
                "fixed-group fifo path has no boundary seam to apply them")
        self.schedule = schedule
        self.steps_per_dispatch = steps_per_dispatch
        self.policy = policy or BucketPolicy.debug()
        # paged KV: True -> auto geometry, int -> auto with that page
        # size, (page_count, page_size) -> exact. False must mean "dense",
        # not "auto with page_size=0": bool is an int subclass, so it has
        # to be caught before the page-size branch
        if paged is True:
            paged = auto_paged(self.policy)
        elif paged is False:
            paged = None
        elif isinstance(paged, int):
            paged = auto_paged(self.policy, page_size=paged)
        elif paged is not None:
            paged = tuple(paged)
        if paged is not None:
            if schedule != "continuous":
                raise ValueError(
                    "paged KV needs schedule='continuous' — only the "
                    "masked-decode path threads page tables")
            for b in self.policy.buckets:
                if b.max_len % paged[1]:
                    raise ValueError(
                        f"bucket {b.label}: max_len must be a multiple of "
                        f"page_size={paged[1]}")
        self.paged = paged
        # speculative decode: ``speculative`` = spec_k (draft tokens per
        # micro-run, must equal steps_per_dispatch), ``draft`` names the
        # draft model — "prefix:N" runs the first N layers of the target
        # as a self-speculative draft (default: half the stack). All
        # spec/paged constraints live in repro.serve.validation — the
        # scheduler re-checks the resolved tuple through the same module
        from repro.serve.validation import (
            resolve_speculative,
            validate_paged_spec,
        )

        spec = resolve_speculative(
            speculative, draft, schedule=schedule,
            steps_per_dispatch=steps_per_dispatch,
            n_layers=self.plan.cfg.n_layers, model=self.plan.model,
            family=self.plan.cfg.family)
        if spec is not None and paged is not None:
            validate_paged_spec(spec, paged, self.policy.buckets)
        self.spec = spec
        self.pool = StatePool(self.plan, paged=paged, spec=spec)
        self.params = None
        self.metrics: Dict[str, BucketMetrics] = {}
        self._pending: Deque[DecodeRequest] = collections.deque()
        self._pending_ids: set = set()
        # ids the scheduler's admission policy shed during the last run()
        # (EDF deadline misses): completed zero times, ids reusable
        self.last_shed: set = set()
        self._scheduler = None
        if schedule == "continuous":
            from repro.serve.scheduler import ContinuousScheduler

            self._scheduler = ContinuousScheduler(
                self.plan, self.policy, self.pool,
                steps_per_dispatch=steps_per_dispatch,
                admission=admission, spec=spec)

    @property
    def scheduler(self):
        """The ContinuousScheduler (None under schedule="fifo")."""
        return self._scheduler

    # plan views (kept as attributes of record for tests/telemetry)
    @property
    def cfg(self) -> ArchConfig:
        return self.plan.cfg

    @property
    def mesh(self) -> Mesh:
        return self.plan.mesh

    @property
    def rules(self):
        return self.plan.rules

    @property
    def model(self):
        return self.plan.model

    @property
    def cache(self) -> ExecutableCache:
        return self.plan.cache

    # -- parameters -----------------------------------------------------------

    def load_params(self, params) -> "ServeBatcher":
        """Install (calibrate quantization shifts, then shard) params."""
        self.params = self.plan.shard_params(params)
        return self

    def init_demo_params(self, seed: int = 0) -> "ServeBatcher":
        """Random sharded parameters (CLI demos, benchmarks, tests)."""
        self.params = self.plan.init_params(seed)
        return self

    # -- admission ------------------------------------------------------------

    def submit(self, request: DecodeRequest) -> str:
        self.policy.bucket_for(request.need_len)   # reject unservable now
        if request.request_id in self._pending_ids:
            # silently accepting a duplicate id would last-write-win in
            # the results dict and one caller would lose their tokens
            raise ValueError(
                f"duplicate request id {request.request_id!r}: a request "
                "with this id is already queued")
        self._pending_ids.add(request.request_id)
        self._pending.append(request)
        return request.request_id

    def cancel(self, request_id: str) -> bool:
        """Cancel a queued or in-flight request; returns True if known.

        A queued request is removed from the admission queue immediately
        (it never reaches a slot). An in-flight request — only possible
        under ``schedule="continuous"`` — is marked for the scheduler,
        which frees its slot (and wipes its state lanes) at the next
        micro-run boundary; it never appears in the results. The id
        becomes reusable the moment this returns True. Under
        ``schedule="fifo"`` a request already inside a dispatch group
        cannot be canceled (the group runs to completion) and this
        returns False.

        Call this from the dispatching thread only — between ``run()``
        calls, or mid-run from the scheduler's ``on_boundary`` hook (the
        queue is not locked against a concurrently draining ``run()``;
        an async front-end that feeds cancels from other threads is the
        ROADMAP follow-on).
        """
        if request_id not in self._pending_ids:
            return False
        for i, req in enumerate(self._pending):
            if req.request_id == request_id:
                del self._pending[i]
                self._pending_ids.discard(request_id)
                return True
        if self._scheduler is not None:
            self._scheduler.cancel(request_id)
            self._pending_ids.discard(request_id)
            return True
        return False

    def warmup(self, bucket: Bucket, prompt_len: int = 1) -> None:
        """Compile a bucket's executables ahead of traffic."""
        if self.schedule == "continuous":
            self._executable("masked_decode", bucket, 0)
        else:
            self._executable("prefill", bucket,
                             self._prefill_len(prompt_len))
            self._executable("decode", bucket, 0)

    # -- dispatch -------------------------------------------------------------

    def run(self) -> Dict[str, RequestResult]:
        """Drain the queue: group -> dispatch until empty.

        ``schedule="continuous"`` hands the whole queue to the
        :class:`~repro.serve.scheduler.ContinuousScheduler` (slot reuse
        inside in-flight dispatches); the default fixed-group FIFO path
        below is kept as the fallback.
        """
        if self.params is None:
            raise RuntimeError("no parameters loaded "
                               "(load_params / init_demo_params)")
        results: Dict[str, RequestResult] = {}
        if self._scheduler is not None:
            results = self._scheduler.run(self._pending, self.params,
                                          self.metrics)
            self.last_shed = self._scheduler.drain_shed()
            self._pending_ids.difference_update(self.last_shed)
        else:
            while self._pending:
                group, bucket = self._form_group()
                for res in self._dispatch(group, bucket):
                    results[res.request_id] = res
        self._pending_ids.difference_update(results)
        return results

    def _form_group(self):
        """FIFO head picks the bucket; fill with queued requests that fit."""
        first = self._pending.popleft()
        bucket = self.policy.bucket_for(first.need_len)
        group = [first]
        kept: Deque[DecodeRequest] = collections.deque()
        while self._pending and len(group) < bucket.batch:
            req = self._pending.popleft()
            if req.need_len <= bucket.max_len:
                group.append(req)
            else:
                kept.append(req)
        kept.extend(self._pending)
        self._pending = kept
        return group, bucket

    def _prefill_len(self, max_prompt: int) -> int:
        return max(_MIN_PREFILL, _pow2ceil(max_prompt))

    def _executable(self, kind: str, bucket: Bucket,
                    prefill_len: int) -> CachedExecutable:
        kw = {}
        if kind == "masked_decode" and self.paged is not None:
            kw["paged"] = self.paged
        if kind == "masked_decode" and self.spec is not None:
            kw["spec"] = self.spec
        return self.plan.serve_executable(
            kind, batch=bucket.batch, max_len=bucket.max_len,
            prefill_len=prefill_len,
            steps_per_dispatch=self.steps_per_dispatch
            if kind == "masked_decode" else 1, **kw)

    def _dispatch(self, group: List[DecodeRequest],
                  bucket: Bucket) -> List[RequestResult]:
        t0 = time.perf_counter()
        B, P = bucket.batch, self._prefill_len(
            max(len(r.prompt) for r in group))
        prefill = self._executable("prefill", bucket, P)
        decode = self._executable("decode", bucket, 0)

        prompt = np.zeros((B, P), np.int32)
        lengths = np.ones((B,), np.int32)       # inert slots: 1-token prompt
        for slot, req in enumerate(group):
            prompt[slot, :len(req.prompt)] = req.prompt
            lengths[slot] = len(req.prompt)

        _, _, prompt_sh, len_sh = prefill.bundle.in_shardings
        state = self.pool.acquire(B, bucket.max_len)
        tok_out, state = prefill.compiled(
            self.params, state,
            jax.device_put(prompt, prompt_sh),
            jax.device_put(lengths, len_sh))
        jax.block_until_ready(tok_out)
        t_prefill = time.perf_counter() - t0
        prefill_np = np.asarray(jax.device_get(tok_out))     # [B, P]

        # decode loop: everyone continues from position P in lockstep
        steps = max((r.max_new_tokens - (P - len(r.prompt) + 1)
                     for r in group), default=0)
        steps = max(steps, 0)
        tok_sh = decode.bundle.in_shardings[2]
        pos_sh = decode.bundle.in_shardings[3]
        argmax = self.plan.token_argmax(tok_sh)
        last = jax.device_put(tok_out[:, -1], tok_sh)
        decoded = []
        for t in range(steps):
            logits, state = decode.compiled(
                self.params, state, last,
                jax.device_put(np.int32(P + t), pos_sh))
            last = argmax(logits)
            decoded.append(last)
        if decoded:
            jax.block_until_ready(decoded[-1])
        decoded_np = (np.stack([np.asarray(jax.device_get(t))
                                for t in decoded], axis=1)
                      if decoded else np.zeros((B, 0), np.int32))
        self.pool.release(B, bucket.max_len, state)
        t_total = time.perf_counter() - t0

        results = []
        for slot, req in enumerate(group):
            li = len(req.prompt)
            gen = np.concatenate(
                [prefill_np[slot, li - 1:], decoded_np[slot]])
            results.append(RequestResult(
                request_id=req.request_id,
                tokens=[int(t) for t in gen[:req.max_new_tokens]],
                bucket=bucket.label,
                prefill_seconds=t_prefill,
                total_seconds=t_total,
            ))

        m = self.metrics.setdefault(bucket.label, BucketMetrics())
        m.dispatches += 1
        m.requests += len(group)
        m.padded_slots += B - len(group)
        m.new_tokens += sum(len(r.tokens) for r in results)
        m.prefill_seconds += t_prefill
        m.decode_seconds += t_total - t_prefill
        m.latencies.extend([t_total] * len(group))
        # slot occupancy: the group runs P prefill + `steps` decode
        # positions in lockstep; a slot is busy while it still carries
        # prompt or requested tokens, idle from its finish to group end
        span = P + steps
        m.slot_steps += span * B
        for slot in range(B):
            busy_slot = 0
            if slot < len(group):
                req, res = group[slot], results[slot]
                busy_slot = min(span, len(req.prompt) + len(res.tokens) - 1)
            m.busy_slot_steps += busy_slot
            m.slot_idle.append(span - busy_slot)
        return results

    # -- observability --------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        out = {
            "cache": self.cache.stats(),
            "pool": self.pool.stats(),
            "buckets": {k: m.summary() for k, m in self.metrics.items()},
        }
        if self._scheduler is not None:
            out["scheduler"] = self._scheduler.stats()
        if getattr(self.pool, "allocator", None) is not None:
            out["paged"] = self.pool.allocator.stats()
        return out
