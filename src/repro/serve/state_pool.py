"""Per-bucket resident decode-state pools (KV cache / SSM state).

Allocating a fresh sharded KV cache per request costs a device_put of the
largest tensors in the serving path; the paper's on-chip regime instead
keeps state RESIDENT and re-initializes it in place. ``StatePool`` does
the host-mesh equivalent: one pool of state pytrees per shape bucket,
acquired zeroed at dispatch and released back after the request group
completes. Reuse zeroes through a donated jitted reset, so the released
buffers are recycled rather than reallocated.

Lifecycle per dispatch:

    state = pool.acquire(batch, max_len)    # zeroed, sharded, resident
    ... prefill / decode executables consume+donate it ...
    pool.release(batch, max_len, final_state)

The step executables donate their state argument, so the pytree handed
back by ``release`` is a *different* buffer than the one acquired — the
pool only tracks counts per bucket, never object identity.

Paged mode (``StatePool(plan, paged=(page_count, page_size))``) splits
every state pytree in two (see docs/memory_model.md):

* the **pooled KV leaves** (``cache_k``/``cache_v``) live in ONE shared
  physical page pool in the ``[..., page_count, page_size, ...]`` layout
  — built once, shared by every bucket, and NEVER zeroed on reuse
  (zeroing would destroy prefix pages other requests still reference);
  a host-side :class:`repro.serve.paging.PageAllocator` hands out page
  ids, and stale page contents are harmless because a slot only reads
  cache positions its own prefill/decode steps (or a shared prefix)
  wrote;
* the **dense remainder** (SSM/conv state, cross-attention caches) keeps
  the per-bucket pooling above — acquired zeroed, slot-wiped on cancel.

``acquire`` merges the pooled leaves into the bucket's dense remainder
and ``release`` extracts the (donated-through) pooled leaves back out,
so exactly one in-flight dispatch owns the pool at a time — which the
continuous scheduler's sequential dispatch loop guarantees.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

BucketShape = Tuple[int, int]        # (batch, max_len)


@dataclasses.dataclass
class _BucketPool:
    free: List[Any]
    created: int = 0
    reused: int = 0
    in_use: int = 0
    slot_resets: int = 0     # host-side per-slot wipes (cancellation path)
    slots_wiped: int = 0     # lanes zeroed across those wipes


class StatePool:
    """Pools of decode-state pytrees, one per (batch, max_len) bucket.

    A thin consumer of :class:`repro.plan.ExecutionPlan`: fresh state
    allocation (shapes, shardings, stage placement of the layers dim) is
    the plan's job; the pool only tracks reuse.
    """

    def __init__(self, plan, paged: Optional[Tuple[int, int]] = None,
                 spec: Optional[Tuple[int, int]] = None):
        self.plan = plan
        self.paged = tuple(paged) if paged else None
        # speculative decode: (spec_k, draft_layers) — fresh states carry
        # the draft_-prefixed layer-prefix KV twins alongside the target's
        self.spec = tuple(spec) if spec else None
        self.allocator = None
        if self.paged is not None:
            from repro.serve.paging import PageAllocator

            self.allocator = PageAllocator(*self.paged)
        self._lock = threading.Lock()
        self._pools: Dict[BucketShape, _BucketPool] = {}
        self._reset_fns: Dict[BucketShape, Any] = {}
        self._slot_reset_fns: Dict[BucketShape, Any] = {}
        self.slot_resets = 0
        # paged mode: the one shared physical page pool (lazily built)
        # and a checkout guard — exactly one dispatch may own it
        self._pool_leaves = None
        self._pool_out = False

    def _fresh(self, bucket: BucketShape):
        batch, max_len = bucket
        if self.paged is None:
            return self.plan.fresh_decode_state(batch, max_len,
                                                spec=self.spec)
        return self.plan.fresh_decode_state(batch, max_len,
                                            paged=self.paged, only="dense",
                                            spec=self.spec)

    def _checkout_pool(self, bucket: BucketShape):
        """The shared paged KV leaves, exclusively, for one dispatch."""
        with self._lock:
            if self._pool_out:
                raise RuntimeError(
                    "paged state pool is already checked out: paged mode "
                    "supports one in-flight dispatch at a time")
            self._pool_out = True
            leaves = self._pool_leaves
            self._pool_leaves = None
        if leaves is None:
            batch, max_len = bucket
            leaves = self.plan.fresh_decode_state(
                batch, max_len, paged=self.paged, only="pool",
                spec=self.spec)
        return leaves

    def _pool(self, bucket: BucketShape) -> _BucketPool:
        if bucket not in self._pools:
            self._pools[bucket] = _BucketPool(free=[])
        return self._pools[bucket]

    def _reset(self, bucket: BucketShape, state):
        """Zero a released state in place (buffers donated and recycled)."""
        fn = self._reset_fns.get(bucket)
        if fn is None:
            fn = jax.jit(
                lambda s: jax.tree.map(jnp.zeros_like, s), donate_argnums=0
            )
            self._reset_fns[bucket] = fn
        return fn(state)

    def acquire(self, batch: int, max_len: int):
        """A zeroed state pytree for the bucket, reusing released buffers.

        Paged mode returns the bucket's zeroed DENSE remainder merged
        with the shared (never-zeroed) page-pool leaves.
        """
        bucket = (batch, max_len)
        with self._lock:
            pool = self._pool(bucket)
            if pool.free:
                state = pool.free.pop()
                pool.reused += 1
                pool.in_use += 1
            else:
                state = None
                pool.created += 1
                pool.in_use += 1
        # build/zero outside the lock: both can take device time
        if state is None:
            state = self._fresh(bucket)
        else:
            state = self._reset(bucket, state)
        if self.paged is not None:
            state = dict(state, **self._checkout_pool(bucket))
        return state

    def reset_slots(self, batch: int, max_len: int, state, slot_mask):
        """Zero selected batch lanes of a LIVE state pytree, in place.

        The continuous scheduler's host-side reset: when a request is
        CANCELED at a micro-run boundary its lanes are wiped through this
        immediately (the state must not carry a dead request's KV/SSM
        past the boundary, successor or not); ordinary finish-then-refill
        relies on the in-step ``fresh`` lane instead. ``slot_mask`` is a
        [batch] bool vector;
        the per-bucket jitted reset donates the state, so the wipe reuses
        the resident buffers (no reallocation, no executable-shape
        change). Each state leaf's batch axis comes from the plan's
        decode-state specs (``"batch"`` logical axis), so KV caches and
        SSM/conv states are handled uniformly.
        """
        bucket = (batch, max_len)
        fn = self._slot_reset_fns.get(bucket)
        if fn is None:
            from repro.models.base import (
                paged_state_specs,
                state_batch_axes,
                wipe_state_slots,
            )

            sspecs = self.plan.model.decode_state_specs(batch, max_len)
            if self.spec is not None:
                from repro.models.base import spec_state_specs

                sspecs = dict(sspecs,
                              **spec_state_specs(sspecs, self.spec[1]))
            if self.paged is not None:
                # pooled leaves have no batch axis (-1): the wipe skips
                # them — a canceled request's pages go back to the
                # allocator instead, and stale page contents are never
                # read (a slot only attends over positions it wrote)
                sspecs = paged_state_specs(sspecs, *self.paged)
            batch_axes = state_batch_axes(sspecs)
            fn = jax.jit(
                lambda state, mask: wipe_state_slots(state, mask,
                                                     batch_axes),
                donate_argnums=0)
            self._slot_reset_fns[bucket] = fn
        with self._lock:
            self.slot_resets += 1
            pool = self._pool(bucket)
            pool.slot_resets += 1
            pool.slots_wiped += int(sum(bool(m) for m in slot_mask))
        return fn(state, jnp.asarray(slot_mask, jnp.bool_))

    def release(self, batch: int, max_len: int, state) -> None:
        bucket = (batch, max_len)
        if self.paged is not None:
            from repro.models.base import is_paged_state_key

            # the executables donated the state through, so the pooled
            # leaves inside it ARE the current page pool (draft KV twins
            # included in speculative mode): check it back in for the
            # next dispatch and free-list only the remainder
            leaves = {k: v for k, v in state.items()
                      if is_paged_state_key(k)}
            state = {k: v for k, v in state.items()
                     if not is_paged_state_key(k)}
            with self._lock:
                self._pool_leaves = leaves
                self._pool_out = False
        with self._lock:
            pool = self._pool(bucket)
            pool.free.append(state)
            pool.in_use = max(0, pool.in_use - 1)

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {
                f"{b}x{m}": {
                    "created": p.created,
                    "reused": p.reused,
                    "in_use": p.in_use,
                    "free": len(p.free),
                    "slot_resets": p.slot_resets,
                    "slots_wiped": p.slots_wiped,
                }
                for (b, m), p in sorted(self._pools.items())
            }
