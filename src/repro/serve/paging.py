"""Host-side page allocator for the paged KV cache.

The device holds one physical page pool per model — KV-cache leaves
shaped ``[..., page_count, page_size, kv_heads, head_dim]`` instead of
per-bucket ``[..., batch, max_len, ...]`` slabs (see
``docs/memory_model.md``). Everything that decides WHICH pages a slot
reads and writes is plain host bookkeeping and lives here:

* a free list plus per-page reference counts (a page is recycled the
  moment its count hits zero);
* a chained-hash **prefix cache**: when a slot finishes feeding a full
  page worth of prompt tokens, that page is published under the hash of
  the token prefix it encodes, and later requests whose prompt starts
  with the same tokens map the published page read-only into their own
  page table — skipping prefill for the shared span;
* **copy-on-write by allocation**: sharing is whole-page and capped at
  the last full prompt page, so a shared page is never written by any
  slot — the first divergent (or partial) page is simply allocated
  private and recomputed, which is the COW fork;
* per-lane **scratch pages** that absorb the writes of empty or
  self-masked schedule lanes, so the device step never needs a branch.

Pages in the pool are content-addressed only through this allocator;
the device kernels see nothing but int32 page tables. The allocator is
dependency-free and fully deterministic, which is what the hypothesis
property suite in ``tests/test_paging.py`` leans on.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple


def _page_hash(prev: bytes, tokens: Sequence[int]) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(prev)
    h.update(",".join(str(int(t)) for t in tokens).encode())
    return h.digest()


def prefix_page_hashes(prompt: Sequence[int], page_size: int) -> List[bytes]:
    """Chained hash per FULL prompt page: hash[i] covers prompt[:(i+1)*ps]."""
    out, h = [], b"\x00"
    for i in range(len(prompt) // page_size):
        h = _page_hash(h, prompt[i * page_size:(i + 1) * page_size])
        out.append(h)
    return out


@dataclasses.dataclass
class SlotPages:
    """One slot's page-table lease, returned by :meth:`PageAllocator.admit`.

    ``pages[i]`` is the physical page holding local positions
    ``[i*page_size, (i+1)*page_size)``; the first ``shared`` entries are
    read-only prefix-cache hits, the rest are private to this slot.
    ``draft`` continues the run past ``pages``: revocable pages absorbing
    one micro-run's speculative writes, resolved at the boundary by
    :meth:`PageAllocator.resolve_draft` (committed pages splice into
    ``pages``, the rest roll back to the free list).
    """

    pages: List[int]
    shared: int                  # leading read-only (prefix-hit) pages
    prompt: Tuple[int, ...]
    published: int               # prompt pages already in the prefix cache
    shared_len: int = 0          # prefix tokens whose prefill is skipped
    draft: List[int] = dataclasses.field(default_factory=list)
    released: bool = False


class PageAllocator:
    """Free list + refcounts + prefix cache over ``page_count`` pages.

    Invariants (property-tested):
      * every page is free, scratch, or refcounted > 0 — counts conserve;
      * a page with refcount > 1 is never any slot's writable page
        (writable == private == the slot holds its only lease);
      * publishing moves a page to refcount >= 2 (slot + cache) and it
        survives the slot's release at refcount 1 until evicted.
    """

    def __init__(self, page_count: int, page_size: int):
        if page_count <= 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry {page_count}x{page_size}")
        self.page_count = int(page_count)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(page_count - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        self._scratch: List[int] = []
        # prefix cache: chained page hash -> physical page (LRU ordered)
        self._prefix: "OrderedDict[bytes, int]" = OrderedDict()
        # stats
        self.peak_pages = 0
        self.prefix_hits = 0          # admissions that reused >= 1 page
        self.shared_pages_served = 0
        self.skipped_tokens = 0
        self.prompt_tokens = 0
        self.evictions = 0
        self.draft_pages_committed = 0
        self.draft_pages_rolled_back = 0

    # -- accounting ---------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return self.page_count - len(self._free)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    def _take(self) -> int:
        page = self._free.pop()
        self._refs[page] = self._refs.get(page, 0) + 1
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return page

    def _incref(self, page: int) -> None:
        self._refs[page] += 1

    def _decref(self, page: int) -> None:
        self._refs[page] -= 1
        if self._refs[page] == 0:
            del self._refs[page]
            self._free.append(page)

    def _evictable(self) -> int:
        return sum(1 for p in self._prefix.values() if self._refs[p] == 1)

    def _evict_one(self) -> bool:
        for h, p in self._prefix.items():       # oldest first (LRU)
            if self._refs[p] == 1:
                del self._prefix[h]
                self._decref(p)
                self.evictions += 1
                return True
        return False

    # -- scratch ------------------------------------------------------------

    def scratch(self, n: int) -> List[int]:
        """First ``n`` scratch pages (pinned forever; grows on demand)."""
        while len(self._scratch) < n:
            if not self._free and not self._evict_one():
                raise RuntimeError("page pool exhausted allocating scratch")
            self._scratch.append(self._take())
        return self._scratch[:n]

    # -- admission ----------------------------------------------------------

    def probe(self, prompt: Sequence[int]) -> int:
        """Longest reusable prefix of ``prompt`` in TOKENS (page-aligned,
        capped at ``len(prompt) - 1`` so a slot always feeds at least one
        prompt token — the result-slicing/feed-lane contract)."""
        ps = self.page_size
        cap = (len(prompt) - 1) // ps
        hit = 0
        for h in prefix_page_hashes(prompt, ps)[:cap]:
            if h not in self._prefix:
                break
            hit += 1
        return hit * ps

    def spec_demand(self, k: int) -> int:
        """Worst-case transient draft pages one speculative lane holds
        mid-micro-run: the ``k`` draft/verify positions can straddle a
        page boundary, so one extra page on top of the span."""
        return -(-k // self.page_size) + 1

    def can_admit(self, prompt: Sequence[int], need: int, *,
                  reserve: int = 0, lazy: bool = False) -> bool:
        ps = self.page_size
        cap = (len(prompt) - 1) // ps
        shared: List[int] = []
        for h in prefix_page_hashes(prompt, ps)[:cap]:
            if h not in self._prefix:
                break
            shared.append(self._prefix[h])
        span = min(need, len(prompt)) if lazy else need
        n_pages = -(-span // ps)
        private = n_pages - len(shared)
        # the shared hits get pinned at admit, so they must not count
        # toward the evictable budget even when only the cache holds them
        shared_set = set(shared)
        evictable = sum(1 for p in self._prefix.values()
                        if self._refs[p] == 1 and p not in shared_set)
        return private + reserve <= len(self._free) + evictable

    def admit(self, prompt: Sequence[int], need: int, *,
              lazy: bool = False) -> Optional[SlotPages]:
        """Lease pages covering local positions ``[0, need)``.

        With ``lazy=True`` (speculative mode) only the prompt span is
        leased up front; the run grows at each dispatch through
        :meth:`draft_lease` / :meth:`resolve_draft`, so rejected drafts
        never hold pages past the micro-run boundary.

        Returns None if the pool cannot cover the private span even
        after evicting unpinned prefix pages (caller skips admission).
        """
        ps = self.page_size
        cap = (len(prompt) - 1) // ps
        hashes = prefix_page_hashes(prompt, ps)
        shared: List[int] = []
        for h in hashes[:cap]:
            if h not in self._prefix:
                break
            shared.append(self._prefix[h])
        for h, p in zip(hashes, shared):
            self._incref(p)                     # pin before any eviction
            self._prefix.move_to_end(h)         # LRU touch
        span = min(need, len(prompt)) if lazy else need
        n_pages = -(-span // ps)
        private_needed = n_pages - len(shared)  # always >= 1: sharing is
        # capped at the last FULL prompt page, and span > len(prompt) - 1
        while private_needed > len(self._free):
            if not self._evict_one():
                for p in shared:                # roll back the pins
                    self._decref(p)
                return None
        pages = list(shared) + [self._take() for _ in range(private_needed)]
        self.prompt_tokens += len(prompt)
        if shared:
            self.prefix_hits += 1
            self.shared_pages_served += len(shared)
            self.skipped_tokens += len(shared) * ps
        return SlotPages(pages=pages, shared=len(shared),
                         prompt=tuple(int(t) for t in prompt),
                         published=len(shared),
                         shared_len=len(shared) * ps)

    # -- publish / release ---------------------------------------------------

    def publish(self, lease: SlotPages, fed: int) -> int:
        """Register prompt pages fully fed so far into the prefix cache.

        ``fed`` is the number of prompt tokens whose KV the slot has
        written. A page enters the cache with its own reference (so it
        outlives the slot); pages whose content hash is already cached
        stay private. Returns the number of pages newly published.
        """
        if lease.released:
            return 0
        ps = self.page_size
        hashes = prefix_page_hashes(lease.prompt, ps)
        done = 0
        while (lease.published < len(hashes)
               and (lease.published + 1) * ps <= fed
               and lease.published < len(lease.pages)):
            i = lease.published
            h = hashes[i]
            if h not in self._prefix:
                self._prefix[h] = lease.pages[i]
                self._incref(lease.pages[i])
                done += 1
            lease.published += 1
        return done

    # -- draft leases (speculative lanes) ------------------------------------

    def draft_lease(self, lease: SlotPages, hi: int) -> bool:
        """Extend the lease's page run with revocable draft pages so that
        local positions ``[0, hi)`` are all mapped for one micro-run's
        draft + verify writes. Returns False — lease untouched — when the
        pool cannot cover the span even after LRU eviction; the caller
        must then park the slot instead of dispatching it."""
        if lease.released:
            raise ValueError("draft_lease on a released lease")
        ps = self.page_size
        grow = -(-hi // ps) - (len(lease.pages) + len(lease.draft))
        if grow <= 0:
            return True
        while grow > len(self._free):
            if not self._evict_one():
                return False
        for _ in range(grow):
            lease.draft.append(self._take())
        return True

    def resolve_draft(self, lease: SlotPages, committed_local: int) -> None:
        """Boundary resolution of a draft lease: every draft page holding
        at least one committed local position (``< committed_local``)
        splices into the committed run and follows the normal
        publish/refcount lifecycle; rejected pages roll back to the free
        list. The scheduler's ``slot.start`` bump already rewinds the
        local clock, so a later micro-run re-covers the freed span with
        fresh draft pages."""
        if lease.released or not lease.draft:
            lease.draft = []
            return
        ps = self.page_size
        keep: List[int] = []
        for j, p in enumerate(lease.draft, start=len(lease.pages)):
            if j * ps < committed_local:
                keep.append(p)
            else:
                self._decref(p)
                self.draft_pages_rolled_back += 1
        lease.pages.extend(keep)
        self.draft_pages_committed += len(keep)
        lease.draft = []

    def release(self, lease: SlotPages) -> None:
        """Drop the slot's reference on every leased page (boundary-time
        reclaim on finish/cancel/shed). Published pages survive at
        refcount >= 1 under the prefix cache; purely private pages go
        straight back to the free list. Idempotent: a finish and a
        boundary cancel/shed landing on the same lease must not
        double-decref."""
        if lease.released:
            return
        lease.released = True
        for p in lease.pages:
            self._decref(p)
        for p in lease.draft:
            self._decref(p)
        lease.pages = []
        lease.draft = []

    # -- stats ----------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        total = self.prompt_tokens or 1
        return {
            "page_size": self.page_size,
            "page_count": self.page_count,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.pages_free,
            "peak_pages": self.peak_pages,
            "scratch_pages": len(self._scratch),
            "prefix_entries": len(self._prefix),
            "prefix_hits": self.prefix_hits,
            "shared_pages_served": self.shared_pages_served,
            "skipped_prefill_tokens": self.skipped_tokens,
            "prefill_skip_rate": self.skipped_tokens / total,
            "evictions": self.evictions,
            "draft_pages_committed": self.draft_pages_committed,
            "draft_pages_rolled_back": self.draft_pages_rolled_back,
        }
