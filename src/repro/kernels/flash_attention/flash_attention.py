"""Pallas TPU flash-attention (forward) — beyond-paper serving kernel.

The roofline analysis (EXPERIMENTS.md §Roofline) shows the prefill cells are
memory-bound on attention-score traffic: the pure-JAX chunked attention
materializes softmax(QK^T) blocks in HBM. This kernel keeps the running
online-softmax state (m, l, acc) in VMEM across KV blocks, so scores never
leave the core — the standard flash schedule mapped onto the same
BlockSpec/VMEM machinery as the paper's qmatmul kernel.

Grid = (BH, Sq/bq, Sk/bk), KV innermost ("arbitrary"); scratch carries the
per-(q-row) max, sum, and output accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams after 0.4.x; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, nk: int, bq: int, bk: int, scale: float,
                  causal: bool, q_start: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[0].astype(jnp.float32)                    # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                           # [bq, bk]

    if causal:
        qpos = q_start + iq * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]                                 # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                     # [bq, bk]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,   # [BH, Sq, hd]
    k: jnp.ndarray,   # [BH, Sk, hd]
    v: jnp.ndarray,   # [BH, Sk, hd]
    *,
    causal: bool = True,
    q_start: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    grid = (BH, Sq // block_q, Sk // block_k)
    scale = hd**-0.5
    kernel = functools.partial(
        _flash_kernel,
        nk=Sk // block_k, bq=block_q, bk=block_k, scale=scale,
        causal=causal, q_start=q_start,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
