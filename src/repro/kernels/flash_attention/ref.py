"""Pure-jnp attention oracle for the flash kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,   # [BH, Sq, hd]
    k: jnp.ndarray,   # [BH, Sk, hd]
    v: jnp.ndarray,   # [BH, Sk, hd]
    *,
    causal: bool = True,
    q_start: int = 0,
) -> jnp.ndarray:
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        qi = q_start + jnp.arange(Sq)
        mask = jnp.arange(Sk)[None, :] <= qi[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
