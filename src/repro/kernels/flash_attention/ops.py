"""Jit'd wrapper for the flash-attention kernel: padding + auto-interpret."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=(
    "causal", "q_start", "block_q", "block_k", "interpret"))
def _padded(q, k, v, *, causal, q_start, block_q, block_k, interpret):
    return flash_attention_pallas(
        q, k, v, causal=causal, q_start=q_start,
        block_q=block_q, block_k=block_k, interpret=interpret)


def flash_attention(
    q: jnp.ndarray,   # [BH, Sq, hd]
    k: jnp.ndarray,   # [BH, Sk, hd]
    v: jnp.ndarray,   # [BH, Sk, hd]
    *,
    causal: bool = True,
    q_start: int = 0,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bq = block_q or (128 if not interpret else min(_ceil_to(Sq, 8), 32))
    bk = block_k or (128 if not interpret else min(_ceil_to(Sk, 8), 32))
    Sqp, Skp = _ceil_to(Sq, bq), _ceil_to(Sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0)))
    if Skp > Sk:
        # mask padded keys by pushing them outside the causal window; for
        # non-causal, bias via a large-negative value through v? Simplest:
        # rely on causal masking when padded; for non-causal inputs the
        # caller must pass block-divisible Sk.
        if not causal:
            raise ValueError("non-causal flash requires Sk % block_k == 0")
        # padded keys have kpos > every valid qpos only if Sq == Sk
        if Sqp != Skp:
            raise ValueError("causal flash padding requires Sq == Sk")
    out = _padded(qp, kp, vp, causal=causal, q_start=q_start,
                  block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :Sq]
