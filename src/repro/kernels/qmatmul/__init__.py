from repro.kernels.qmatmul.ops import qlinear
from repro.kernels.qmatmul.ref import qlinear_ref

__all__ = ["qlinear", "qlinear_ref"]
