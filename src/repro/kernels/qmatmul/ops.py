"""Jit'd public wrapper around the qmatmul Pallas kernel.

Handles the zero-padding that AIE4ML's memory tiles provide in hardware
(arbitrary layer shapes padded to tile multiples; padding is sliced away
after the call), picks TPU-legal block shapes, and auto-selects interpret
mode on non-TPU backends so the same call validates on CPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.qmatmul.qmatmul import qmatmul_pallas


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _auto_blocks(M: int, K: int, N: int, on_tpu: bool) -> Tuple[tuple, tuple]:
    """Choose (bm, bk, bn) and (qm, qn).

    On TPU the minor dim must be a multiple of 128 and the second-minor a
    multiple of 32 for int8 — we keep 128-aligned blocks and shrink the
    macro factor for small problems. In interpret mode (CPU validation) any
    block works, so we shrink blocks to the problem to keep runtime small.
    """
    if on_tpu:
        bm = 128 if M >= 512 else 64
        bk = 128
        bn = 128 if N >= 512 else 128
        qm = 2 if M >= 2 * bm else 1
        qn = 2 if N >= 2 * bn else 1
        return (bm, bk, bn), (qm, qn)
    # interpret mode: small blocks, still exercising the 2x2 scheme
    bm = min(_ceil_to(max(M // 2, 1), 8), 64)
    bk = min(_ceil_to(K, 8), 64)
    bn = min(_ceil_to(max(N // 2, 1), 8), 64)
    qm = 2 if M > bm else 1
    qn = 2 if N > bn else 1
    return (bm, bk, bn), (qm, qn)


@functools.partial(
    jax.jit,
    static_argnames=(
        "shift", "relu", "out_dtype", "rounding", "block", "acc_blocks",
        "interpret",
    ),
)
def _qlinear_padded(x, w, bias, *, shift, relu, out_dtype, rounding, block,
                    acc_blocks, interpret):
    return qmatmul_pallas(
        x, w, bias,
        shift=shift, relu=relu, out_dtype=out_dtype, rounding=rounding,
        block=block, acc_blocks=acc_blocks, interpret=interpret,
    )


def qlinear(
    x: jnp.ndarray,                 # (M, K) int8/int16
    w: jnp.ndarray,                 # (K, N) int8/int16
    bias: Optional[jnp.ndarray] = None,  # (N,) int32
    *,
    shift: int,
    relu: bool = False,
    out_dtype: str = "int8",
    rounding: str = "half_up",
    block: Optional[tuple] = None,
    acc_blocks: Optional[tuple] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused quantized linear: y = SRS(x @ w + bias), optional ReLU.

    Bit-exact against :func:`repro.kernels.qmatmul.ref.qlinear_ref`.
    """
    M, K = x.shape
    _, N = w.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block is None or acc_blocks is None:
        ablock, aacc = _auto_blocks(M, K, N, on_tpu=not interpret)
        block = block or ablock
        acc_blocks = acc_blocks or aacc
    bm, bk, bn = block
    qm, qn = acc_blocks
    Mp = _ceil_to(M, qm * bm)
    Kp = _ceil_to(K, bk)
    Np = _ceil_to(N, qn * bn)
    xp = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    bp = None
    if bias is not None:
        bp = jnp.pad(bias.astype(jnp.int32), (0, Np - N))
    y = _qlinear_padded(
        xp, wp, bp,
        shift=shift, relu=relu, out_dtype=out_dtype, rounding=rounding,
        block=(bm, bk, bn), acc_blocks=(qm, qn), interpret=interpret,
    )
    return y[:M, :N]
