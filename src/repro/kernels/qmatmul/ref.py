"""Pure-jnp oracle for the quantized fused linear ("x86 simulation" role).

Implements Algorithm 1 of the paper exactly:

    acc = A @ W (+ bias broadcast into the accumulators)   # int32
    y   = SRS(acc, shift)          # shift-round-saturate to out_dtype
    y   = max(y, 0) if USERELU     # epilogue activation
    store y

All integer arithmetic is int32 with two's-complement wraparound, identical
to the Pallas kernel, so the two paths are bit-exact by construction.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.quant.srs import srs


def qlinear_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    shift: int,
    relu: bool = False,
    out_dtype: str = "int8",
    rounding: str = "half_up",
) -> jnp.ndarray:
    """y[M,N] = SRS(x[M,K] @ w[K,N] + bias[N]) with optional fused ReLU."""
    acc = jnp.dot(
        x.astype(jnp.int32), w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    y = srs(acc, shift, out_dtype, rounding)
    if relu:
        y = jnp.maximum(y, jnp.zeros((), dtype=y.dtype))
    return y
