"""Pallas TPU kernel: blocked quantized matmul with fused bias + ReLU + SRS.

TPU adaptation of the paper's aie::mmul 2x2-accumulator linear kernel
(Sec. III-A). The mapping:

  AIE concept                       ->  this kernel
  ---------------------------------------------------------------------
  aie::mmul <M,K,N> native tile     ->  MXU-aligned VMEM blocks (bm,bk,bn)
  2x2 accumulator scheme C00..C11   ->  (qm x qn) macro-tile: each grid step
                                        loads qm A-blocks and qn W-blocks and
                                        updates qm*qn accumulator quadrants,
                                        reusing every loaded block qn (resp.
                                        qm) times — same arithmetic-intensity
                                        amplification as the paper's scheme
  bias loaded into acc in prologue  ->  acc initialized from bias on k==0
  SRS fused into the store (VST.SRS)->  shift-round-saturate on k==K-1,
                                        single store of the finished tile
  ReLU in the epilogue              ->  max(y,0) after SRS, before the store
  ping-pong local buffers           ->  Pallas software pipelining across the
                                        grid (automatic multi-buffering of
                                        HBM->VMEM block streams)

Grid = (M/(qm*bm), N/(qn*bn), K/bk) with K innermost ("arbitrary" semantics)
so the int32 accumulator scratch lives in VMEM across the contraction, and
M/N dimensions are "parallel" — the same loop nest as Algorithm 1.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.srs import INT_RANGE

# jax renamed TPUCompilerParams -> CompilerParams after 0.4.x; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_NEUTRAL = 0


def _srs_block(acc, shift: int, out_dtype: str, rounding: str):
    """Shift-round-saturate a finished accumulator block (int32 math)."""
    if shift > 0:
        if rounding == "floor":
            acc = acc >> shift
        elif rounding == "half_up":
            acc = (acc + jnp.int32(1 << (shift - 1))) >> shift
        elif rounding == "half_even":
            floor = acc >> shift
            rem = acc & jnp.int32((1 << shift) - 1)
            half = jnp.int32(1 << (shift - 1))
            bump = (rem > half) | ((rem == half) & ((floor & 1) == 1))
            acc = floor + bump.astype(jnp.int32)
        else:
            raise ValueError(f"unknown rounding {rounding}")
    lo, hi = INT_RANGE[out_dtype]
    return jnp.clip(acc, lo, hi).astype(out_dtype)


def _qmatmul_kernel(
    x_ref, w_ref, b_ref, o_ref, acc_ref,
    *, nk: int, qm: int, qn: int, bm: int, bn: int,
    shift: int, relu: bool, use_bias: bool,
    out_dtype: str, rounding: str,
):
    k = pl.program_id(2)

    # ---- prologue: ACC_INIT / BIAS_LOAD (Algorithm 1 lines 3-6) ----
    @pl.when(k == 0)
    def _init():
        if use_bias:
            bias_row = b_ref[0, :].astype(jnp.int32)  # (qn*bn,)
            acc_ref[...] = jnp.broadcast_to(bias_row[None, :], acc_ref.shape)
        else:
            acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.int32)

    # ---- steady state: the (qm x qn) accumulator scheme ----
    # Load each A row-block and W col-block once; update all quadrants.
    for i in range(qm):
        a_i = x_ref[i * bm:(i + 1) * bm, :]
        for j in range(qn):
            w_j = w_ref[:, j * bn:(j + 1) * bn]
            acc_ref[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn] += (
                jax.lax.dot_general(
                    a_i, w_j,
                    dimension_numbers=(((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
            )

    # ---- epilogue: SRS -> ReLU -> VST (Algorithm 1 lines 12-16) ----
    @pl.when(k == nk - 1)
    def _store():
        y = _srs_block(acc_ref[...], shift, out_dtype, rounding)
        if relu:
            y = jnp.maximum(y, jnp.zeros((), dtype=y.dtype))
        o_ref[...] = y


def qmatmul_pallas(
    x: jnp.ndarray,            # (M, K) int8/int16, M % (qm*bm) == 0
    w: jnp.ndarray,            # (K, N) int8/int16
    bias: Optional[jnp.ndarray],  # (N,) int32 or None
    *,
    shift: int,
    relu: bool = False,
    out_dtype: str = "int8",
    rounding: str = "half_up",
    block: tuple = (128, 128, 128),   # (bm, bk, bn)
    acc_blocks: tuple = (2, 2),       # (qm, qn) — the paper's 2x2 scheme
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw blocked kernel; dimensions must already be padded to macro blocks.

    Use :func:`repro.kernels.qmatmul.ops.qlinear` for the padding wrapper.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    bm, bk, bn = block
    qm, qn = acc_blocks
    MB_M, MB_N = qm * bm, qn * bn
    if M % MB_M or N % MB_N or K % bk:
        raise ValueError(
            f"shape ({M},{K},{N}) not padded to macro blocks "
            f"({MB_M},{bk},{MB_N})"
        )
    nk = K // bk
    grid = (M // MB_M, N // MB_N, nk)

    use_bias = bias is not None
    if not use_bias:
        bias = jnp.zeros((N,), jnp.int32)
    bias2d = bias.reshape(1, N)

    kernel = functools.partial(
        _qmatmul_kernel,
        nk=nk, qm=qm, qn=qn, bm=bm, bn=bn,
        shift=shift, relu=relu, use_bias=use_bias,
        out_dtype=out_dtype, rounding=rounding,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((MB_M, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, MB_N), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, MB_N), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((MB_M, MB_N), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((MB_M, MB_N), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, bias2d)
