"""Distributed execution: logical-axis sharding over TPU meshes.

``repro.dist.sharding`` is the single place where logical axis names used
throughout the layers/models ("batch", "act_heads", "fsdp", "cascade_in",
...) are resolved to physical mesh axes ("pod", "data", "model"). See
docs/sharding.md for the full API reference.
"""

from repro.dist.sharding import (
    ParamSpec,
    ShardingRules,
    abstract_params,
    current_ctx,
    fit_pspec,
    init_params,
    logical_to_pspec,
    rules_for_mode,
    shard_act,
    sharding_ctx,
    specs_to_shardings,
)

__all__ = [
    "ParamSpec",
    "ShardingRules",
    "abstract_params",
    "current_ctx",
    "fit_pspec",
    "init_params",
    "logical_to_pspec",
    "rules_for_mode",
    "shard_act",
    "sharding_ctx",
    "specs_to_shardings",
]
