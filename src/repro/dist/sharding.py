"""Logical-axis sharding: the TPU-mesh retargeting of the paper's 2D fabric.

The paper parallelizes each layer over a physical AIE array two ways at
once: *cascade rows* stream partial sums west->east (the contraction dim is
spatial), and *column replicas* split the output features. On a TPU mesh
the same decomposition becomes a choice of PartitionSpec per tensor dim.
This module keeps that choice out of the layers: layers annotate tensors
with LOGICAL axis names ("batch", "act_heads", "cascade_in", ...) and a
per-mode rule table resolves those names to physical mesh axes
("pod", "data", "model") at trace time.

Three rule tables ship (``rules_for_mode``):

* ``cascade``   — paper-faithful: every weight's contraction dim maps to
                  the model axis (the west->east cascade reduction becomes
                  one psum per linear); the non-contracted dim carries FSDP
                  over (pod, data).
* ``megatron``  — tensor parallelism: "col" weights split their output dim
                  on model, "row" weights their input dim; one psum per
                  col+row pair. FSDP over (pod, data) on the other dim.
* ``megatron_sp`` — megatron + sequence parallelism: activations are
                  additionally split along "seq" on the model axis between
                  TP regions (a seq-sharded KV cache takes precedence over
                  head sharding; ``fit_pspec`` drops the duplicate axis).

Resolution is two-stage and total (it never fails): ``logical_to_pspec``
maps names -> mesh axes through the rule table, dropping axes the mesh
doesn't have (the "pod" axis on a single-pod mesh); ``fit_pspec`` then
drops or trims any axis whose size doesn't divide the tensor dim, and
de-duplicates mesh axes used by more than one dim (first dim wins). A
tensor that can't be sharded is simply replicated — the rule tables are
hints to GSPMD, never correctness requirements.

``sharding_ctx`` installs (mesh, rules) in a thread-local; ``shard_act``
is an activation constraint (``jax.lax.with_sharding_constraint``) under
an active context and a no-op otherwise, so every layer runs unchanged on
a single device.
"""

from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A rule-table entry: replicate (None), one mesh axis ("model"), or a
# composite of mesh axes (("pod", "data")) applied to a single tensor dim.
MeshAxes = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# ParamSpec: shape + logical axes + init recipe for one parameter
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative spec for one parameter (or state) tensor.

    ``logical`` names each dim with a logical axis (or None = replicated);
    ``init`` picks the initializer ("normal" | "zeros" | "ones" | "embed" |
    "small"); ``scale`` overrides the initializer's stddev. ParamSpec trees
    are pytree LEAVES (deliberately unregistered) so ``jax.tree.map(...,
    is_leaf=lambda x: isinstance(x, ParamSpec))`` sees whole specs.
    """

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"
    scale: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(self.shape))
        object.__setattr__(self, "logical", tuple(self.logical))
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"ParamSpec rank mismatch: shape {self.shape} vs "
                f"logical axes {self.logical}"
            )


_IS_SPEC = lambda x: isinstance(x, ParamSpec)  # noqa: E731


# ---------------------------------------------------------------------------
# Rule tables: logical axis name -> mesh axes, per sharding mode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Immutable logical-axis -> mesh-axes table for one sharding mode."""

    mode: str
    table: Tuple[Tuple[str, MeshAxes], ...]

    def __post_init__(self):
        # lookup cache: get() runs once per tensor dim at trace time
        object.__setattr__(self, "_map", dict(self.table))

    def get(self, name: str, default: MeshAxes = None) -> MeshAxes:
        return self._map.get(name, default)

    def __getitem__(self, name: str) -> MeshAxes:
        return self._map[name]

    def __contains__(self, name: str) -> bool:
        return name in self._map

    def items(self):
        return self.table

    def replace(self, **updates: MeshAxes) -> "ShardingRules":
        merged = dict(self.table)
        merged.update(updates)
        return ShardingRules(self.mode, tuple(merged.items()))


# Axes shared by every mode. "batch"/"fsdp" use the composite
# ("pod", "data") so the same table serves the 16x16 single-pod and the
# 2x16x16 multi-pod mesh — logical_to_pspec drops "pod" when absent.
_COMMON: Mapping[str, MeshAxes] = {
    # data-parallel / FSDP dims
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    # scan-over-layers dim: never sharded
    "layers": None,
    "seq": None,
    # embedding / LM head
    "vocab": "model",
    "embed": None,
    # tensor-parallel weight dims (megatron roles)
    "col_out": "model",
    "row_in": "model",
    # MoE: experts on model (EP), capacity slots on data
    "experts": "model",
    "expert_cap": "data",
    # SSM / RWKV inner dims
    "mlp": "model",
    "q_heads": "model",
    "conv_k": None,
    # KV-cache dims
    "cache_heads": "model",
    "cache_hd": None,
    # activation dims
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
}

_MODE_OVERRIDES: Mapping[str, Mapping[str, MeshAxes]] = {
    # Paper-faithful: contraction dim on model (the cascade psum), output
    # dim FSDP over (pod, data). Activations keep their feature dim on
    # model so the next linear contracts locally before its psum.
    "cascade": {
        "cascade_in": "model",
        "cascade_out": ("pod", "data"),
        "act_embed": "model",
    },
    # Megatron TP: roles already in _COMMON; activations replicated on
    # model between the col->row psum pairs.
    "megatron": {},
    # Megatron + sequence parallelism: activations shard "seq" on model
    # between TP regions. Where both "seq" and "act_heads" resolve to
    # model, fit_pspec keeps the first (seq) and drops the duplicate.
    "megatron_sp": {"seq": "model"},
}

MODES = tuple(_MODE_OVERRIDES)


def rules_for_mode(mode: str) -> ShardingRules:
    """The logical->mesh rule table for "cascade" | "megatron" | "megatron_sp"."""
    if mode not in _MODE_OVERRIDES:
        raise ValueError(f"unknown sharding mode {mode!r}; expected {MODES}")
    table = dict(_COMMON)
    table.update(_MODE_OVERRIDES[mode])
    return ShardingRules(mode, tuple(table.items()))


# ---------------------------------------------------------------------------
# Resolution: logical names -> PartitionSpec -> mesh-fitted PartitionSpec
# ---------------------------------------------------------------------------


def _mesh_axis_sizes(mesh) -> Mapping[str, int]:
    # via devices.shape (not mesh.shape) so duck-typed meshes work in tests
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_pspec(
    axes: Sequence[Optional[str]], mesh, rules: ShardingRules
) -> P:
    """Map logical axis names to a PartitionSpec of mesh axes.

    Names missing from the rule table resolve to None (replicated), and
    mesh axes the mesh doesn't have (e.g. "pod" on a 2D mesh) are dropped.
    The result may still name an axis more than once or not divide the
    tensor — ``fit_pspec`` repairs both.
    """
    present = set(mesh.axis_names)
    out = []
    for name in axes:
        entry = rules.get(name) if name is not None else None
        if entry is None:
            out.append(None)
        elif isinstance(entry, str):
            out.append(entry if entry in present else None)
        else:
            kept = tuple(ax for ax in entry if ax in present)
            out.append(kept if kept else None)
    return P(*out)


def fit_pspec(shape: Sequence[int], pspec: P, mesh) -> P:
    """Repair ``pspec`` so it is legal for ``shape`` on ``mesh``.

    Per dim: an axis whose size doesn't divide the dim is dropped; a
    composite entry keeps its longest divisible prefix; a mesh axis already
    consumed by an earlier dim is dropped (first dim wins). The result
    always partitions validly — worst case fully replicated.
    """
    sizes = _mesh_axis_sizes(mesh)
    entries = tuple(pspec)
    used = set()
    out = []
    for i, dim in enumerate(shape):
        entry = entries[i] if i < len(entries) else None
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept = []
        prod = 1
        for ax in axes:
            if ax in used or ax not in sizes or dim % (prod * sizes[ax]):
                break
            prod *= sizes[ax]
            kept.append(ax)
        if not kept:
            out.append(None)
        else:
            out.append(kept[0] if isinstance(entry, str) else tuple(kept))
            used.update(kept)
    return P(*out)


def spec_to_pspec(spec: ParamSpec, mesh, rules: ShardingRules) -> P:
    """Fully resolved PartitionSpec for one ParamSpec."""
    return fit_pspec(spec.shape, logical_to_pspec(spec.logical, mesh, rules),
                     mesh)


def specs_to_shardings(specs, mesh: Mesh, rules: ShardingRules):
    """ParamSpec pytree -> NamedSharding pytree (device_put / jit shardings)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)),
        specs, is_leaf=_IS_SPEC,
    )


# ---------------------------------------------------------------------------
# Context: install (mesh, rules) for activation constraints
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextmanager
def sharding_ctx(mesh: Mesh, rules: ShardingRules):
    """Install (mesh, rules) so ``shard_act`` emits sharding constraints.

    Tracing (jit / lower) must happen inside this context for activation
    constraints to resolve; outside it every ``shard_act`` is the identity.
    Re-entrant and thread-local.
    """
    prev = getattr(_CTX, "active", None)
    _CTX.active = (mesh, rules)
    try:
        yield (mesh, rules)
    finally:
        _CTX.active = prev


def current_ctx() -> Optional[Tuple[Mesh, ShardingRules]]:
    """The innermost active (mesh, rules), or None."""
    return getattr(_CTX, "active", None)


def shard_act(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    """Constrain an activation's sharding by logical axis names.

    Under an active ``sharding_ctx`` this resolves the names through the
    rule table and applies ``jax.lax.with_sharding_constraint``; with no
    context (single-device tests, eager debugging) it returns ``x``
    unchanged. Trailing unnamed dims replicate.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    pspec = fit_pspec(x.shape, logical_to_pspec(logical_axes, mesh, rules),
                      mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


# ---------------------------------------------------------------------------
# Initialization / abstract values
# ---------------------------------------------------------------------------


def _fan_in(shape: Tuple[int, ...]) -> int:
    # weights are (..., d_in, d_out); the stacked layer dim sits in front
    return shape[-2] if len(shape) >= 2 else max(shape[-1], 1)


def _init_one(key, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        std = spec.scale if spec.scale is not None else _fan_in(spec.shape) ** -0.5
    elif spec.init == "embed":
        # lookup table: variance set by the embedding dim, not the vocab
        std = spec.scale if spec.scale is not None else spec.shape[-1] ** -0.5
    elif spec.init == "small":
        # token-shift mixing coefficients and per-head bonuses start near 0
        std = spec.scale if spec.scale is not None else 0.02
    else:
        raise ValueError(f"unknown init {spec.init!r} for shape {spec.shape}")
    x = jax.random.normal(key, spec.shape, jnp.float32) * std
    return x.astype(spec.dtype)


def init_params(key, specs):
    """Materialize a ParamSpec pytree: one fresh RNG split per leaf.

    Deterministic in (key, tree structure): the key is split once into
    len(leaves) subkeys in flattening order, so the same spec tree under
    the same key always produces identical parameters.
    """
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_IS_SPEC)
    if not leaves:
        return specs
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten(
        [_init_one(k, s) for k, s in zip(keys, leaves)])


def abstract_params(specs):
    """ParamSpec pytree -> ShapeDtypeStruct pytree (AOT lowering inputs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=_IS_SPEC,
    )
