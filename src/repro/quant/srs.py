"""Shift-Round-Saturate (SRS) primitives.

On AIE-ML, quantization is fused into the vector store: ``VST.SRS`` applies a
right shift (power-of-two rescale), rounding, and saturation in a single
instruction. We reproduce those integer semantics exactly so that the Pallas
kernel ("AIE sim" analogue) and the pure-jnp oracle ("x86 sim" analogue) are
bit-identical.

All arithmetic is performed in the accumulator dtype (int32 by default) with
two's-complement wraparound semantics — the same on XLA:CPU, XLA:TPU and the
Pallas interpreter — so bit-exactness is a property of the math, not the
backend.
"""

from __future__ import annotations

import jax.numpy as jnp

# (min, max) representable values per integer dtype.
INT_RANGE = {
    "int8": (-128, 127),
    "int16": (-32768, 32767),
    "int32": (-(2**31), 2**31 - 1),
}

VALID_ROUNDING = ("floor", "half_up", "half_even")


def saturate(x: jnp.ndarray, out_dtype: str) -> jnp.ndarray:
    """Clamp ``x`` to the representable range of ``out_dtype`` and cast."""
    lo, hi = INT_RANGE[out_dtype]
    return jnp.clip(x, lo, hi).astype(out_dtype)


def _round_shift(acc: jnp.ndarray, shift: int, rounding: str) -> jnp.ndarray:
    """Arithmetic right shift by ``shift`` with the requested rounding mode.

    ``shift`` is a static Python int >= 0. Overflow of the rounding addend
    wraps in-accumulator-dtype, matching hardware behaviour.
    """
    if shift == 0:
        return acc
    if rounding == "floor":
        return acc >> shift
    half = jnp.asarray(1 << (shift - 1), dtype=acc.dtype)
    if rounding == "half_up":
        # Round half towards +inf: floor((acc + half) >> shift).
        return (acc + half) >> shift
    if rounding == "half_even":
        floor = acc >> shift
        rem = acc & jnp.asarray((1 << shift) - 1, dtype=acc.dtype)
        bump = (rem > half) | ((rem == half) & ((floor & 1) == 1))
        return floor + bump.astype(acc.dtype)
    raise ValueError(f"unknown rounding mode {rounding!r}")


def srs(
    acc: jnp.ndarray,
    shift: int,
    out_dtype: str = "int8",
    rounding: str = "half_up",
) -> jnp.ndarray:
    """Shift-round-saturate: the AIE ``VST.SRS`` store path.

    Args:
      acc: integer accumulator values (int32/int64).
      shift: static right-shift amount (power-of-two rescale), >= 0.
      out_dtype: output integer dtype name ("int8"/"int16"/"int32").
      rounding: "half_up" (AIE default we adopt), "half_even", or "floor".

    Returns:
      Requantized values in ``out_dtype``.
    """
    if shift < 0:
        raise ValueError("SRS shift must be non-negative")
    if rounding not in VALID_ROUNDING:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    return saturate(_round_shift(acc, shift, rounding), out_dtype)


def requant_shift(in_shift: int, w_shift: int, out_shift: int) -> int:
    """SRS shift for y = x @ w: accumulator lives at scale 2^-(sx+sw); to emit
    outputs at scale 2^-sy we shift right by (sx + sw - sy)."""
    s = in_shift + w_shift - out_shift
    if s < 0:
        raise ValueError(
            f"requantization would need a LEFT shift ({s}); "
            "choose a smaller output shift"
        )
    return s
