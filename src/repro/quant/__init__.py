"""Integer quantization substrate: SRS (shift-round-saturate) semantics and
quantized tensor containers, matching the AIE-ML VST.SRS behaviour that
AIE4ML fuses into the kernel store."""

from repro.quant.srs import (
    INT_RANGE,
    srs,
    saturate,
    requant_shift,
)
from repro.quant.qtensor import QTensor, quantize, dequantize, choose_shift

__all__ = [
    "INT_RANGE",
    "srs",
    "saturate",
    "requant_shift",
    "QTensor",
    "quantize",
    "dequantize",
    "choose_shift",
]
