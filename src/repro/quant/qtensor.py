"""Quantized tensor container with power-of-two scales.

AIE4ML inherits hls4ml's fixed-point world: a quantized tensor is an integer
array ``data`` plus a binary-point position ``shift`` such that
``real = data * 2**-shift``. Power-of-two scales are what make SRS a pure
shift (no integer multiplier needed), which is the paper's requantization
model.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.quant.srs import INT_RANGE, saturate


@dataclasses.dataclass
class QTensor:
    """Integer data + binary-point shift: real value = data * 2**-shift."""

    data: jnp.ndarray
    shift: int

    @property
    def dtype(self) -> str:
        return str(self.data.dtype)

    @property
    def shape(self):
        return self.data.shape

    def dequantize(self) -> jnp.ndarray:
        return self.data.astype(jnp.float32) * (2.0 ** (-self.shift))


MAX_SHIFT = 46  # beyond this the scale exceeds fp32 dynamic range usefully


def choose_shift(x: np.ndarray, dtype: str = "int8", margin_bits: int = 0) -> int:
    """Largest shift s such that max|x| * 2**s still fits in ``dtype``.

    margin_bits reserves headroom (e.g. for bias tensors that will be added to
    accumulators). Capped at MAX_SHIFT so near-zero tensors can't explode
    the scale.
    """
    lo, hi = INT_RANGE[dtype]
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    if amax == 0.0:
        return 0
    # hi * 2**-s >= amax  =>  s <= log2(hi / amax)
    s = int(math.floor(math.log2(hi / amax)))
    return min(MAX_SHIFT, max(0, s - margin_bits))


def quantize(
    x,
    dtype: str = "int8",
    shift: Optional[int] = None,
    rounding: str = "half_up",
) -> QTensor:
    """Quantize a float array to ``QTensor`` with a power-of-two scale."""
    x = np.asarray(x, dtype=np.float64)
    if shift is None:
        shift = choose_shift(x, dtype)
    scaled = x * (2.0**shift)
    if rounding == "half_up":
        q = np.floor(scaled + 0.5)
    elif rounding == "half_even":
        q = np.rint(scaled)
    elif rounding == "floor":
        q = np.floor(scaled)
    else:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    lo, hi = INT_RANGE[dtype]
    q = np.clip(q, lo, hi)
    return QTensor(data=jnp.asarray(q.astype(np.int64)).astype(dtype), shift=shift)


def dequantize(q: QTensor) -> jnp.ndarray:
    return q.dequantize()


def requantize(q: QTensor, new_shift: int, out_dtype: str = "int8") -> QTensor:
    """Change the binary point of an existing QTensor (shift right only)."""
    delta = q.shift - new_shift
    if delta < 0:
        raise ValueError("requantize only supports reducing precision")
    data = q.data.astype(jnp.int32)
    if delta > 0:
        half = jnp.asarray(1 << (delta - 1), dtype=jnp.int32)
        data = (data + half) >> delta
    return QTensor(data=saturate(data, out_dtype), shift=new_shift)
