"""Device models.

Two targets coexist in this framework:

* ``AIEMLDevice`` — an analytical model of the AMD Versal AIE-ML array
  (VEK280: 304 compute tiles on a 38x8 grid plus a row of memory tiles).
  This reproduces the paper's Table I single-tile ceilings and drives the
  cycle model used by the Table II / Fig. 4 benchmarks. It is also the
  geometry the branch-and-bound placer works on when reproducing Fig. 3.

* ``TPUv5eTarget`` — the roofline constants of the hardware this framework
  actually compiles for (TPU v5e pods). The dry-run roofline analysis in
  ``launch/roofline.py`` converts compiled-HLO statistics into seconds using
  these numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


# --------------------------------------------------------------------------
# AIE-ML analytical model (paper Table I geometry and ceilings)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MmulTiling:
    """A native aie::mmul <M,K,N> tiling for a given precision pair."""

    M: int
    K: int
    N: int
    dt_a: str
    dt_b: str
    macs_per_cycle: int
    native: bool = True

    @property
    def macs_per_tile(self) -> int:
        return self.M * self.K * self.N

    @property
    def cycles_per_mmul(self) -> float:
        """Cycles for one tile-level multiply at the VMAC issue rate."""
        return self.macs_per_tile / self.macs_per_cycle


# The representative native tilings from paper Table I.
NATIVE_TILINGS: Dict[Tuple[str, str], MmulTiling] = {
    ("int8", "int8"): MmulTiling(4, 8, 8, "int8", "int8", 256),
    ("int16", "int8"): MmulTiling(4, 4, 8, "int16", "int8", 128),
    ("int16", "int16"): MmulTiling(4, 4, 4, "int16", "int16", 64),
}


@dataclasses.dataclass(frozen=True)
class AIEMLDevice:
    """AMD Versal AIE-ML array (VEK280-class) analytical model."""

    n_cols: int = 38
    n_rows: int = 8
    clock_hz: float = 1.25e9
    local_mem_bytes: int = 64 * 1024     # per compute tile
    memtile_bytes: int = 512 * 1024      # per memory tile (row of 38)
    n_memtiles: int = 38
    load_ports: int = 2                  # 256-bit loads per cycle
    load_bits: int = 256
    store_bits: int = 256
    cascade_bits: int = 512              # west->east partial-sum port
    # Calibrated per-macro-step overheads of the 2x2 blocked kernel schedule
    # (fit to paper Table II; see benchmarks/table2_single_kernel.py):
    overhead_base_cycles: float = 3.0        # loop/SRS/store epilogue per macro step
    overhead_bias_relu_cycles: float = 15.0  # + bias prologue + ReLU epilogue
    startup_cycles: float = 120.0            # kernel prologue (first loads, acc init)

    @property
    def n_tiles(self) -> int:
        return self.n_cols * self.n_rows

    # -- Table I -----------------------------------------------------------

    def peak_macs_per_s(self, dt_a: str, dt_b: str) -> float:
        return NATIVE_TILINGS[(dt_a, dt_b)].macs_per_cycle * self.clock_hz

    def peak_gops(self, dt_a: str, dt_b: str) -> float:
        """GOP/s counting one MAC as 2 ops (paper Table I convention)."""
        return 2.0 * self.peak_macs_per_s(dt_a, dt_b) / 1e9

    def memory_bound_macs_per_cycle(self, bytes_per_element: int) -> float:
        """MAC/cycle ceiling with zero reuse: limited by the two load ports."""
        bytes_per_cycle = self.load_ports * self.load_bits // 8
        return bytes_per_cycle / (2.0 * bytes_per_element)

    # -- cycle model for the 2x2 blocked kernel (paper Sec. III-A) ----------

    def kernel_cycles(
        self,
        batch: int,
        f_in: int,
        f_out: int,
        dt_a: str = "int8",
        dt_b: str = "int8",
        use_bias: bool = False,
        use_relu: bool = False,
    ) -> float:
        """Estimated cycles for C[batch, f_out] = A[batch, f_in] @ W.

        The 2x2 accumulator scheme walks macro steps of (2 M-tiles x 2
        N-tiles); each macro step runs k_tiles iterations issuing 4 VMACs.
        Steady state is VMAC-bound (4 loads fit in 2 cycles on 2 ports while
        4 VMACs take 4 cycles), so cycles ~= total_macs / macs_per_cycle plus
        per-macro-step prologue/epilogue overhead.
        """
        t = NATIVE_TILINGS[(dt_a, dt_b)]
        m_tiles = -(-batch // t.M)
        k_tiles = -(-f_in // t.K)
        n_tiles = -(-f_out // t.N)
        macro_steps = -(-m_tiles // 2) * -(-n_tiles // 2)
        steady = macro_steps * k_tiles * 4 * t.cycles_per_mmul
        overhead = self.overhead_base_cycles
        if use_bias or use_relu:
            overhead += self.overhead_bias_relu_cycles
        return self.startup_cycles + steady + macro_steps * overhead

    def kernel_gops(self, batch, f_in, f_out, dt_a="int8", dt_b="int8",
                    use_bias=False, use_relu=False) -> float:
        cycles = self.kernel_cycles(batch, f_in, f_out, dt_a, dt_b,
                                    use_bias=use_bias, use_relu=use_relu)
        ops = 2.0 * batch * f_in * f_out
        return ops / (cycles / self.clock_hz) / 1e9

    def kernel_latency_s(self, batch, f_in, f_out, dt_a="int8", dt_b="int8",
                         use_bias=False, use_relu=False) -> float:
        cycles = self.kernel_cycles(batch, f_in, f_out, dt_a, dt_b,
                                    use_bias=use_bias, use_relu=use_relu)
        return cycles / self.clock_hz


# --------------------------------------------------------------------------
# TPU v5e roofline target (assignment constants)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUv5eTarget:
    """Roofline constants for one TPU v5e chip (assignment-specified)."""

    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    peak_ops_int8: float = 394e12        # OP/s per chip (2x bf16)
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw_per_link: float = 50e9        # bytes/s per link
    ici_links: int = 4                   # 2D torus: +/-x, +/-y
    hbm_bytes: int = 16 * 2**30          # 16 GiB HBM per chip
    vmem_bytes: int = 128 * 1024 * 1024  # ~128 MiB VMEM

    def compute_time_s(self, flops_per_chip: float, dtype: str = "bf16") -> float:
        peak = self.peak_ops_int8 if dtype == "int8" else self.peak_flops_bf16
        return flops_per_chip / peak

    def memory_time_s(self, bytes_per_chip: float) -> float:
        return bytes_per_chip / self.hbm_bw

    def collective_time_s(self, coll_bytes_per_chip: float) -> float:
        # Conservative single-link model: a chip moves its collective bytes
        # over one ICI link. (Ring collectives use 2 directions; we report
        # the single-link number and note the 2x headroom in EXPERIMENTS.md.)
        return coll_bytes_per_chip / self.ici_bw_per_link


DEFAULT_AIE = AIEMLDevice()
DEFAULT_TPU = TPUv5eTarget()
