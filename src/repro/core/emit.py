"""Project emission: render the resolved IR into executable form.

On AIE hardware this stage instantiates C++ templates into a Vitis project.
On the JAX retarget, "emission" builds the executable graph directly: a
chain of fused quantized linear calls whose two execution modes mirror the
paper's simulation flow —

  * ``mode="x86"``  — pure-jnp oracle per layer (fast functional sim)
  * ``mode="aie"``  — the Pallas kernel per layer (cycle-accurate sim role;
                      interpret-mode on CPU, compiled on TPU)

Both are bit-exact. ``predict()`` accepts float arrays and (optionally)
quantizes inputs / dequantizes outputs, matching the paper's toolflow
(Sec. IV-B).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.ir import Graph, OpKind
from repro.core.passes import CompileConfig, run_passes
from repro.kernels.qmatmul.ops import qlinear
from repro.kernels.qmatmul.ref import qlinear_ref
from repro.quant.srs import INT_RANGE


@dataclasses.dataclass
class LayerExec:
    name: str
    weight: jnp.ndarray        # padded quantized weight (K_pad, N_pad)
    bias: Optional[jnp.ndarray]
    srs_shift: int
    relu: bool
    out_dtype: str
    rounding: str
    f_in: int
    f_out: int


class EmittedModel:
    """The generated 'AIE project': executable, introspectable."""

    def __init__(self, graph: Graph):
        self.graph = graph
        self.layers: List[LayerExec] = []
        for node in graph.compute_nodes():
            q = node.quant
            w_padded = jnp.asarray(node.packed["weight_padded"])
            bias = None
            if node.quant["bias_q"] is not None:
                bias = jnp.asarray(node.packed["bias_padded"]).astype(jnp.int32)
            self.layers.append(
                LayerExec(
                    name=node.name,
                    weight=w_padded,
                    bias=bias,
                    srs_shift=q["srs_shift"],
                    relu=bool(node.params.get("relu", False)),
                    out_dtype=q["a_dtype"],
                    rounding=q["rounding"],
                    f_in=graph.predecessors(node.name)[0].out_spec.features,
                    f_out=node.out_spec.features,
                )
            )
        self.in_shift = graph.inputs()[0].quant["shift"]
        self.in_dtype = graph.inputs()[0].quant["dtype"]
        self.out_shift = graph.outputs()[0].out_spec.shift

    # -- execution ----------------------------------------------------------

    def _run_int(self, x_q: jnp.ndarray, mode: str) -> jnp.ndarray:
        h = x_q
        for layer in self.layers:
            # pad activations into the zero-padded feature space (the
            # memory-tile zero-padding role)
            k_pad = layer.weight.shape[0]
            if h.shape[-1] < k_pad:
                h = jnp.pad(h, ((0, 0), (0, k_pad - h.shape[-1])))
            fn = qlinear if mode == "aie" else qlinear_ref
            h = fn(
                h, layer.weight, layer.bias,
                shift=layer.srs_shift, relu=layer.relu,
                out_dtype=layer.out_dtype, rounding=layer.rounding,
            )
            h = h[:, : layer.weight.shape[1]]
        # strip final padding back to logical features
        return h[:, : self.layers[-1].f_out]

    def predict(
        self,
        x: np.ndarray,
        mode: str = "x86",
        quantize_input: bool = True,
        dequantize_output: bool = True,
    ) -> np.ndarray:
        """hls4ml-style predict() over float (or pre-quantized int) inputs."""
        if mode not in ("x86", "aie"):
            raise ValueError(f"unknown mode {mode!r}")
        if quantize_input:
            lo, hi = INT_RANGE[self.in_dtype]
            xq = jnp.clip(
                jnp.round(jnp.asarray(x, jnp.float32) * (2.0**self.in_shift)),
                lo, hi,
            ).astype(self.in_dtype)
        else:
            xq = jnp.asarray(x)
        y = self._run_int(xq, mode)
        if dequantize_output:
            return np.asarray(y, np.float32) * (2.0 ** (-self.out_shift))
        return np.asarray(y)

    # -- introspection (benchmarks read these) -------------------------------

    @property
    def tiles_used(self) -> int:
        return self.graph.meta["tiles_used"]

    @property
    def memtile_bytes(self) -> int:
        return self.graph.meta.get("memtile_bytes", 0)

    @property
    def placement_cost(self) -> float:
        return self.graph.meta["placement_cost"]

    def placements(self) -> Dict[str, tuple]:
        return {
            n.name: (n.place.col, n.place.row, n.place.width, n.place.height)
            for n in self.graph.compute_nodes()
        }

    def estimated_cycles(self, batch: int) -> float:
        """Analytical cycle estimate for one inference at the given batch,
        assuming perfectly pipelined layers (throughput = slowest layer)."""
        dev = self.graph.meta["device"]
        worst = 0.0
        for node in self.graph.compute_nodes():
            c = node.cascade
            q = node.quant
            pred = self.graph.predecessors(node.name)[0]
            cyc = dev.kernel_cycles(
                batch, c.f_in_slice, c.f_out_slice,
                pred.out_spec.dtype, q["w_dtype"],
                use_bias=q["bias_q"] is not None,
                use_relu=bool(node.params.get("relu", False)),
            )
            worst = max(worst, cyc)
        return worst


def compile_graph(
    graph: Graph, config: Optional[CompileConfig] = None
) -> EmittedModel:
    """The full paper pipeline: passes + emission."""
    run_passes(graph, config)
    return EmittedModel(graph)
