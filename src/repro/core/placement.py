"""Graph placement on the 2D array — the paper's branch-and-bound search.

Each layer graph G_i is a rectangle of ``cas_len`` columns x ``cas_num`` rows.
Given the execution order G_0..G_{n-1}, we choose lower-left corners to
minimize the weighted cost (paper Eq. 2):

    J = sum_i ( |c_out^i - c_in^{i+1}| + lam*|r_out^i - r_in^{i+1}|
                + mu*r_top^i )

Port convention (Sec. III-B/C): inputs are broadcast up the *leftmost* column
of a block from the memory-tile row (c_in = col, r_in = row); the cascade
exits the *rightmost* column (c_out = col + w - 1, r_out = row). r_top biases
the layout toward the lower rows where the memory tiles aggregate.

The solver is an exact branch-and-bound: depth-first over graphs in order,
candidates at each level sorted by (incremental cost + admissible lower
bound), pruning any partial assignment that cannot beat the incumbent. A
candidate ``beam`` cap bounds the per-level branching for very large
instances (None = exact); tests verify exact mode against brute force.

The same engine places this framework's TPU pipeline stages on the device
mesh — the array is then the (data, model) grid and blocks are stage
sub-rectangles. The algorithm is hardware-agnostic; only the geometry and
the interpretation of a "hop" change.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.ir import PlacementSpec


@dataclasses.dataclass(frozen=True)
class Block:
    """A placeable layer graph: width=cas_len, height=cas_num."""

    width: int
    height: int
    name: str = ""


@dataclasses.dataclass
class PlacementResult:
    positions: List[PlacementSpec]
    cost: float
    nodes_expanded: int = 0
    method: str = "bnb"

    def as_tuples(self) -> List[Tuple[int, int]]:
        return [(p.col, p.row) for p in self.positions]


def _overlaps(a: PlacementSpec, b: PlacementSpec) -> bool:
    return not (
        a.col + a.width <= b.col
        or b.col + b.width <= a.col
        or a.row + a.height <= b.row
        or b.row + b.height <= a.row
    )


def _pair_cost(prev: PlacementSpec, nxt: PlacementSpec, lam: float) -> float:
    return abs(prev.c_out - nxt.c_in) + lam * abs(prev.r_out - nxt.r_in)


def placement_cost(
    positions: Sequence[PlacementSpec], lam: float = 1.0, mu: float = 0.05
) -> float:
    """Evaluate Eq. 2 for a full placement."""
    j = 0.0
    for i, p in enumerate(positions):
        j += mu * p.r_top
        if i + 1 < len(positions):
            j += _pair_cost(p, positions[i + 1], lam)
    return j


class Placer:
    def __init__(
        self,
        n_cols: int,
        n_rows: int,
        lam: float = 1.0,
        mu: float = 0.05,
        beam: Optional[int] = 64,
        max_expansions: Optional[int] = 500_000,
    ):
        self.n_cols = n_cols
        self.n_rows = n_rows
        self.lam = lam
        self.mu = mu
        self.beam = beam
        # anytime budget: when exceeded, return the best incumbent so far
        # (candidate ordering means the first descent is already greedy-good)
        self.max_expansions = max_expansions

    # -- candidate generation ------------------------------------------------

    def _feasible_positions(
        self, block: Block, placed: List[PlacementSpec]
    ) -> List[PlacementSpec]:
        out = []
        for c in range(self.n_cols - block.width + 1):
            for r in range(self.n_rows - block.height + 1):
                cand = PlacementSpec(c, r, block.width, block.height)
                if all(not _overlaps(cand, p) for p in placed):
                    out.append(cand)
        return out

    # -- exact / beam branch-and-bound ----------------------------------------

    def branch_and_bound(
        self,
        blocks: Sequence[Block],
        start: Optional[Tuple[int, int]] = None,
        fixed: Optional[Dict[int, Tuple[int, int]]] = None,
    ) -> PlacementResult:
        """Minimize Eq. 2. ``fixed`` pins block i at (col, row) as a hard
        constraint (user overrides); ``start`` pins block 0."""
        blocks = list(blocks)
        fixed = dict(fixed or {})
        if start is not None:
            fixed[0] = start
        for i, b in enumerate(blocks):
            if b.width > self.n_cols or b.height > self.n_rows:
                raise ValueError(f"block {i} ({b.width}x{b.height}) exceeds array")

        # Admissible lower bound for the unplaced suffix: each remaining
        # block contributes at least mu*(h-1) (best case: row 0), pairwise
        # terms are >= 0.
        suffix_lb = [0.0] * (len(blocks) + 1)
        for i in range(len(blocks) - 1, -1, -1):
            suffix_lb[i] = suffix_lb[i + 1] + self.mu * (blocks[i].height - 1)

        best_cost = float("inf")
        best: Optional[List[PlacementSpec]] = None
        stats = {"expanded": 0}

        class _Budget(Exception):
            pass

        def dfs(i: int, placed: List[PlacementSpec], cost: float):
            nonlocal best_cost, best
            if (self.max_expansions is not None
                    and stats["expanded"] > self.max_expansions
                    and best is not None):
                raise _Budget
            if cost + suffix_lb[i] >= best_cost:
                return
            if i == len(blocks):
                best_cost, best = cost, list(placed)
                return
            if i in fixed:
                c, r = fixed[i]
                if (c + blocks[i].width > self.n_cols
                        or r + blocks[i].height > self.n_rows
                        or c < 0 or r < 0):
                    raise ValueError(
                        f"fixed placement for block {i} is out of bounds")
                cands = [PlacementSpec(c, r, blocks[i].width,
                                       blocks[i].height)]
                if any(_overlaps(cands[0], p) for p in placed):
                    return  # conflicts with this partial assignment: backtrack
            else:
                cands = self._feasible_positions(blocks[i], placed)

            def inc(cand: PlacementSpec) -> float:
                d = self.mu * cand.r_top
                if placed:
                    d += _pair_cost(placed[-1], cand, self.lam)
                return d

            cands.sort(key=inc)
            if self.beam is not None and i not in fixed:
                cands = cands[: self.beam]
            for cand in cands:
                stats["expanded"] += 1
                d = inc(cand)
                if cost + d + suffix_lb[i + 1] >= best_cost:
                    # candidates are sorted by incremental cost, but the
                    # suffix bound is constant here, so all later cands
                    # prune too.
                    break
                placed.append(cand)
                dfs(i + 1, placed, cost + d)
                placed.pop()

        try:
            dfs(0, [], 0.0)
        except _Budget:
            pass  # anytime: fall through with the incumbent
        if best is None:
            raise ValueError("no feasible placement found")
        return PlacementResult(best, best_cost, stats["expanded"], "bnb")

    # -- greedy baselines (paper Fig. 3 b, c) ---------------------------------

    def _greedy(self, blocks: Sequence[Block], primary: str,
                start: Tuple[int, int] = (0, 0)) -> PlacementResult:
        placed: List[PlacementSpec] = []
        cur = start
        for i, b in enumerate(blocks):
            cand = None
            if i == 0:
                cand = PlacementSpec(start[0], start[1], b.width, b.height)
                if any(_overlaps(cand, p) for p in placed):
                    cand = None
            else:
                prev = placed[-1]
                if primary == "right":
                    order = [
                        (prev.col + prev.width, prev.row),
                        (prev.col, prev.row + prev.height),
                    ]
                else:  # "up"
                    order = [
                        (prev.col, prev.row + prev.height),
                        (prev.col + prev.width, prev.row),
                    ]
                for c, r in order:
                    t = PlacementSpec(c, r, b.width, b.height)
                    if (
                        c + b.width <= self.n_cols
                        and r + b.height <= self.n_rows
                        and all(not _overlaps(t, p) for p in placed)
                    ):
                        cand = t
                        break
            if cand is None:
                # fall back: first feasible position (row-major scan)
                feas = self._feasible_positions(b, placed)
                if not feas:
                    raise ValueError(f"greedy-{primary}: no feasible slot for {i}")
                cand = feas[0]
            placed.append(cand)
            cur = (cand.col, cand.row)
        return PlacementResult(
            placed, placement_cost(placed, self.lam, self.mu), 0, f"greedy_{primary}"
        )

    def greedy_right(self, blocks, start=(0, 0)) -> PlacementResult:
        return self._greedy(blocks, "right", start)

    def greedy_up(self, blocks, start=(0, 0)) -> PlacementResult:
        return self._greedy(blocks, "up", start)

    # -- exhaustive reference (tests only) ------------------------------------

    def brute_force(
        self, blocks: Sequence[Block], start: Optional[Tuple[int, int]] = None
    ) -> PlacementResult:
        blocks = list(blocks)
        best_cost, best = float("inf"), None
        all_pos = [
            self._feasible_positions(b, []) for b in blocks
        ]
        if start is not None:
            all_pos[0] = [
                p for p in all_pos[0] if (p.col, p.row) == start
            ]
        for combo in itertools.product(*all_pos):
            ok = True
            for a, b in itertools.combinations(combo, 2):
                if _overlaps(a, b):
                    ok = False
                    break
            if not ok:
                continue
            c = placement_cost(combo, self.lam, self.mu)
            if c < best_cost:
                best_cost, best = c, list(combo)
        if best is None:
            raise ValueError("no feasible placement")
        return PlacementResult(best, best_cost, 0, "brute")
