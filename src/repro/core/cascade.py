"""Cascade parallelism resolution (paper Sec. III-B).

A layer with (f_in, f_out) features is spread over a CAS_LEN x CAS_NUM
rectangle of tiles:

    f_in  = CAS_LEN * f_in_slice     (contraction split; partial sums flow
                                      west->east over the cascade ports)
    f_out = CAS_NUM * f_out_slice    (output-feature split; rows replicate
                                      north-south)

On the TPU retarget the same decomposition becomes mesh sharding: the
contraction split is K-sharding + psum along the model axis; the row split is
N-sharding. ``cascade_axes`` computes a (cas_len, cas_num) factorization of a
mesh axis so the layer-level math is identical on both targets.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.device import AIEMLDevice, MmulTiling
from repro.core.ir import CascadeSpec
from repro.core.packing import ceil_to


def resolve_cascade(
    f_in: int,
    f_out: int,
    tiling: MmulTiling,
    device: AIEMLDevice,
    batch: int,
    a_bytes: int,
    w_bytes: int,
    overrides: Optional[Dict] = None,
    weight_budget_bytes: Optional[int] = None,
) -> CascadeSpec:
    """Choose CAS_LEN/CAS_NUM and per-tile slices for one dense layer.

    Constraints honored:
      * slices are multiples of the mmul tile dims (K, N);
      * the per-tile weight slice (resident, RTP-loaded) plus double-buffered
        I/O slices fit in local memory;
      * user overrides (cas_len / cas_num / f_in_slice / f_out_slice) are
        hard constraints.
    """
    overrides = overrides or {}
    budget = weight_budget_bytes or (device.local_mem_bytes // 2)

    # default slice caps: keep the contraction slice near 128 features (a
    # sweet spot for K-tile streaming), then size the output slice so the
    # weight slice fits the budget.
    f_in_slice = overrides.get("f_in_slice")
    cas_len = overrides.get("cas_len")
    if cas_len is not None and f_in_slice is None:
        f_in_slice = ceil_to(-(-f_in // cas_len), tiling.K)
    if f_in_slice is None:
        f_in_slice = min(ceil_to(f_in, tiling.K), 128)
    f_in_slice = ceil_to(f_in_slice, tiling.K)
    if cas_len is None:
        cas_len = -(-f_in // f_in_slice)

    f_out_slice = overrides.get("f_out_slice")
    cas_num = overrides.get("cas_num")
    if cas_num is not None and f_out_slice is None:
        f_out_slice = ceil_to(-(-f_out // cas_num), tiling.N)
    if f_out_slice is None:
        cap = max(tiling.N, budget // max(1, f_in_slice * w_bytes))
        # round the cap DOWN to a tile multiple (never below one tile), and
        # never exceed the padded layer width.
        f_out_slice = max(tiling.N, (cap // tiling.N) * tiling.N)
        f_out_slice = min(f_out_slice, ceil_to(f_out, tiling.N))
    f_out_slice = ceil_to(f_out_slice, tiling.N)
    if cas_num is None:
        cas_num = -(-f_out // f_out_slice)

    spec = CascadeSpec(
        cas_len=cas_len, cas_num=cas_num,
        f_in_slice=f_in_slice, f_out_slice=f_out_slice,
    )

    # local-memory feasibility: resident weights + double-buffered io slices
    w_slice = f_in_slice * f_out_slice * w_bytes
    io_slice = 2 * batch * (f_in_slice * a_bytes + f_out_slice * a_bytes)
    if w_slice > device.local_mem_bytes:
        raise ValueError(
            f"weight slice {w_slice}B exceeds tile local memory "
            f"({device.local_mem_bytes}B); increase cas_len/cas_num"
        )
    if w_slice + io_slice > 4 * device.local_mem_bytes:
        # io buffers can spill into neighbor tiles' banks (AIE shares memory
        # with 3 neighbors); beyond 4 banks it cannot work.
        raise ValueError("layer slice working set cannot fit tile memory")
    return spec


def cascade_grid_factor(tp: int, prefer_len: int) -> tuple:
    """Factor a TP degree into (cas_len, cas_num) with cas_len as close to
    ``prefer_len`` as possible. Used by the TPU linear layer to map the
    cascade rectangle onto a 1D model axis."""
    best = (1, tp)
    for cl in range(1, tp + 1):
        if tp % cl == 0 and abs(cl - prefer_len) < abs(best[0] - prefer_len):
            best = (cl, tp // cl)
    return best
