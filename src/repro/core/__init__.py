"""AIE4ML core: the paper's compiler (IR, passes, placement, emission)."""

from repro.core.device import AIEMLDevice, TPUv5eTarget, NATIVE_TILINGS
from repro.core.ir import (
    Graph,
    Node,
    OpKind,
    TensorSpec,
    CascadeSpec,
    PlacementSpec,
    MemTileEdge,
    DenseSpec,
    build_mlp_graph,
)
from repro.core.passes import CompileConfig, run_passes
from repro.core.placement import Block, Placer, placement_cost
from repro.core.emit import EmittedModel, compile_graph

__all__ = [
    "AIEMLDevice",
    "TPUv5eTarget",
    "NATIVE_TILINGS",
    "Graph",
    "Node",
    "OpKind",
    "TensorSpec",
    "CascadeSpec",
    "PlacementSpec",
    "MemTileEdge",
    "DenseSpec",
    "build_mlp_graph",
    "CompileConfig",
    "run_passes",
    "Block",
    "Placer",
    "placement_cost",
    "EmittedModel",
    "compile_graph",
]
