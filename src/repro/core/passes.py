"""The AIE4ML pass pipeline (paper Fig. 2).

    Lower -> Quantize -> Resolve -> Pack -> GraphPlan -> Place -> Emit

Each pass consumes and enriches the IR. Inferred attributes are overridable
via ``node.overrides`` (user configuration directives) and are honored as
hard constraints, exactly as in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.device import AIEMLDevice, NATIVE_TILINGS, MmulTiling
from repro.core.ir import Graph, MemTileEdge, Node, OpKind
from repro.core.cascade import resolve_cascade
from repro.core.packing import ceil_to, pack_bias, pack_dense_weight
from repro.core.placement import Block, Placer
from repro.quant.qtensor import choose_shift, quantize
from repro.quant.srs import requant_shift

_DTYPE_BYTES = {"int8": 1, "int16": 2, "int32": 4}


@dataclasses.dataclass
class CompileConfig:
    """Framework-level configuration (the hls4ml config-dict role)."""

    a_dtype: str = "int8"          # activation dtype between layers
    w_dtype: str = "int8"          # weight dtype
    acc_dtype: str = "int32"
    in_shift: Optional[int] = None  # binary point of the quantized input
    rounding: str = "half_up"
    # placement heuristics (paper Fig. 3 defaults)
    lam: float = 1.0
    mu: float = 0.05
    beam: Optional[int] = 64
    start: Optional[Tuple[int, int]] = (0, 0)
    device: AIEMLDevice = dataclasses.field(default_factory=AIEMLDevice)
    # optional calibration batch (float) for activation ranges; None = use
    # conservative analytic worst-case bounds (never saturates)
    calib: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# 1. Lower: fuse Dense+ReLU, initialize device context
# ---------------------------------------------------------------------------


def lower_pass(g: Graph, cfg: CompileConfig) -> Graph:
    g.meta["device"] = cfg.device
    fused = []
    for node in list(g):
        if node.op != OpKind.RELU:
            continue
        (prod,) = g.predecessors(node.name)
        if prod.op == OpKind.DENSE and len(g.successors(prod.name)) == 1:
            prod.params["relu"] = True
            g.rewire(node.name, prod.name)
            fused.append(node.name)
    for name in fused:
        g.remove(name)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# 2. Quantize: integer dtypes + binary points, bit-exact chain
# ---------------------------------------------------------------------------


def quantize_pass(g: Graph, cfg: CompileConfig) -> Graph:
    # activation ranges: calibration if provided, else analytic worst case
    ranges: Dict[str, float] = {}
    if cfg.calib is not None:
        acts = {g.inputs()[0].name: np.asarray(cfg.calib, np.float64)}
        for node in g:
            if node.op == OpKind.DENSE:
                x = acts[node.inputs[0]]
                y = x @ node.params["weight"]
                if "bias" in node.params:
                    y = y + node.params["bias"]
                if node.params.get("relu"):
                    y = np.maximum(y, 0.0)
                acts[node.name] = y
        ranges = {k: float(np.max(np.abs(v))) if v.size else 1.0
                  for k, v in acts.items()}

    in_node = g.inputs()[0]
    a_dt = in_node.overrides.get("a_dtype", cfg.a_dtype)
    if cfg.in_shift is not None:
        in_shift = cfg.in_shift
    elif cfg.calib is not None:
        fake = np.asarray([ranges[in_node.name]])
        in_shift = choose_shift(fake, a_dt)
    else:
        in_shift = 7 if a_dt == "int8" else 15  # inputs assumed in [-1, 1)
    in_node.quant = {"dtype": a_dt, "shift": in_shift}
    in_node.out_spec.dtype = a_dt
    in_node.out_spec.shift = in_shift

    cur_shift, cur_amax = in_shift, ranges.get(in_node.name, 1.0)
    for node in g:
        if node.op != OpKind.DENSE:
            if node.op == OpKind.OUTPUT:
                src = g.predecessors(node.name)[0]
                node.quant = dict(src.quant)
                node.out_spec.dtype = src.out_spec.dtype
                node.out_spec.shift = src.out_spec.shift
            continue
        w = node.params["weight"]
        w_dt = node.overrides.get("w_dtype", cfg.w_dtype)
        a_out_dt = node.overrides.get("a_dtype", cfg.a_dtype)
        w_shift = node.overrides.get("w_shift", choose_shift(w, w_dt))
        wq = quantize(w, w_dt, w_shift, cfg.rounding)

        # output range -> output shift
        if cfg.calib is not None:
            out_amax = max(ranges.get(node.name, 1.0), 1e-12)
        else:
            colsum = float(np.max(np.sum(np.abs(w), axis=0)))
            out_amax = cur_amax * colsum
            if "bias" in node.params:
                out_amax += float(np.max(np.abs(node.params["bias"])))
            out_amax = max(out_amax, 1e-12)
        out_shift = node.overrides.get(
            "out_shift",
            choose_shift(np.asarray([out_amax]), a_out_dt),
        )
        # SRS shift must be >= 0: out binary point can't exceed acc's
        out_shift = min(out_shift, cur_shift + wq.shift)

        bias_q = None
        if "bias" in node.params:
            # bias is added to the accumulator, so it lives at acc scale
            bias_q = quantize(
                node.params["bias"], "int32", cur_shift + wq.shift,
                cfg.rounding,
            )
        node.quant = {
            "a_dtype": a_out_dt,
            "w_dtype": w_dt,
            "acc_dtype": cfg.acc_dtype,
            "in_shift": cur_shift,
            "w_shift": wq.shift,
            "out_shift": out_shift,
            "srs_shift": requant_shift(cur_shift, wq.shift, out_shift),
            "rounding": cfg.rounding,
            "weight_q": np.asarray(wq.data),
            "bias_q": None if bias_q is None else np.asarray(bias_q.data),
        }
        node.out_spec.dtype = a_out_dt
        node.out_spec.shift = out_shift
        cur_shift = out_shift
        cur_amax = min(out_amax,
                       (2 ** (8 * _DTYPE_BYTES[a_out_dt] - 1)) / 2**out_shift)
    return g


# ---------------------------------------------------------------------------
# 3. Resolve: tilings + cascade parallelism
# ---------------------------------------------------------------------------


def resolve_pass(g: Graph, cfg: CompileConfig) -> Graph:
    dev: AIEMLDevice = g.meta["device"]
    for node in g.compute_nodes():
        a_dt_in = g.predecessors(node.name)[0].out_spec.dtype
        w_dt = node.quant["w_dtype"]
        key = (a_dt_in, w_dt)
        if key not in NATIVE_TILINGS:
            raise ValueError(f"no native mmul tiling for {key}")
        t: MmulTiling = NATIVE_TILINGS[key]
        node.tile = {"M": t.M, "K": t.K, "N": t.N, "tiling": t}
        f_in = g.predecessors(node.name)[0].out_spec.features
        f_out = node.out_spec.features
        batch = node.out_spec.shape[0]
        node.cascade = resolve_cascade(
            f_in, f_out, t, dev,
            batch=min(batch, 128),
            a_bytes=_DTYPE_BYTES[a_dt_in],
            w_bytes=_DTYPE_BYTES[w_dt],
            overrides=node.overrides,
        )
    total = sum(n.cascade.n_tiles for n in g.compute_nodes())
    if total > dev.n_tiles:
        raise ValueError(
            f"model needs {total} tiles > device has {dev.n_tiles}; "
            "reduce parallelism overrides"
        )
    g.meta["tiles_used"] = total
    return g


# ---------------------------------------------------------------------------
# 4. Pack: tile-format weight/bias layouts (+ zero padding)
# ---------------------------------------------------------------------------


def pack_pass(g: Graph, cfg: CompileConfig) -> Graph:
    for node in g.compute_nodes():
        c = node.cascade
        t: MmulTiling = node.tile["tiling"]
        packed = pack_dense_weight(
            node.quant["weight_q"], c.cas_len, c.cas_num,
            c.f_in_slice, c.f_out_slice, t.K, t.N,
        )
        node.packed = {
            "weight_tiles": packed["packed"],
            "weight_padded": packed["padded"],
            "pad_in": packed["padded"].shape[0] - node.quant["weight_q"].shape[0],
            "pad_out": packed["padded"].shape[1] - node.quant["weight_q"].shape[1],
        }
        if node.quant["bias_q"] is not None:
            b_tiles, b_padded = pack_bias(
                node.quant["bias_q"], c.cas_num, c.f_out_slice
            )
            node.packed["bias_tiles"] = b_tiles
            node.packed["bias_padded"] = b_padded
    return g


# ---------------------------------------------------------------------------
# 5. GraphPlan: memory-tile edges between layer graphs
# ---------------------------------------------------------------------------


def graphplan_pass(g: Graph, cfg: CompileConfig) -> Graph:
    dev: AIEMLDevice = g.meta["device"]
    g.memtile_edges = []
    for node in g.compute_nodes():
        for succ in g.successors(node.name):
            if succ.op not in (OpKind.DENSE, OpKind.OUTPUT):
                continue
            batch = node.out_spec.shape[0]
            n_pad = node.cascade.cas_num * node.cascade.f_out_slice
            write_t = (node.tile["M"], node.tile["N"])
            if succ.op == OpKind.DENSE:
                read_t = (succ.tile["M"], succ.tile["K"])
            else:
                read_t = write_t
            edge = MemTileEdge(
                src=node.name,
                dst=succ.name,
                buffer_shape=(min(batch, 128), n_pad),
                write_tiling=write_t,
                read_tiling=read_t,
                zero_pad=(0, n_pad - node.out_spec.features),
                dtype=node.out_spec.dtype,
                double_buffered=True,
            )
            g.memtile_edges.append(edge)
    total_bytes = sum(e.buffer_bytes for e in g.memtile_edges)
    capacity = dev.n_memtiles * dev.memtile_bytes
    if total_bytes > capacity:
        raise ValueError(
            f"memtile demand {total_bytes}B exceeds capacity {capacity}B"
        )
    g.meta["memtile_bytes"] = total_bytes
    return g


# ---------------------------------------------------------------------------
# 6. Place: branch-and-bound placement on the 2D array
# ---------------------------------------------------------------------------


def place_pass(g: Graph, cfg: CompileConfig) -> Graph:
    dev: AIEMLDevice = g.meta["device"]
    nodes = g.compute_nodes()
    blocks = [
        Block(n.cascade.cas_len, n.cascade.cas_num, n.name) for n in nodes
    ]
    fixed = {
        i: tuple(n.overrides["place"])
        for i, n in enumerate(nodes)
        if "place" in n.overrides
    }
    placer = Placer(dev.n_cols, dev.n_rows, cfg.lam, cfg.mu, cfg.beam)
    result = placer.branch_and_bound(blocks, start=cfg.start, fixed=fixed)
    for node, pos in zip(nodes, result.positions):
        node.place = pos
    g.meta["placement_cost"] = result.cost
    g.meta["placement_expanded"] = result.nodes_expanded
    return g


PIPELINE = [lower_pass, quantize_pass, resolve_pass, pack_pass,
            graphplan_pass, place_pass]


def run_passes(g: Graph, cfg: Optional[CompileConfig] = None) -> Graph:
    cfg = cfg or CompileConfig()
    g.meta["config"] = cfg
    for p in PIPELINE:
        g = p(g, cfg)
    return g
