"""AIE4ML intermediate representation.

The IR is a small SSA-ish graph of named nodes. Each node carries an op kind,
its tensor specification, and attribute namespaces that the pass pipeline
progressively populates (quantization, tiling, cascade parallelism, packing,
graph-plan edges, placement). User-supplied directives land in
``node.overrides`` and are honored by every pass ("inferred attributes can be
overridden by the user configuration").

This mirrors the paper's Fig. 2 pipeline: the hls4ml graph is lowered into
this representation, and every later stage is a pass over it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np


class OpKind:
    INPUT = "input"
    DENSE = "dense"          # linear layer, optionally with fused bias/relu
    RELU = "relu"            # standalone (gets fused by the Lower pass)
    RESHAPE = "reshape"
    OUTPUT = "output"


@dataclasses.dataclass
class TensorSpec:
    """Logical tensor: shape is (batch, features) after lowering."""

    shape: tuple
    dtype: str = "float32"
    shift: int = 0  # binary point for integer dtypes

    @property
    def features(self) -> int:
        return int(self.shape[-1])


@dataclasses.dataclass
class CascadeSpec:
    """The paper's CAS_LEN x CAS_NUM rectangle for one layer.

    cas_len tiles split the contraction (input-feature) dimension; cas_num
    rows split the output features. f_in_slice / f_out_slice are the
    per-tile local dimensions.
    """

    cas_len: int = 1
    cas_num: int = 1
    f_in_slice: int = 0
    f_out_slice: int = 0

    @property
    def n_tiles(self) -> int:
        return self.cas_len * self.cas_num


@dataclasses.dataclass
class PlacementSpec:
    """Block placement on the 2D array: lower-left corner + extent."""

    col: int = -1
    row: int = -1
    width: int = 0
    height: int = 0

    @property
    def c_in(self) -> int:
        return self.col  # inputs broadcast up the leftmost column

    @property
    def c_out(self) -> int:
        return self.col + self.width - 1  # cascades exit east

    @property
    def r_in(self) -> int:
        return self.row

    @property
    def r_out(self) -> int:
        return self.row

    @property
    def r_top(self) -> int:
        return self.row + self.height - 1


@dataclasses.dataclass
class MemTileEdge:
    """A memory-tile connection between two layer graphs (GraphPlan pass).

    Writer and reader tilings may differ — the memory tile re-tiles the
    activation stream between layers (paper Sec. III-C).
    """

    src: str
    dst: str
    buffer_shape: tuple
    write_tiling: tuple  # (M, N) tiles produced by src
    read_tiling: tuple   # (M, K) tiles consumed by dst
    zero_pad: tuple = (0, 0)
    dtype: str = "int8"
    double_buffered: bool = True

    @property
    def buffer_bytes(self) -> int:
        elt = {"int8": 1, "int16": 2, "int32": 4, "float32": 4, "bfloat16": 2}[
            self.dtype
        ]
        n = int(np.prod(self.buffer_shape)) * elt
        return 2 * n if self.double_buffered else n


@dataclasses.dataclass
class Node:
    name: str
    op: str
    inputs: List[str] = dataclasses.field(default_factory=list)
    out_spec: Optional[TensorSpec] = None
    # op payload (weights/bias as numpy, activation flags, ...)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # user directives, honored by passes
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # pass-populated namespaces
    quant: Dict[str, Any] = dataclasses.field(default_factory=dict)
    tile: Dict[str, Any] = dataclasses.field(default_factory=dict)
    cascade: Optional[CascadeSpec] = None
    packed: Dict[str, Any] = dataclasses.field(default_factory=dict)
    place: Optional[PlacementSpec] = None

    def __repr__(self) -> str:  # keep graph dumps readable
        return f"Node({self.name}:{self.op}->{self.out_spec})"


class Graph:
    """Ordered DAG of nodes (insertion order is topological by construction)."""

    def __init__(self, name: str = "model"):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.memtile_edges: List[MemTileEdge] = []
        self.meta: Dict[str, Any] = {}

    def add(self, node: Node) -> Node:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        for i in node.inputs:
            if i not in self.nodes:
                raise ValueError(f"node {node.name} references unknown input {i}")
        self.nodes[node.name] = node
        return node

    def __iter__(self):
        return iter(self.nodes.values())

    def __getitem__(self, name: str) -> Node:
        return self.nodes[name]

    def __len__(self) -> int:
        return len(self.nodes)

    def predecessors(self, name: str) -> List[Node]:
        return [self.nodes[i] for i in self.nodes[name].inputs]

    def successors(self, name: str) -> List[Node]:
        return [n for n in self.nodes.values() if name in n.inputs]

    def inputs(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.op == OpKind.INPUT]

    def outputs(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.op == OpKind.OUTPUT]

    def compute_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.op == OpKind.DENSE]

    def remove(self, name: str) -> None:
        if self.successors(name):
            raise ValueError(f"cannot remove {name}: has successors")
        del self.nodes[name]

    def rewire(self, old: str, new: str) -> None:
        """Point every consumer of ``old`` at ``new``."""
        for n in self.nodes.values():
            n.inputs = [new if i == old else i for i in n.inputs]

    def validate(self) -> None:
        seen = set()
        for n in self.nodes.values():
            for i in n.inputs:
                if i not in seen:
                    raise ValueError(f"{n.name} uses {i} before definition")
            seen.add(n.name)


# ---------------------------------------------------------------------------
# Frontend builders (the hls4ml-parser role). We accept a simple layer-list
# description — the same information hls4ml's IR would hand us.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DenseSpec:
    """Frontend description of one linear layer."""

    f_out: int
    weight: Optional[np.ndarray] = None  # (f_in, f_out)
    bias: Optional[np.ndarray] = None    # (f_out,)
    activation: Optional[str] = None     # None | "relu"
    name: Optional[str] = None


def build_mlp_graph(
    batch: int,
    f_in: int,
    layers: List[DenseSpec],
    name: str = "mlp",
    seed: int = 0,
) -> Graph:
    """Build a frontend graph for an MLP. Missing weights are sampled
    deterministically (benchmarks and dry-runs use this)."""
    rng = np.random.default_rng(seed)
    g = Graph(name)
    g.add(Node("x", OpKind.INPUT, out_spec=TensorSpec((batch, f_in))))
    prev, prev_f = "x", f_in
    for li, spec in enumerate(layers):
        lname = spec.name or f"dense_{li}"
        w = spec.weight
        if w is None:
            w = rng.standard_normal((prev_f, spec.f_out)) / np.sqrt(prev_f)
        if w.shape != (prev_f, spec.f_out):
            raise ValueError(
                f"{lname}: weight shape {w.shape} != ({prev_f},{spec.f_out})"
            )
        params = {"weight": np.asarray(w, np.float64)}
        if spec.bias is not None:
            params["bias"] = np.asarray(spec.bias, np.float64)
        node = Node(
            lname,
            OpKind.DENSE,
            inputs=[prev],
            out_spec=TensorSpec((batch, spec.f_out)),
            params=params,
        )
        g.add(node)
        if spec.activation == "relu":
            rname = f"{lname}_relu"
            g.add(
                Node(
                    rname,
                    OpKind.RELU,
                    inputs=[lname],
                    out_spec=TensorSpec((batch, spec.f_out)),
                )
            )
            prev = rname
        else:
            prev = lname
        prev_f = spec.f_out
    g.add(Node("y", OpKind.OUTPUT, inputs=[prev], out_spec=TensorSpec((batch, prev_f))))
    g.validate()
    return g
