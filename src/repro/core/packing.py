"""Packing pass helpers: tile-format weight layouts and zero padding.

The paper's Packing stage "reorganizes quantized stationary tensors (weights
and biases) into tiled and aligned layouts compatible with the formats
expected by AIE intrinsics". For aie::mmul<M,K,N>, a weight slice must be
streamed as contiguous K x N tiles; arbitrary layer dimensions are zero-padded
to tile multiples (the memory-tile DMA injects the zeros on hardware — here
the pack step materializes them so kernels never see ragged edges).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def pad2d(w: np.ndarray, k_pad: int, n_pad: int) -> np.ndarray:
    """Zero-pad a (K, N) matrix up to (k_pad, n_pad)."""
    out = np.zeros((k_pad, n_pad), dtype=w.dtype)
    out[: w.shape[0], : w.shape[1]] = w
    return out


def tile_interleave(w: np.ndarray, K: int, N: int) -> np.ndarray:
    """Rearrange a padded (Kp, Np) matrix into contiguous mmul tiles:
    result[kt, nt, K, N] — the stream order aie::mmul consumes."""
    Kp, Np = w.shape
    assert Kp % K == 0 and Np % N == 0
    return (
        w.reshape(Kp // K, K, Np // N, N).transpose(0, 2, 1, 3).copy()
    )


def pack_dense_weight(
    w_q: np.ndarray,
    cas_len: int,
    cas_num: int,
    f_in_slice: int,
    f_out_slice: int,
    K: int,
    N: int,
) -> Dict[str, np.ndarray]:
    """Pack a quantized (f_in, f_out) weight into per-tile mmul tile streams.

    Returns:
      packed:  [cas_num, cas_len, kt, nt, K, N] integer array — the exact
               per-tile buffers loaded once via RTP and resident on-chip.
      padded:  the zero-padded (K_pad, N_pad) matrix (oracle layout).
    """
    f_in, f_out = w_q.shape
    k_pad, n_pad = cas_len * f_in_slice, cas_num * f_out_slice
    if k_pad < f_in or n_pad < f_out:
        raise ValueError("cascade slices do not cover the layer dimensions")
    if f_in_slice % K or f_out_slice % N:
        raise ValueError("slices must be multiples of the mmul tile dims")
    padded = pad2d(w_q, k_pad, n_pad)
    # split into cascade slices, then tile-interleave each slice
    sliced = padded.reshape(cas_len, f_in_slice, cas_num, f_out_slice)
    sliced = sliced.transpose(2, 0, 1, 3)  # [cas_num, cas_len, f_in_s, f_out_s]
    kt, nt = f_in_slice // K, f_out_slice // N
    packed = np.empty((cas_num, cas_len, kt, nt, K, N), dtype=w_q.dtype)
    for r in range(cas_num):
        for c in range(cas_len):
            packed[r, c] = tile_interleave(sliced[r, c], K, N)
    return {"packed": packed, "padded": padded}


def pack_bias(
    b_q: np.ndarray, cas_num: int, f_out_slice: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pad and slice a quantized bias across cascade rows.

    Bias is loaded into the accumulators in the kernel prologue, so it lives
    at accumulator precision, sliced per cascade row: [cas_num, f_out_slice].
    """
    n_pad = cas_num * f_out_slice
    padded = np.zeros((n_pad,), dtype=b_q.dtype)
    padded[: b_q.shape[0]] = b_q
    return padded.reshape(cas_num, f_out_slice), padded
