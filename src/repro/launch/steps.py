"""Step-function builders shared by the dry-run, trainer, and server.

``make_train_step``/``make_serve_step``/``make_prefill_step`` return
(step_fn, in_shardings, out_shardings, abstract_inputs) ready for
``jax.jit(...).lower(...)``. Tracing must happen inside
``sharding_ctx(mesh, rules)`` so activation constraints resolve — the
returned ``lower`` helper handles that.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    ShardingRules,
    abstract_params,
    fit_pspec,
    logical_to_pspec,
    rules_for_mode,
    sharding_ctx,
    specs_to_shardings,
)
from repro.models.base import (
    ArchConfig,
    PageView,
    ShapeSpec,
    build_model,
    draft_prefix_params,
    paged_state_specs,
    spec_state_specs,
    split_spec_state,
    state_batch_axes,
    wipe_state_slots,
)
from repro.optim.optimizers import make_optimizer


def batch_sharding(ispec: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
                   rules: ShardingRules):
    """First dim of every batched input is the batch axis; scalars replicate."""
    out = {}
    for k, s in ispec.items():
        if s.ndim == 0:
            out[k] = NamedSharding(mesh, P())
        else:
            axes = ("batch",) + (None,) * (s.ndim - 1)
            pspec = fit_pspec(s.shape,
                              logical_to_pspec(axes, mesh, rules), mesh)
            out[k] = NamedSharding(mesh, pspec)
    return out


@dataclasses.dataclass
class LoweringBundle:
    fn: Any
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: Tuple
    mesh: Mesh
    rules: ShardingRules
    donate_argnums: Tuple[int, ...] = ()

    def lower(self):
        with self.mesh, sharding_ctx(self.mesh, self.rules):
            jitted = jax.jit(
                self.fn,
                in_shardings=self.in_shardings,
                out_shardings=self.out_shardings,
                donate_argnums=self.donate_argnums,
            )
            return jitted.lower(*self.abstract_inputs)


def _resolve_rules(cfg: ArchConfig, mode: Optional[str],
                   rules: Optional[ShardingRules]) -> ShardingRules:
    # an explicit rule table (e.g. a stage-sharded one from repro.plan)
    # takes precedence over the mode string
    return rules if rules is not None \
        else rules_for_mode(mode or cfg.sharding_mode)


def make_train_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                    mode: Optional[str] = None, *,
                    rules: Optional[ShardingRules] = None) -> LoweringBundle:
    rules = _resolve_rules(cfg, mode, rules)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg.optimizer)
    pspecs = model.param_specs()
    ospecs = optimizer.state_specs(pspecs)
    ispec = model.input_specs(shape)

    nmb = max(1, cfg.microbatches)

    def accum(params, batch):
        if nmb == 1:
            return jax.value_and_grad(model.loss)(params, batch)
        # gradient accumulation: peak activation memory drops ~nmb-fold;
        # the psum over data happens once on the accumulated grads
        micro = jax.tree.map(
            lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])
            if hasattr(x, "shape") and x.ndim else x, batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(model.loss)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
        inv = 1.0 / nmb
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = accum(params, batch)
        updates, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, updates,
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    param_sh = specs_to_shardings(pspecs, mesh, rules)
    opt_sh = specs_to_shardings(ospecs, mesh, rules)
    batch_sh = batch_sharding(ispec, mesh, rules)
    metric_sh = {"loss": NamedSharding(mesh, P()),
                 "grad_norm": NamedSharding(mesh, P())}
    return LoweringBundle(
        fn=train_step,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, metric_sh),
        abstract_inputs=(abstract_params(pspecs), abstract_params(ospecs),
                         ispec),
        mesh=mesh,
        rules=rules,
        donate_argnums=(0, 1),
    )


def make_prefill_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                      mode: Optional[str] = None, *,
                      rules: Optional[ShardingRules] = None) -> LoweringBundle:
    rules = _resolve_rules(cfg, mode, rules)
    model = build_model(cfg)
    pspecs = model.param_specs()
    ispec = model.input_specs(shape)
    # prefill doesn't need labels
    ispec = {k: v for k, v in ispec.items() if k != "labels"}

    def prefill_step(params, batch):
        return model.forward(params, batch)

    param_sh = specs_to_shardings(pspecs, mesh, rules)
    batch_sh = batch_sharding(ispec, mesh, rules)
    dec_len = ispec["tokens"].shape[1]
    logits_sh = NamedSharding(
        mesh,
        fit_pspec(
            (shape.global_batch, dec_len, cfg.vocab),
            logical_to_pspec(("batch", "seq", "vocab"), mesh, rules), mesh),
    )
    return LoweringBundle(
        fn=prefill_step,
        in_shardings=(param_sh, batch_sh),
        out_shardings=logits_sh,
        abstract_inputs=(abstract_params(pspecs), ispec),
        mesh=mesh,
        rules=rules,
    )


def make_serve_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                    mode: Optional[str] = None, *,
                    rules: Optional[ShardingRules] = None) -> LoweringBundle:
    """Decode step: one new token per sequence against resident state."""
    rules = _resolve_rules(cfg, mode, rules)
    model = build_model(cfg)
    pspecs = model.param_specs()
    sspecs = model.decode_state_specs(shape.global_batch, shape.seq_len)
    ispec = model.input_specs(shape)

    def serve_step(params, state, tokens, pos):
        return model.decode_step(params, state, tokens, pos)

    param_sh = specs_to_shardings(pspecs, mesh, rules)
    state_sh = specs_to_shardings(sspecs, mesh, rules)
    B = shape.global_batch
    tok_sh = NamedSharding(
        mesh, fit_pspec((B,), logical_to_pspec(("batch",), mesh, rules), mesh))
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(
        mesh,
        fit_pspec((B, cfg.vocab),
                  logical_to_pspec(("batch", "vocab"), mesh, rules), mesh),
    )
    return LoweringBundle(
        fn=serve_step,
        in_shardings=(param_sh, state_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, state_sh),
        abstract_inputs=(
            abstract_params(pspecs), abstract_params(sspecs),
            ispec["tokens"], ispec["pos"],
        ),
        mesh=mesh,
        rules=rules,
        donate_argnums=(1,),
    )


def make_prefill_decode_step(cfg: ArchConfig, batch: int, prefill_len: int,
                             max_len: int, mesh: Mesh,
                             mode: Optional[str] = None, *,
                             rules: Optional[ShardingRules] = None
                             ) -> LoweringBundle:
    """Batched prefill that hands off to decode: scan ``decode_step`` over
    a right-padded prompt block, teacher-forcing each sequence's prompt
    tokens and switching to greedy generation the moment its prompt runs
    out. All sequences stay position-synchronized, the KV/SSM state is
    populated exactly as an unbatched decode would populate it (no pad
    tokens ever enter the cache), and the returned state is ready for the
    single-token serve step at position ``prefill_len``.

    Inputs:  (params, state, prompt [B, P] int32, lengths [B] int32 >= 1)
    Outputs: (tokens [B, P] int32, state) — ``tokens[b, i]`` is the greedy
             prediction for position ``i + 1``; entries at ``i >=
             lengths[b] - 1`` are generated tokens, earlier ones are
             teacher-forced prompt echoes a batcher discards.
    """
    rules = _resolve_rules(cfg, mode, rules)
    model = build_model(cfg)
    pspecs = model.param_specs()
    sspecs = model.decode_state_specs(batch, max_len)

    def prefill_decode(params, state, prompt, lengths):
        def body(carry, xs):
            st, prev = carry
            i, col = xs
            tok = jnp.where(i < lengths, col, prev)
            logits, st = model.decode_step(params, st, tok, i)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (st, nxt), nxt

        xs = (jnp.arange(prefill_len, dtype=jnp.int32),
              jnp.swapaxes(prompt, 0, 1))
        (state, _), toks = jax.lax.scan(body, (state, prompt[:, 0]), xs)
        return jnp.swapaxes(toks, 0, 1), state

    param_sh = specs_to_shardings(pspecs, mesh, rules)
    state_sh = specs_to_shardings(sspecs, mesh, rules)
    prompt_sh = NamedSharding(
        mesh, fit_pspec((batch, prefill_len),
                        logical_to_pspec(("batch", None), mesh, rules), mesh))
    len_sh = NamedSharding(
        mesh, fit_pspec((batch,),
                        logical_to_pspec(("batch",), mesh, rules), mesh))
    return LoweringBundle(
        fn=prefill_decode,
        in_shardings=(param_sh, state_sh, prompt_sh, len_sh),
        out_shardings=(prompt_sh, state_sh),
        abstract_inputs=(
            abstract_params(pspecs), abstract_params(sspecs),
            jax.ShapeDtypeStruct((batch, prefill_len), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ),
        mesh=mesh,
        rules=rules,
        donate_argnums=(1,),
    )


def make_masked_decode_step(cfg: ArchConfig, batch: int, max_len: int,
                            mesh: Mesh, mode: Optional[str] = None, *,
                            rules: Optional[ShardingRules] = None,
                            steps_per_dispatch: int = 1,
                            paged: Optional[Tuple[int, int]] = None,
                            spec: Optional[Tuple[int, int]] = None
                            ) -> LoweringBundle:
    """Slot-masked decode micro-run for continuous batching (one
    executable per (bucket, k), shape-stable under churn — zero
    lowerings after warmup).

    Unlike ``make_serve_step`` (whole group in lockstep from position 0),
    this step lets every batch lane be at a different point in a request
    lifecycle while the compiled program never changes shape, and it
    ``lax.scan``s ``steps_per_dispatch`` (k) masked steps inside ONE
    executable so per-dispatch host overhead is amortized k-fold. The
    per-slot control lanes are ``[k, batch]`` *schedules* the host
    precomputes for the whole micro-run (finish steps are known at
    admission, so the schedule needs no device readback):

    * ``fresh[i, b]``  — slot ``b`` is (re)admitted at scan step ``i``:
      its KV/SSM state lanes are zeroed (buffers donated, so the reset
      is in place) before anything reads them, so a reused slot can
      never see its predecessor's cache. Admission lands on micro-run
      boundaries, so ONLY ROW 0 may be set (the compiled program applies
      exactly row 0, once, before the scan — one full-state masked pass
      per micro-run instead of k; the schedule keeps the ``[k, B]``
      shape so mid-scan admission can land later without an API break,
      at which point the wipe moves into the scan body);
    * ``start[i, b]``  — the global position the slot's request began
      at; attention is windowed to ``[start[i, b], pos + i]``. RoPE
      scores depend only on relative position, so a request admitted
      mid-dispatch decodes exactly as it would from position 0;
    * ``feed[i, b]``   — teacher-forcing lane for chunked prefill:
      ``>= 0`` feeds this prompt token (a long prompt enters as
      successive k-token chunks across micro-runs while its neighbours
      decode), ``-1`` continues from the slot's previous argmax;
    * ``active[i, b]`` — a slot whose request finishes mid-scan
      self-masks for the remaining steps: it emits token 0 (never read —
      a refilled slot always teacher-forces its first prompt token) and
      its writes land outside every other slot's window, so they are
      harmless.

    With ``paged=(page_count, page_size)`` the KV leaves are the shared
    page pool instead of per-bucket slabs, and the step takes a ninth
    input — ``table`` [B, max_len // page_size] int32, each slot's page
    table. Attention then reads/writes at each slot's LOCAL position
    ``pos + i - start[i, b]`` through its table (RoPE included), so
    ``start`` doubles as the local-coordinate origin and may sit BEFORE
    the admission boundary when a prompt prefix was served from the
    prefix cache. The fresh-lane wipe covers only the dense leaves
    (SSM/conv/cross); stale pool pages are invisible behind the
    local-position validity mask. See ``docs/memory_model.md``.

    With ``spec=(spec_k, draft_layers)`` the micro-run becomes a fused
    speculative dispatch (``spec_k`` must equal k):
    the first ``draft_layers`` blocks of the target act as a
    self-speculative DRAFT (shared embed/ln_f/head, stacked-layer
    parameter slice — a second compiled program from the same plan
    machinery, not a second parameter set) and run the k-step masked
    scan, chaining their own argmax through the feed lane exactly like
    the plain scan chains the target's. The TARGET then scores all k
    consumed tokens in ONE teacher-forced block pass
    (``model.decode_block``) over the same positions. Both programs
    index their caches at per-slot LOCAL coordinates
    (``pos + i - start[i, b]``), which is what makes host-side rollback
    free: the scheduler accepts the drafted prefix the target agrees
    with and re-winds a rejected suffix by bumping the slot's start
    cursor — no device readback beyond the per-boundary token fetch the
    streaming path already does, no in-place cache surgery (rejected
    rows sit at-or-above the rewound cursor where the next block's
    write front replaces them before any validity mask admits them).
    The draft state leaves ride in the same pytree under ``draft_``
    keys, so pool acquire/release, per-slot wipes, and donation are
    unchanged.

    ``spec`` and ``paged`` compose: the draft's ``draft_``-prefixed KV
    twins are paged into their own pool with the SAME page axes, and
    both the draft scan and the target's block verify index through the
    slot's single page table at the same local coordinates — one page id
    addresses matching rows of both pools. The host backs the drafted
    span with revocable draft pages (``PageAllocator.draft_lease``) and
    commits or rolls them back at the boundary, so the start-cursor
    rollback works unchanged over page runs.

    Inputs:  (params, state, feed [k,B] i32, prev [B] i32, pos [] i32,
              start [k,B] i32, active [k,B] bool, fresh [k,B] bool
              [, table [B, max_len/ps] i32]) —
             ``pos`` is the micro-run's base position; scan step ``i``
             runs global position ``pos + i``. Speculative mode:
             ``prev`` is the last COMMITTED token per slot (host-built
             each boundary — the device carry is meaningless under
             rollback).
    Outputs: (toks [k,B] i32 — greedy argmax for active lane-steps, 0
              elsewhere — last [B] i32 (the final scan step's tokens,
              the next micro-run's ``prev``), and the updated state).
             Speculative mode: (verify [k,B] i32 — the TARGET's greedy
             token after each consumed position — drafts [k,B] i32 —
             the draft's proposals — and the updated state); the host
             compares the two lanes to accept/rollback at the boundary.
    """
    if steps_per_dispatch < 1:
        raise ValueError(
            f"steps_per_dispatch must be >= 1, got {steps_per_dispatch}")
    k = steps_per_dispatch
    rules = _resolve_rules(cfg, mode, rules)
    model = build_model(cfg)
    pspecs = model.param_specs()
    sspecs = model.decode_state_specs(batch, max_len)
    if spec is not None:
        spec_k, draft_layers = spec
        if spec_k != k:
            raise ValueError(
                f"spec_k ({spec_k}) must equal steps_per_dispatch ({k}): "
                "the draft proposes exactly one micro-run per dispatch")
        if not 1 <= draft_layers <= cfg.n_layers:
            raise ValueError(
                f"draft_layers must be in [1, {cfg.n_layers}], "
                f"got {draft_layers}")
        if not hasattr(model, "decode_block"):
            raise ValueError(
                f"family {cfg.family!r} has no block-verify decode path "
                "(decode_block); speculative lanes need one")
        sspecs = dict(sspecs, **spec_state_specs(sspecs, draft_layers))
    if paged is not None:
        page_count, page_size = paged
        if max_len % page_size:
            raise ValueError(
                f"max_len {max_len} must be a multiple of page_size "
                f"{page_size}")
        sspecs = paged_state_specs(sspecs, page_count, page_size)
        n_tables = max_len // page_size

    batch_axes = state_batch_axes(sspecs)

    def spec_run(params, state, feed, prev, pos, start, active, fresh,
                 table=None):
        state = wipe_state_slots(state, fresh[0], batch_axes)
        tstate, dstate = split_spec_state(state)
        dparams = draft_prefix_params(params, draft_layers)
        local0 = (pos - start[0]).astype(jnp.int32)      # [B] per-slot

        def body(carry, xs):
            st, pv = carry
            i, feed_i = xs
            tok_in = jnp.where(feed_i >= 0, feed_i, pv).astype(jnp.int32)
            pages = (PageView(table, local0 + i, page_size)
                     if paged is not None else None)
            logits, st = model.decode_block(dparams, st, tok_in[:, None],
                                            local0 + i, pages=pages)
            d = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            return (st, d), (tok_in, d)

        xs = (jnp.arange(k, dtype=jnp.int32), feed)
        (dstate, _), (tok_ins, drafts) = jax.lax.scan(
            body, (dstate, prev), xs)
        # one teacher-forced pass of the full target over the k tokens
        # the draft scan actually consumed (feed steps included, so both
        # caches hold identical token prefixes)
        logits, tstate = model.decode_block(
            params, tstate, jnp.swapaxes(tok_ins, 0, 1), local0,
            pages=(PageView(table, local0, page_size)
                   if paged is not None else None))
        verify = jnp.swapaxes(
            jnp.argmax(logits, -1).astype(jnp.int32), 0, 1)      # [k, B]
        zero = jnp.zeros((), jnp.int32)
        verify = jnp.where(active, verify, zero)
        drafts = jnp.where(active, drafts, zero)
        state = dict(tstate, **{"draft_" + n: v for n, v in dstate.items()})
        return verify, drafts, state

    def masked_run(params, state, feed, prev, pos, start, active, fresh,
                   table=None):
        # admission lands on boundaries: only fresh[0] may be set, so
        # the wipe runs ONCE ahead of the scan, not k times inside it
        # (paged mode: dense leaves only — pool pages need no wipe)
        state = wipe_state_slots(state, fresh[0], batch_axes)

        def body(carry, xs):
            st, pv = carry
            i, feed_i, start_i, active_i = xs
            tok_in = jnp.where(feed_i >= 0, feed_i, pv).astype(jnp.int32)
            if paged is not None:
                pages = PageView(table, pos + i - start_i, page_size)
                logits, st = model.decode_step(params, st, tok_in, pos + i,
                                               pages=pages)
            else:
                logits, st = model.decode_step(params, st, tok_in, pos + i,
                                               window_start=start_i)
            tok = jnp.where(active_i,
                            jnp.argmax(logits, -1).astype(jnp.int32), 0)
            # pv is only ever read on live decode steps (feed == -1), and
            # a slot live at the next micro-run is necessarily active at
            # step k-1, so the masked tok is always a valid next-prev
            return (st, tok), tok

        xs = (jnp.arange(k, dtype=jnp.int32), feed, start, active)
        (state, _), toks = jax.lax.scan(body, (state, prev), xs)
        return toks, toks[-1], state

    param_sh = specs_to_shardings(pspecs, mesh, rules)
    state_sh = specs_to_shardings(sspecs, mesh, rules)
    lane_sh = NamedSharding(
        mesh, fit_pspec((batch,),
                        logical_to_pspec(("batch",), mesh, rules), mesh))
    sched_sh = NamedSharding(
        mesh, fit_pspec((k, batch),
                        logical_to_pspec((None, "batch"), mesh, rules), mesh))
    pos_sh = NamedSharding(mesh, P())
    lane_i32 = jax.ShapeDtypeStruct((batch,), jnp.int32)
    sched_i32 = jax.ShapeDtypeStruct((k, batch), jnp.int32)
    sched_bool = jax.ShapeDtypeStruct((k, batch), jnp.bool_)
    in_sh = (param_sh, state_sh, sched_sh, lane_sh, pos_sh,
             sched_sh, sched_sh, sched_sh)
    abstract = (
        abstract_params(pspecs), abstract_params(sspecs),
        sched_i32, lane_i32, jax.ShapeDtypeStruct((), jnp.int32),
        sched_i32, sched_bool, sched_bool,
    )
    if paged is not None:
        table_sh = NamedSharding(mesh, P())    # replicated: host-built int32
        in_sh = in_sh + (table_sh,)
        abstract = abstract + (
            jax.ShapeDtypeStruct((batch, n_tables), jnp.int32),)
    if spec is not None:
        # the [k, B] draft lane replaces the [B] last-token carry: the
        # host rebuilds ``prev`` from committed tokens every boundary
        return LoweringBundle(
            fn=spec_run,
            in_shardings=in_sh,
            out_shardings=(sched_sh, sched_sh, state_sh),
            abstract_inputs=abstract,
            mesh=mesh,
            rules=rules,
            donate_argnums=(1,),
        )
    return LoweringBundle(
        fn=masked_run,
        in_shardings=in_sh,
        out_shardings=(sched_sh, lane_sh, state_sh),
        abstract_inputs=abstract,
        mesh=mesh,
        rules=rules,
        donate_argnums=(1,),
    )


def make_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
              mode: Optional[str] = None, *,
              rules: Optional[ShardingRules] = None) -> LoweringBundle:
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, mode, rules=rules)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, mode, rules=rules)
    if shape.kind == "decode":
        return make_serve_step(cfg, shape, mesh, mode, rules=rules)
    raise ValueError(shape.kind)
