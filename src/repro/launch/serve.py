"""Production serving launcher: batched autoregressive decode against
resident KV-cache/SSM state (the paper's GEMV regime at pod scale).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --debug --tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.dist.sharding import (
    init_params,
    rules_for_mode,
    sharding_ctx,
    specs_to_shardings,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import SHAPES, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--debug", action="store_true")
    ap.add_argument("--tokens", type=int, default=8,
                    help="tokens to decode per sequence")
    args = ap.parse_args()

    if args.debug:
        cfg = reduced_config(args.arch)
        mesh = make_debug_mesh(1, 1)
        batch, max_len = 2, 64
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]
        batch, max_len = shape.global_batch, shape.seq_len
    if args.mode:
        cfg = cfg.with_(sharding_mode=args.mode)

    rules = rules_for_mode(cfg.sharding_mode)
    model = build_model(cfg)
    with mesh, sharding_ctx(mesh, rules):
        pspecs = model.param_specs()
        params = jax.device_put(
            init_params(jax.random.PRNGKey(0), pspecs),
            specs_to_shardings(pspecs, mesh, rules))
        sspecs = model.decode_state_specs(batch, max_len)
        state = jax.device_put(
            init_params(jax.random.PRNGKey(1), sspecs),
            specs_to_shardings(sspecs, mesh, rules))
        step = jax.jit(model.decode_step, donate_argnums=(1,))
        tokens = jnp.ones((batch,), jnp.int32)
        t_first = None
        t0 = time.perf_counter()
        for i in range(args.tokens):
            logits, state = step(params, state, tokens, jnp.int32(i))
            tokens = jnp.argmax(logits, -1).astype(jnp.int32)
            if i == 0:
                jax.block_until_ready(logits)
                t_first = time.perf_counter() - t0
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
    print(f"{cfg.name}: decoded {args.tokens} tokens x {batch} seqs "
          f"in {dt:.2f}s (first token {t_first:.2f}s, "
          f"{args.tokens * batch / dt:.1f} tok/s host-sim)")
    print("sample tokens:", jax.device_get(tokens)[:8])


if __name__ == "__main__":
    main()
