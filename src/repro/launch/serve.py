"""Production serving launcher: batched autoregressive decode against
resident KV-cache/SSM state (the paper's GEMV regime at pod scale).

Default (production) path: 16x16 single-pod mesh (2x16x16 with
--multi-pod), batch/context from the --shape ShapeSpec (default
decode_32k: batch 128, context 32768). With --debug: a reduced config on
a 1x1 host mesh with batch=2, context=64. Params and decode state are
initialized sharded via specs_to_shardings, then greedy argmax decode
runs --tokens steps with the state donated each step.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --debug --tokens 8

Flags:
  --arch       architecture alias (required), e.g. yi-6b
  --shape      production ShapeSpec name (default decode_32k); ignored
               under --debug
  --mode       sharding mode override: cascade | megatron | megatron_sp
               (default: the config's sharding_mode)
  --multi-pod  use the 2x16x16 ("pod","data","model") mesh
  --debug      reduced config on a tiny local mesh
  --tokens     tokens to decode per sequence (default 8)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.dist.sharding import (
    init_params,
    rules_for_mode,
    sharding_ctx,
    specs_to_shardings,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import SHAPES, build_model


def main():
    ap = argparse.ArgumentParser(
        description="Batched autoregressive decode against resident "
                    "KV-cache/SSM state on a production or debug mesh.")
    ap.add_argument("--arch", required=True,
                    help="architecture alias, e.g. yi-6b")
    ap.add_argument("--shape", default="decode_32k", choices=list(SHAPES),
                    help="production ShapeSpec (ignored under --debug)")
    ap.add_argument("--mode", default=None,
                    choices=["cascade", "megatron", "megatron_sp"],
                    help="sharding mode override (default: per-arch config)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (pod,data,model) mesh instead of 16x16")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a tiny local mesh (batch=2)")
    ap.add_argument("--tokens", type=int, default=8,
                    help="tokens to decode per sequence")
    args = ap.parse_args()

    if args.debug:
        cfg = reduced_config(args.arch)
        mesh = make_debug_mesh(1, 1)
        batch, max_len = 2, 64
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]
        batch, max_len = shape.global_batch, shape.seq_len
    if args.mode:
        cfg = cfg.with_(sharding_mode=args.mode)

    rules = rules_for_mode(cfg.sharding_mode)
    model = build_model(cfg)
    with mesh, sharding_ctx(mesh, rules):
        pspecs = model.param_specs()
        params = jax.device_put(
            init_params(jax.random.PRNGKey(0), pspecs),
            specs_to_shardings(pspecs, mesh, rules))
        sspecs = model.decode_state_specs(batch, max_len)
        state = jax.device_put(
            init_params(jax.random.PRNGKey(1), sspecs),
            specs_to_shardings(sspecs, mesh, rules))
        step = jax.jit(model.decode_step, donate_argnums=(1,))
        tokens = jnp.ones((batch,), jnp.int32)
        t_first = None
        t0 = time.perf_counter()
        for i in range(args.tokens):
            logits, state = step(params, state, tokens, jnp.int32(i))
            tokens = jnp.argmax(logits, -1).astype(jnp.int32)
            if i == 0:
                jax.block_until_ready(logits)
                t_first = time.perf_counter() - t0
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
    print(f"{cfg.name}: decoded {args.tokens} tokens x {batch} seqs "
          f"in {dt:.2f}s (first token {t_first:.2f}s, "
          f"{args.tokens * batch / dt:.1f} tok/s host-sim)")
    print("sample tokens:", jax.device_get(tokens)[:8])


if __name__ == "__main__":
    main()
