"""Serving launcher: thin CLI over ``repro.plan`` + ``repro.serve``.

All execution wiring — mesh construction, sharding rules, quantization
calibration, AOT executable compilation — happens inside the
:class:`repro.plan.ExecutionPlan` built by ``build_plan``; the batcher and
this CLI are thin consumers. This module only parses flags, builds the
plan, submits synthetic requests, and prints the counters. It dispatches
``--rounds`` request waves so the executable-cache hit counter is
observable after the first wave (the CI smoke job asserts hits > 0 on
the second).

Default (production) path: 16x16 single-pod mesh (2x16x16 with
--multi-pod), bucket shapes from the --shape ShapeSpec (default
decode_32k: batch 128, context 32768). With --debug: a reduced config on
a 1x1 host mesh with 2-sequence buckets.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --debug --tokens 4

Flags:
  --arch       architecture alias (required), e.g. yi-6b
  --shape      production ShapeSpec name (default decode_32k); ignored
               under --debug
  --mode       sharding mode override: cascade | megatron | megatron_sp
               (default: the config's sharding_mode)
  --multi-pod  use the 2x16x16 ("pod","data","model") mesh
  --debug      reduced config on a tiny local mesh
  --tokens     tokens to decode per request (default 8, must be >= 1)
  --quantized  int8 qmatmul decode LM head + a16w8 MLP down-projection
               (shifts calibrated from the loaded weights by the plan's
               Quantize pass)
  --rounds     request waves to dispatch (default 2: warm + cache-hit)
  --schedule   fifo (fixed dispatch groups, default) | continuous
               (slot reuse inside in-flight dispatches via the
               ContinuousScheduler — one masked decode executable per
               bucket)
  --steps-per-dispatch
               continuous micro-run length k: scan k masked steps per
               executable call (amortizes dispatch overhead; long
               prompts are chunk-prefilled k tokens per call). Needs
               --schedule continuous when > 1; bucket max_len must be a
               multiple of k. Default 1.
  --policy     boundary-time admission policy (continuous only):
               fifo (arrival order, default) | priority (strict classes,
               per-tenant fairness, aging) | edf (earliest deadline
               first, expired requests shed)
  --stream     drive the waves through the asyncio streaming front-end
               (repro.serve.server.AsyncServeServer): concurrent
               submission, per-micro-run token streams, p50/p99 TTFT
               printed from the server's client-side stats
  --paged      paged KV cache (needs --schedule continuous): one shared
               physical page pool instead of dense per-bucket KV slabs,
               with content-hashed shared-prefix reuse that skips
               prefill for common prompt openings. Optionally takes the
               page size in tokens (default 16); the pool is auto-sized
               so paged mode is never less capable than dense. Prints
               the allocator counters (pages in use, peak, prefix hits,
               prefill-skip rate) after the waves. See
               docs/memory_model.md.
  --speculative K
               speculative decode lanes (needs --schedule continuous):
               a layer-prefix draft proposes K tokens per micro-run and
               the full target verifies them in the same fused dispatch;
               K must equal --steps-per-dispatch. Accepted tokens are
               committed at micro-run boundaries, rejections roll the
               slot back. Greedy streams stay bit-exact. Composes with
               --paged: draft+verify writes land in revocable draft-page
               leases that commit or roll back with the tokens (see
               docs/memory_model.md). Prints the acceptance counters
               after the waves. See docs/serving.md.
  --draft      draft model spec for --speculative: "prefix:N" runs the
               first N layers of the target as a self-speculative draft
               (default: half the stack).
"""

from __future__ import annotations

import argparse
import asyncio

from repro.models import SHAPES
from repro.plan import MeshSpec, build_plan
from repro.serve import BucketPolicy, DecodeRequest, ServeBatcher, make_policy


def build_batcher(args) -> ServeBatcher:
    """One ExecutionPlan -> a ServeBatcher with demo params."""
    if args.debug:
        mesh_spec = MeshSpec.debug(1, 1)
        policy = BucketPolicy.debug()
    else:
        mesh_spec = MeshSpec.production(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]
        policy = BucketPolicy.production(shape.global_batch, shape.seq_len)
    plan = build_plan(args.arch, None, mode=args.mode, mesh_spec=mesh_spec,
                      quantized=args.quantized, debug=args.debug)
    admission = make_policy(args.policy) if args.policy != "fifo" else None
    batcher = plan.make_batcher(policy=policy, schedule=args.schedule,
                                steps_per_dispatch=args.steps_per_dispatch,
                                admission=admission, paged=args.paged,
                                speculative=args.speculative,
                                draft=args.draft)
    with plan.activate():
        batcher.init_demo_params(seed=0)
    return batcher


def main():
    ap = argparse.ArgumentParser(
        description="Bucketed batch decode over AOT-cached executables "
                    "and resident KV/SSM state pools, wired by one "
                    "ExecutionPlan.",
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="""\
continuous-batching extras (all need --schedule continuous):
  --steps-per-dispatch k   scan k masked steps per executable call
  --policy priority|edf    boundary-time admission ordering / shedding
  --stream                 asyncio streaming front-end with client TTFT
  --paged [PAGE_SIZE]      paged KV cache with shared-prefix prefill
                           skipping (docs/memory_model.md)
  --speculative K          fused draft+verify lanes, K = micro-run length
                           (greedy streams stay bit-exact; composes with
                           --paged via revocable draft-page leases)

examples:
  %(prog)s --arch yi-6b --debug --schedule continuous \\
      --steps-per-dispatch 4 --paged --tokens 8
  %(prog)s --arch yi-6b --debug --schedule continuous \\
      --policy edf --stream""")
    ap.add_argument("--arch", required=True,
                    help="architecture alias, e.g. yi-6b")
    ap.add_argument("--shape", default="decode_32k", choices=list(SHAPES),
                    help="production ShapeSpec (ignored under --debug)")
    ap.add_argument("--mode", default=None,
                    choices=["cascade", "megatron", "megatron_sp"],
                    help="sharding mode override (default: per-arch config)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (pod,data,model) mesh instead of 16x16")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a tiny local mesh (batch=2)")
    ap.add_argument("--tokens", type=int, default=8,
                    help="tokens to decode per request (>= 1)")
    ap.add_argument("--quantized", action="store_true",
                    help="int8 qmatmul decode LM head + quantized MLP "
                         "down-projection (calibrated shifts)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="request waves (2nd+ hit the executable cache)")
    ap.add_argument("--schedule", default="fifo",
                    choices=["fifo", "continuous"],
                    help="fixed FIFO dispatch groups, or continuous "
                         "batching with in-flight slot reuse")
    ap.add_argument("--steps-per-dispatch", type=int, default=1,
                    help="continuous micro-run length k: scan k masked "
                         "steps per executable call (>= 1; > 1 needs "
                         "--schedule continuous)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "edf"],
                    help="boundary-time admission policy (non-fifo needs "
                         "--schedule continuous)")
    ap.add_argument("--stream", action="store_true",
                    help="drive the waves through the asyncio streaming "
                         "front-end (needs --schedule continuous)")
    ap.add_argument("--paged", nargs="?", const=True, default=None,
                    type=int, metavar="PAGE_SIZE",
                    help="paged KV cache with shared-prefix reuse (needs "
                         "--schedule continuous); optional page size in "
                         "tokens, default 16")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="speculative decode: draft K tokens per micro-run "
                         "and verify them in the same fused dispatch "
                         "(needs --schedule continuous; K must equal "
                         "--steps-per-dispatch; composes with --paged)")
    ap.add_argument("--draft", default=None, metavar="PREFIX:N",
                    help="draft model for --speculative: 'prefix:N' = "
                         "first N target layers (default: half the stack)")
    args = ap.parse_args()
    if args.tokens < 1:
        ap.error("--tokens must be >= 1")
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")
    if args.steps_per_dispatch < 1:
        ap.error("--steps-per-dispatch must be >= 1")
    if args.steps_per_dispatch > 1 and args.schedule != "continuous":
        ap.error("--steps-per-dispatch > 1 needs --schedule continuous")
    if args.policy != "fifo" and args.schedule != "continuous":
        ap.error("--policy needs --schedule continuous")
    if args.stream and args.schedule != "continuous":
        ap.error("--stream needs --schedule continuous")
    if args.paged is not None and args.schedule != "continuous":
        ap.error("--paged needs --schedule continuous")
    if args.paged is not None and args.paged is not True and args.paged < 1:
        ap.error("--paged page size must be >= 1")
    if args.speculative:
        if args.schedule != "continuous":
            ap.error("--speculative needs --schedule continuous")
        if args.speculative != args.steps_per_dispatch:
            ap.error("--speculative must equal --steps-per-dispatch "
                     "(the draft proposes exactly one micro-run)")
    if args.draft is not None and not args.speculative:
        ap.error("--draft needs --speculative")

    batcher = build_batcher(args)
    batch = batcher.policy.buckets[0].batch
    # continuous batching is about refilling freed slots from a deep
    # queue: submit two requests per slot so slot reuse is observable
    wave_size = batch * 2 if args.schedule == "continuous" else batch

    def wave_requests(wave: int):
        # priorities/tenants cycle so --policy priority has classes to
        # order; deadlines are generous (nothing sheds in a smoke run)
        import time as _time

        deadline = (_time.monotonic() + 120.0
                    if args.policy == "edf" and args.stream else
                    1_000_000.0 if args.policy == "edf" else None)
        # under --paged every request opens with the same one-page
        # system prompt, so shared-prefix reuse is observable in the
        # printed allocator counters from the second admission on
        system = [1 + (j * 5) % 50 for j in range(16)] if args.paged else []
        return [DecodeRequest(
            f"w{wave}r{i}",
            system + [1 + (i + j) % 7 for j in range(i % 3 + 2)],
            max_new_tokens=args.tokens, priority=i % 3,
            tenant=f"tenant{i % 2}", deadline=deadline)
            for i in range(wave_size)]

    t_first = None
    if args.stream:
        from repro.serve import AsyncServeServer

        async def run_streaming():
            async with AsyncServeServer(batcher) as server:
                for wave in range(args.rounds):
                    results = await asyncio.gather(*[
                        server.generate(r) for r in wave_requests(wave)])
                    sample = min(results, key=lambda r: r.request_id)
                    print(f"wave {wave}: {len(results)} requests x "
                          f"{args.tokens} tokens (streamed), sample "
                          f"{sample.request_id} -> {sample.tokens[:8]}")
                return server.stats()

        with batcher.plan.activate():
            sstats = asyncio.run(run_streaming())
        print(f"stream: p50 TTFT {sstats['p50_ttft_s']}s, "
              f"p99 TTFT {sstats['p99_ttft_s']}s, "
              f"outcomes {sstats['outcomes']}")
    else:
        with batcher.plan.activate():
            for wave in range(args.rounds):
                for r in wave_requests(wave):
                    batcher.submit(r)
                results = batcher.run()
                if t_first is None and results:
                    t_first = min(r.prefill_seconds
                                  for r in results.values())
                sample = results[sorted(results)[0]]
                print(f"wave {wave}: {len(results)} requests x "
                      f"{args.tokens} tokens, sample {sample.request_id} "
                      f"-> {sample.tokens[:8]}")

    stats = batcher.stats()
    for label, m in stats["buckets"].items():
        print(f"bucket {label}: {m['requests']} reqs, "
              f"{m['new_tokens']} tokens, "
              f"{m['tokens_per_second']:.1f} tok/s host-sim, "
              f"p50 {m['p50_latency_s']:.3f}s p99 {m['p99_latency_s']:.3f}s")
    if "scheduler" in stats:
        s = stats["scheduler"]
        print(f"scheduler: {s['admissions']} admissions over "
              f"{s['dispatches']} dispatches, busy slot fraction "
              f"{s['busy_slot_fraction']}, mean refill gap "
              f"{s['mean_refill_gap']} steps")
    if "scheduler" in stats and args.speculative:
        s = stats["scheduler"]["spec"]
        print(f"speculative: k={s['spec_k']} draft_layers="
              f"{s['draft_layers']}, {s['accepted_tokens']}/"
              f"{s['draft_tokens']} draft tokens accepted "
              f"({s['accepted_tokens_per_dispatch']} per verify), "
              f"{s['rollbacks']} rollbacks, "
              f"{s['continuations']} continuations")
    if "paged" in stats:
        p = stats["paged"]
        print(f"paged: {p['pages_in_use']}/{p['page_count']} pages in "
              f"use (peak {p['peak_pages']}), {p['prefix_hits']} prefix "
              f"hits, {p['skipped_prefill_tokens']} prompt tokens "
              f"skipped (rate {p['prefill_skip_rate']:.3f}), "
              f"{p['evictions']} evictions")
        if args.speculative:
            print(f"draft leases: {p['draft_pages_committed']} pages "
                  f"committed, {p['draft_pages_rolled_back']} rolled back")
    c = stats["cache"]
    first = f"{t_first:.2f}s" if t_first is not None else "n/a"
    print(f"{batcher.cfg.name}: first token {first}; cache entries="
          f"{c['entries']} hits={c['hits']} misses={c['misses']} "
          f"lowerings={c['lowerings']} compiles={c['compiles']}")


if __name__ == "__main__":
    main()
