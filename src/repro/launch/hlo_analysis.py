"""Compiled-HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE, so any
scan-over-layers program is undercounted by the layer count. This module
parses ``compiled.as_text()`` (the post-SPMD, per-device module), builds the
computation call graph, and scales every computation's statistics by the
``known_trip_count`` of the while loops that call it. It reports, per device:

  * flops            — 2 * |result| * contraction for every dot op
  * bytes            — result bytes written + resolvable operand bytes read
                       (an HBM-traffic proxy on a no-cache model)
  * collective_bytes — wire bytes per device with ring-algorithm factors:
        all-gather:          result * (G-1)/G
        reduce-scatter:      result * (G-1)
        all-reduce:          result * 2(G-1)/G
        all-to-all:          result * (G-1)/G
        collective-permute:  result
  * per-collective-type byte/op counts (the §Perf iteration reads these)
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\("
)
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\]{},\d]+))")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Ops counted toward the HBM-traffic proxy (operands read + result written).
# Pure elementwise / broadcast / convert / transpose are EXCLUDED: the CPU
# backend materializes them as separate ops, but the TPU backend (the
# roofline target) fuses them into neighbors, so counting them would inflate
# the memory term ~5-10x. Fusion boundaries, dots, copies, slicing/scatter
# and collectives are real materialization points on both backends.
_MEM_OPS = {
    "fusion", "dot", "convolution", "copy",
    "reduce", "sort", "scatter", "gather", "concatenate",
    "dynamic-slice", "dynamic-update-slice", "pad",
    "select-and-scatter", "reduce-window", "custom-call",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Tuple[Optional[str], Tuple[int, ...]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]            # param name -> type string
    instrs: List[Instr]


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw.rstrip())
        if not line:
            continue
        if not raw.startswith(" "):
            # computation header or metadata section
            if "{" in line and ("(" in line and "->" in line):
                is_entry = line.startswith("ENTRY")
                header = line.split("(", 1)
                name = header[0].replace("ENTRY", "").strip().lstrip("%")
                args = line[line.index("(") + 1: line.rindex("->")]
                params = {}
                for pname, ptype in _PARAM_RE.findall(args):
                    params[pname] = ptype
                cur = Computation(name, params, [])
                comps[name] = cur
                if is_entry:
                    entry = name
            elif line.startswith("}"):
                cur = None
            else:
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            cur.instrs.append(Instr(name, opcode, type_str, line))
    return comps, entry


def _operand_names(line: str) -> List[str]:
    """Names inside the op's argument parens (before attribute list)."""
    start = line.index("(")
    depth, i = 0, start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                break
    args = line[start + 1: i]
    return re.findall(r"%([\w.\-]+)", args)


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_ops: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult
        for k, v in other.collective_ops.items():
            self.collective_ops[k] = self.collective_ops.get(k, 0) + int(v * mult)


def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op.startswith("all-reduce"):
        return result_bytes * 2.0 * (g - 1) / g
    if op.startswith("all-gather"):
        return result_bytes * (g - 1) / g
    if op.startswith("reduce-scatter"):
        return result_bytes * (g - 1)
    if op.startswith("all-to-all"):
        return result_bytes * (g - 1) / g
    if op.startswith("collective-permute"):
        return float(result_bytes)
    return 0.0


class ModuleAnalysis:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._local: Dict[str, Stats] = {}
        self._calls: Dict[str, List[Tuple[str, float]]] = {}
        for comp in self.comps.values():
            self._analyze_comp(comp)
        self._total_cache: Dict[str, Stats] = {}

    # -- per-computation local stats + call edges ----------------------------

    def _type_of(self, comp: Computation, name: str) -> Optional[str]:
        for ins in comp.instrs:
            if ins.name == name:
                return ins.type_str
        if name in comp.params:
            return comp.params[name]
        return None

    def _fusion_operand_bytes(self, fusion_line: str, operands, comp) -> float:
        """Bytes read by a fusion: per-operand, if the corresponding fused
        parameter feeds a dynamic-(update-)slice INSIDE the fused
        computation, only the sliced/updated region is touched
        (loop-resident stacked buffers are indexed, not streamed)."""
        m = re.search(r"calls=%?([\w.\-]+)", fusion_line)
        fused = self.comps.get(m.group(1)) if m else None
        param_names = list(fused.params.keys()) if fused else []
        total = 0.0
        for idx, opname in enumerate(operands):
            t = self._type_of(comp, opname)
            if not t:
                continue
            b = _shape_bytes(t)
            if fused and idx < len(param_names):
                # names equivalent to this param through pure cast chains
                # (XLA:CPU wraps bf16 buffers in convert/copy/bitcast; TPU
                # has native bf16 so these are not traffic on the target)
                aliases = {param_names[idx]}
                for fins in fused.instrs:
                    if fins.opcode in ("convert", "copy", "bitcast"):
                        ops_in = _operand_names(fins.line)
                        if ops_in and ops_in[0] in aliases:
                            aliases.add(fins.name)
                for fins in fused.instrs:
                    ops_in = _operand_names(fins.line)
                    if fins.opcode == "dynamic-slice" and \
                            aliases & set(ops_in):
                        b = min(b, _shape_bytes(fins.type_str))
                        break
                    if fins.opcode == "dynamic-update-slice" and ops_in \
                            and ops_in[0] in aliases:
                        # buffer operand of a fused in-place update: the
                        # untouched region is neither read nor written
                        upd = (self._type_of(fused, ops_in[1])
                               if len(ops_in) > 1 else None)
                        b = min(b, _shape_bytes(upd) if upd else b)
                        break
            total += b
        return total

    def _fusion_result_bytes(self, fusion_line: str, rbytes: int) -> float:
        """Bytes written by a fusion: if its root is a dynamic-update-slice,
        only the update region is written (the rest of the buffer aliases
        the input in-place)."""
        m = re.search(r"calls=%?([\w.\-]+)", fusion_line)
        fused = self.comps.get(m.group(1)) if m else None
        if not fused or not fused.instrs:
            return float(rbytes)
        root = fused.instrs[-1]
        for ins in fused.instrs:
            if ins.line.lstrip().startswith("ROOT"):
                root = ins
                break
        # unwrap pure cast chains (convert/copy/bitcast) around the root
        by_name = {i.name: i for i in fused.instrs}
        seen = 0
        while root.opcode in ("convert", "copy", "bitcast") and seen < 8:
            ops_in = _operand_names(root.line)
            if not ops_in or ops_in[0] not in by_name:
                break
            root = by_name[ops_in[0]]
            seen += 1
        if root.opcode == "dynamic-update-slice":
            ops_in = _operand_names(root.line)
            upd = self._type_of(fused, ops_in[1]) if len(ops_in) > 1 else None
            if upd:
                return float(min(rbytes, _shape_bytes(upd)))
        return float(rbytes)

    def _analyze_comp(self, comp: Computation):
        st = Stats()
        calls: List[Tuple[str, float, str]] = []
        for ins in comp.instrs:
            op = ins.opcode
            rbytes = _shape_bytes(ins.type_str)
            # call edges: while bodies scale by trip count; fusion bodies
            # contribute flops/collectives but NOT bytes (fused ops never
            # round-trip HBM)
            if op == "while":
                trip = 1
                m = _TRIP_RE.search(ins.line)
                if m:
                    trip = int(m.group(1))
                for cm in _CALL_ATTR_RE.finditer(ins.line):
                    calls.append((cm.group(1), float(trip), "control"))
            else:
                kind = "fusion" if op in ("fusion", "reduce", "scatter",
                                          "sort", "select-and-scatter",
                                          "reduce-window", "map",
                                          "custom-call") or any(
                    op.startswith(c) for c in COLLECTIVES) else "control"
                for cm in _CALL_ATTR_RE.finditer(ins.line):
                    calls.append((cm.group(1), 1.0, kind))
                m = _BRANCH_RE.search(ins.line)
                if m:
                    for b in re.findall(r"%?([\w.\-]+)", m.group(1)):
                        calls.append((b, 1.0, "control"))
            # collectives
            base = None
            for c in COLLECTIVES:
                if op == c or op == c + "-start":
                    base = c
                    break
            if base is not None:
                g = _group_size(ins.line)
                wire = _wire_bytes(op, rbytes, g)
                st.collective_bytes += wire
                st.per_collective[base] = st.per_collective.get(base, 0.0) + wire
                st.collective_ops[base] = st.collective_ops.get(base, 0) + 1
            # flops: dot contraction
            if op == "dot":
                operands = _operand_names(ins.line)
                lhs_type = self._type_of(comp, operands[0]) if operands else None
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                contraction = 1
                if lhs_type and cdims and cdims.group(1):
                    _, lhs_shape = _first_shape(lhs_type)
                    for d in cdims.group(1).split(","):
                        di = int(d)
                        if di < len(lhs_shape):
                            contraction *= lhs_shape[di]
                _, rshape = _first_shape(ins.type_str)
                st.flops += 2.0 * math.prod(rshape or (1,)) * contraction
            # memory traffic proxy
            if op in _MEM_OPS:
                if op == "dynamic-update-slice":
                    # in-place on the big buffer (XLA aliases loop carries):
                    # traffic = the update region, written once + read once
                    operands = _operand_names(ins.line)
                    upd = (self._type_of(comp, operands[1])
                           if len(operands) > 1 else None)
                    st.bytes += 2 * _shape_bytes(upd) if upd else 0
                elif op == "dynamic-slice":
                    # reads only the sliced region
                    st.bytes += 2 * rbytes
                elif op == "fusion":
                    st.bytes += self._fusion_result_bytes(ins.line, rbytes)
                    st.bytes += self._fusion_operand_bytes(
                        ins.line, _operand_names(ins.line), comp)
                else:
                    st.bytes += rbytes
                    for name in _operand_names(ins.line):
                        t = self._type_of(comp, name)
                        if t:
                            st.bytes += _shape_bytes(t)
        self._local[comp.name] = st
        self._calls[comp.name] = calls

    # -- call-graph rollup ----------------------------------------------------

    def total(self, comp_name: Optional[str] = None,
              _stack: Tuple = ()) -> Stats:
        name = comp_name or self.entry
        if name in self._total_cache:
            return self._total_cache[name]
        if name in _stack or name not in self._local:
            return Stats()
        st = Stats()
        st.add(self._local[name])
        for child, mult, kind in self._calls.get(name, []):
            sub = self.total(child, _stack + (name,))
            if kind == "fusion":
                sub = dataclasses.replace(
                    sub, bytes=0.0,
                    per_collective=dict(sub.per_collective),
                    collective_ops=dict(sub.collective_ops),
                )
            st.add(sub, mult)
        if not _stack:
            self._total_cache[name] = st
        return st


def analyze_hlo(text: str) -> Stats:
    return ModuleAnalysis(text).total()
