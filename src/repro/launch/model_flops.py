"""Analytic MODEL_FLOPS (the 6·N·D / 2·N·D convention) per architecture.

N counts "active" parameters: embedding table excluded, MoE expert weights
scaled by top_k / n_experts (plus shared experts at 1.0). The ratio
MODEL_FLOPS / HLO_FLOPs in the roofline table then measures how much of the
compiled compute is useful (remat and replicated compute push it down).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax

from repro.dist.sharding import ParamSpec
from repro.models.base import ArchConfig, ShapeSpec, build_model


def param_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """Returns (total_params, active_params)."""
    model = build_model(cfg)
    specs = model.param_specs()
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0]
    total = 0
    active = 0.0
    moe_frac = cfg.top_k / cfg.n_experts if cfg.n_experts else 1.0
    for path, spec in flat:
        n = math.prod(spec.shape)
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        total += n
        if "embed" in keys and "table" in keys:
            continue  # lookup, not matmul
        if "moe" in keys and "router" not in keys:
            active += n * moe_frac
        else:
            active += n
    return total, int(active)


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Global MODEL_FLOPS for one step of the given kind."""
    _, n_active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            # encoder sees seq_len frames, decoder seq_len/dec_ratio tokens;
            # 6ND with the blended token count
            tokens = shape.global_batch * (
                shape.seq_len + shape.seq_len // cfg.dec_ratio
            ) // 2
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * (
                shape.seq_len + shape.seq_len // cfg.dec_ratio
            ) // 2
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
