import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: build an ExecutionPlan for every (architecture x
input shape) on the production meshes, compile its executable AOT through
the plan's cache, prove the sharding config is coherent, and extract the
roofline statistics from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count on first init, and the production mesh needs 512 placeholder devices.
(Only this entry point sets it — smoke tests and benches see 1 device.)
"""

import argparse      # noqa: E402
import json          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

from repro.configs import ALIASES, get_config, list_archs  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo          # noqa: E402
from repro.launch.model_flops import model_flops, param_counts  # noqa: E402
from repro.launch.roofline import roofline_terms, summarize     # noqa: E402
from repro.models.base import SHAPES, supports_shape       # noqa: E402
from repro.plan import MeshSpec, build_plan                # noqa: E402


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mode: Optional[str] = None,
    pipeline_stages: int = 1,
    verbose: bool = True,
    hlo_dir: Optional[str] = None,
    config_overrides: Optional[dict] = None,
) -> dict:
    cfg = get_config(arch)
    if config_overrides:
        cfg = cfg.with_(**config_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": mesh_name,
        "mode": mode or cfg.sharding_mode,
        "stages": pipeline_stages,
    }
    ok, reason = supports_shape(cfg, shape_name)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        if verbose:
            print(f"SKIP {cfg.name} x {shape_name}: {reason}")
        return record
    try:
        plan = build_plan(
            cfg, shape, mode=mode,
            mesh_spec=MeshSpec.production(multi_pod=multi_pod),
            pipeline_stages=pipeline_stages,
        )
        n_chips = plan.mesh.devices.size
        entry = plan.executable()          # AOT lower+compile, counted
        compiled = entry.compiled
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {}
        text = compiled.as_text()
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            fn = f"{arch}_{shape_name}_{mesh_name}_{record['mode']}.hlo"
            with open(os.path.join(hlo_dir, fn.replace('/', '_')), "w") as f:
                f.write(text)
        stats = analyze_hlo(text)
        mf = model_flops(cfg, shape)
        total_p, active_p = param_counts(cfg)
        record.update(
            status="ok",
            lower_s=round(entry.lower_seconds, 2),
            compile_s=round(entry.compile_seconds, 2),
            params_total=total_p,
            params_active=active_p,
            memory=mem,
            xla_cost_analysis={
                "flops_module_once": ca.get("flops", 0.0),
                "bytes_module_once": ca.get("bytes accessed", 0.0),
            },
            roofline=roofline_terms(stats, n_chips, mf, mem),
        )
        if pipeline_stages > 1:
            record["stage_slices"] = [s.as_dict() for s in plan.ir.stages]
        if verbose:
            print(f"== {cfg.name} x {shape_name} on {mesh_name} "
                  f"({record['mode']}) ==")
            print(f"memory_analysis (per device): {mem}")
            print(f"cost_analysis: flops(once)={ca.get('flops', 0):.3e}")
            print(summarize(record))
    except Exception as e:  # noqa: BLE001 — record the failure, keep batch
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"ERROR {cfg.name} x {shape_name} on {mesh_name}: "
                  f"{record['error']}")
    return record


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None,
                   help=f"one of {list(ALIASES)} (or module id)")
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--single-pod", action="store_true")
    p.add_argument("--mode", default=None,
                   choices=["cascade", "megatron", "megatron_sp"],
                   help="sharding mode override (default: per-arch config)")
    p.add_argument("--stages", type=int, default=1,
                   help="pipeline stages (PlaceStages pass)")
    p.add_argument("--all", action="store_true",
                   help="every (arch x shape) on the requested mesh(es)")
    p.add_argument("--moe-groups", type=int, default=None,
                   help="group-limited MoE dispatch (0/None = global sort)")
    p.add_argument("--q-chunk", type=int, default=None)
    p.add_argument("--microbatches", type=int, default=None,
                   help="gradient-accumulation factor for train shapes")
    p.add_argument("--out", default=None, help="write JSON records here")
    p.add_argument("--hlo-dir", default=None, help="dump compiled HLO text")
    args = p.parse_args()

    if args.single_pod and not args.multi_pod:
        meshes = [False]
    elif args.multi_pod and not args.single_pod:
        meshes = [True]
    else:  # default: prove both the single-pod and the multi-pod mesh
        meshes = [False, True]

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    overrides = {}
    if args.moe_groups is not None:
        overrides["moe_groups"] = args.moe_groups
    if args.q_chunk is not None:
        overrides["q_chunk"] = args.q_chunk
    if args.microbatches is not None:
        overrides["microbatches"] = args.microbatches

    records = []
    for arch, shape in cells:
        for mp in meshes:
            records.append(
                run_cell(arch, shape, multi_pod=mp, mode=args.mode,
                         pipeline_stages=args.stages,
                         hlo_dir=args.hlo_dir,
                         config_overrides=overrides or None)
            )
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out if args.out.endswith(".json")
                  else args.out + ".json", "w") as f:
            json.dump(records, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"/ {len(records)} cells")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
