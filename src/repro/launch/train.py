"""Production training launcher.

On a TPU pod slice this builds the production mesh and runs the sharded
train step from launch/steps.py; on this CPU container use --debug to run a
reduced config on a small host mesh (the integration tests exercise the
same path with 8 forced host devices).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --debug --steps 20
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import make_train_iterator
from repro.dist.sharding import (
    init_params,
    rules_for_mode,
    sharding_ctx,
    specs_to_shardings,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import SHAPES, build_model
from repro.models.base import ShapeSpec
from repro.optim.optimizers import make_optimizer
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--mode", default=None,
                    choices=["cascade", "megatron", "megatron_sp"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a tiny local mesh")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.debug:
        cfg = reduced_config(args.arch)
        mesh = make_debug_mesh(1, 1)
        seq, batch = 32, 4
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]
        seq, batch = shape.seq_len, shape.global_batch
    if args.mode:
        cfg = cfg.with_(sharding_mode=args.mode)

    rules = rules_for_mode(cfg.sharding_mode)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg.optimizer)

    with mesh, sharding_ctx(mesh, rules):
        specs = model.param_specs()
        params = init_params(jax.random.PRNGKey(0), specs)
        params = jax.device_put(params,
                                specs_to_shardings(specs, mesh, rules))
        opt_state = optimizer.init(params)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh {mesh.devices.shape} "
          f"mode={cfg.sharding_mode}")

    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, log_every=5,
        microbatches=args.microbatches, compress_grads=args.compress_grads,
    )
    trainer = Trainer(model.loss, optimizer, tcfg, mesh=mesh, rules=rules)

    def iters(start):
        return make_train_iterator(cfg.vocab, seq, batch, seed=0,
                                   start_step=start)

    _, _, hist = trainer.fit(params, opt_state, iters)
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
