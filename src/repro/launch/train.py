"""Production training launcher: thin CLI over ``repro.plan``.

The ExecutionPlan owns all execution wiring — mesh construction, the
sharding rule table, pipeline-stage placement, and parameter/optimizer
state sharding; this module parses flags, builds one plan, and hands the
restart-safe Trainer loop the plan's mesh/rules.

Default (production) path: 16x16 single-pod mesh — or 2x16x16 with
--multi-pod — with the full architecture config and the --shape
ShapeSpec. With --debug: a reduced config on a 1x1 host mesh with seq=32,
batch=4 (the 8-device integration tests exercise the same path on a 2x4
mesh). ``--stages N`` engages the plan's PlaceStages pass: the layer
stack splits into N pipeline stages assigned to mesh slices by the
``core.placement`` cost model, sharding the stacked layer weights across
the data axis instead of replicating them.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --debug --steps 20

Flags:
  --arch          architecture alias (required), e.g. yi-6b
  --shape         production ShapeSpec name (default train_4k); ignored
                  under --debug
  --mode          sharding mode override: cascade | megatron | megatron_sp
                  (default: the config's sharding_mode)
  --multi-pod     use the 2x16x16 ("pod","data","model") mesh
  --debug         reduced config on a tiny local mesh
  --stages        pipeline stages for the PlaceStages pass (default 1)
  --steps         training steps (default 50)
  --ckpt-dir      checkpoint directory (resume is automatic from the
                  newest checkpoint found there)
  --microbatches  gradient-accumulation factor
  --compress-grads  int8 error-feedback gradient compression
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.data.pipeline import make_train_iterator
from repro.models import SHAPES
from repro.models.base import ShapeSpec
from repro.plan import MeshSpec, build_plan
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser(
        description="Sharded training on a production or debug mesh with "
                    "the restart-safe Trainer loop, wired by one "
                    "ExecutionPlan.")
    ap.add_argument("--arch", required=True,
                    help="architecture alias, e.g. yi-6b")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES),
                    help="production ShapeSpec (ignored under --debug)")
    ap.add_argument("--mode", default=None,
                    choices=["cascade", "megatron", "megatron_sp"],
                    help="sharding mode override (default: per-arch config)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (pod,data,model) mesh instead of 16x16")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a tiny local mesh (seq=32, batch=4)")
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages (PlaceStages pass; layers shard "
                         "across mesh slices chosen by the cost model)")
    ap.add_argument("--steps", type=int, default=50,
                    help="training steps to run")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train",
                    help="checkpoint dir (resumes from the newest found)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation factor")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args()

    if args.debug:
        shape = ShapeSpec("debug_train", 32, 4, "train")
        mesh_spec = MeshSpec.debug(1, 1)
    else:
        shape = SHAPES[args.shape]
        mesh_spec = MeshSpec.production(multi_pod=args.multi_pod)

    plan = build_plan(args.arch, shape, mode=args.mode, mesh_spec=mesh_spec,
                      pipeline_stages=args.stages, debug=args.debug)
    params, opt_state = plan.init_train_state(seed=0)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{plan.cfg.name}: {n/1e6:.1f}M params on mesh "
          f"{plan.mesh.devices.shape} mode={plan.mode} "
          f"stages={args.stages}")
    if plan.ir.stages:
        for s in plan.ir.stages:
            print(f"  stage {s.index}: layers [{s.first_layer}, "
                  f"{s.first_layer + s.n_layers}) on rows "
                  f"[{s.row}, {s.row + s.height}) (cost model: "
                  f"{plan.ir.placement_method})")

    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, log_every=5,
        microbatches=args.microbatches, compress_grads=args.compress_grads,
    )
    trainer = Trainer(plan.model.loss, plan.optimizer, tcfg,
                      mesh=plan.mesh, rules=plan.rules)

    seq, batch = shape.seq_len, shape.global_batch

    def iters(start):
        return make_train_iterator(plan.cfg.vocab, seq, batch, seed=0,
                                   start_step=start)

    _, _, hist = trainer.fit(params, opt_state, iters)
    if hist:
        print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    else:
        print(f"done: checkpoint in {args.ckpt_dir} is already at "
              f">= {args.steps} steps; nothing to do (use a fresh "
              "--ckpt-dir or raise --steps)")


if __name__ == "__main__":
    main()
