"""Production training launcher.

Default (production) path: build the 16x16 single-pod mesh — or the
2x16x16 multi-pod mesh with --multi-pod — take the full architecture
config and the --shape ShapeSpec, and run the restart-safe Trainer loop
under sharding_ctx. With --debug: a reduced config on a 1x1 host mesh
with seq=32, batch=4 (the 8-device integration tests exercise the same
path on a 2x4 mesh).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --debug --steps 20

Flags:
  --arch          architecture alias (required), e.g. yi-6b
  --shape         production ShapeSpec name (default train_4k); ignored
                  under --debug
  --mode          sharding mode override: cascade | megatron | megatron_sp
                  (default: the config's sharding_mode)
  --multi-pod     use the 2x16x16 ("pod","data","model") mesh
  --debug         reduced config on a tiny local mesh
  --steps         training steps (default 50)
  --ckpt-dir      checkpoint directory (resume is automatic from the
                  newest checkpoint found there)
  --microbatches  gradient-accumulation factor
  --compress-grads  int8 error-feedback gradient compression
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import make_train_iterator
from repro.dist.sharding import (
    init_params,
    rules_for_mode,
    sharding_ctx,
    specs_to_shardings,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import SHAPES, build_model
from repro.models.base import ShapeSpec
from repro.optim.optimizers import make_optimizer
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser(
        description="Sharded training on a production or debug mesh with "
                    "the restart-safe Trainer loop.")
    ap.add_argument("--arch", required=True,
                    help="architecture alias, e.g. yi-6b")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES),
                    help="production ShapeSpec (ignored under --debug)")
    ap.add_argument("--mode", default=None,
                    choices=["cascade", "megatron", "megatron_sp"],
                    help="sharding mode override (default: per-arch config)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (pod,data,model) mesh instead of 16x16")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a tiny local mesh (seq=32, batch=4)")
    ap.add_argument("--steps", type=int, default=50,
                    help="training steps to run")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train",
                    help="checkpoint dir (resumes from the newest found)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation factor")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args()

    if args.debug:
        cfg = reduced_config(args.arch)
        mesh = make_debug_mesh(1, 1)
        seq, batch = 32, 4
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]
        seq, batch = shape.seq_len, shape.global_batch
    if args.mode:
        cfg = cfg.with_(sharding_mode=args.mode)

    rules = rules_for_mode(cfg.sharding_mode)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg.optimizer)

    with mesh, sharding_ctx(mesh, rules):
        specs = model.param_specs()
        params = init_params(jax.random.PRNGKey(0), specs)
        params = jax.device_put(params,
                                specs_to_shardings(specs, mesh, rules))
        opt_state = optimizer.init(params)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh {mesh.devices.shape} "
          f"mode={cfg.sharding_mode}")

    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, log_every=5,
        microbatches=args.microbatches, compress_grads=args.compress_grads,
    )
    trainer = Trainer(model.loss, optimizer, tcfg, mesh=mesh, rules=rules)

    def iters(start):
        return make_train_iterator(cfg.vocab, seq, batch, seed=0,
                                   start_step=start)

    _, _, hist = trainer.fit(params, opt_state, iters)
    if hist:
        print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    else:
        print(f"done: checkpoint in {args.ckpt_dir} is already at "
              f">= {args.steps} steps; nothing to do (use a fresh "
              "--ckpt-dir or raise --steps)")


if __name__ == "__main__":
    main()
