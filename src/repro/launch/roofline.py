"""Roofline term derivation from the dry-run's compiled artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s            [s, per chip]
    memory term     = HLO_bytes / HBM_bw                 [s, per chip]
    collective term = collective_bytes / link_bw         [s, per chip]

HLO statistics come from :mod:`repro.launch.hlo_analysis` (the post-SPMD
per-device module, while-loops scaled by trip count). Hardware constants:
TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.device import TPUv5eTarget
from repro.launch.hlo_analysis import Stats

TPU = TPUv5eTarget()


def roofline_terms(
    stats: Stats,
    n_chips: int,
    model_flops_global: float,
    memory_stats: Optional[Dict] = None,
) -> Dict:
    compute_s = stats.flops / TPU.peak_flops_bf16
    memory_s = stats.bytes / TPU.hbm_bw
    collective_s = stats.collective_bytes / TPU.ici_bw_per_link
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_flops_per_chip = model_flops_global / n_chips
    useful_ratio = (model_flops_per_chip / stats.flops) if stats.flops else 0.0
    # achievable MFU if the dominant term is the critical path and compute
    # overlaps underneath it
    mfu = (model_flops_per_chip / TPU.peak_flops_bf16) / step_s if step_s else 0.0
    out = {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "hlo_flops_per_chip": stats.flops,
        "hlo_bytes_per_chip": stats.bytes,
        "collective_bytes_per_chip": stats.collective_bytes,
        "per_collective_bytes": dict(stats.per_collective),
        "collective_op_counts": dict(stats.collective_ops),
        "model_flops_global": model_flops_global,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": useful_ratio,
        "roofline_mfu": mfu,
        "step_time_bound_s": step_s,
    }
    if memory_stats:
        out["memory_analysis"] = memory_stats
    return out


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"


def summarize(record: Dict) -> str:
    r = record["roofline"]
    return (
        f"{record['arch']:<24s} {record['shape']:<12s} "
        f"{record['mesh']:<10s} {record.get('mode','-'):<10s} "
        f"C={fmt_seconds(r['compute_s']):>9s} "
        f"M={fmt_seconds(r['memory_s']):>9s} "
        f"N={fmt_seconds(r['collective_s']):>9s} "
        f"dom={r['dominant']:<10s} "
        f"useful={r['useful_flops_ratio']*100:5.1f}% "
        f"MFU<={r['roofline_mfu']*100:5.1f}%"
    )
