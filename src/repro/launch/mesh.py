"""Mesh construction: debug meshes for tests, production meshes for pods.

Meshes are built by FUNCTIONS (not module-level constants) so importing
this module never touches jax device state — `jax.devices()` locks the
device count on first call, and entry points like the dry-run need to set
``XLA_FLAGS`` first.

Two production shapes (see docs/architecture.md §4):

* single-pod: ``16x16 = 256`` chips, axes ``("data", "model")``;
* multi-pod:  ``2x16x16 = 512`` chips, axes ``("pod", "data", "model")`` —
  the pod axis extends data parallelism across pods and is what the
  multi-pod dry-run proves out.

Nothing in the step functions depends on the pod count: the sharding rule
tables use composite ``("pod", "data")`` entries that degrade gracefully
on the 2-axis mesh, so the same config scales to N pods by growing the
pod axis. ``make_debug_mesh(data, model)`` builds the small test/example
mesh over however many host devices exist (the 8-device integration tests
use a 2x4).
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 ("data", "model") mesh; 2x16x16 ("pod", "data", "model") with
    ``multi_pod``. Raises RuntimeError when fewer devices exist (the
    dry-run forces 512 host devices via XLA_FLAGS before importing jax).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))
