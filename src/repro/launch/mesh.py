"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the pod axis
extends data parallelism across pods and is what the multi-pod dry-run
proves out. Nothing in the step functions depends on the pod count, so the
same config scales to N pods by growing the pod axis.
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))
