"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + 1 shared.

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840.
[arXiv:2501.kimi2; unverified]

Simplifications noted in DESIGN.md: all 61 layers are MoE (the release keeps
layer 0 dense), and GQA replaces MLA per the assignment's config line.
Memory: bf16 params ~2 TB — training fits from 2 pods up with Adafactor
(see EXPERIMENTS.md §Dry-run fit analysis).
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv=8,
    d_ff=2048,
    moe_d_ff=2048,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    vocab=163840,
    head_dim=128,
    rope_theta=50000.0,
    optimizer="adafactor",
)
