"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536. [arXiv:2404.05892; hf]
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # wkv heads = d_model / 64
    n_kv=64,
    d_ff=14336,
    vocab=65536,
    head_dim=64,
    ssm_head_dim=64,
    is_rwkv=True,
    notes="attention-free; long_500k runs with O(1) recurrent state",
)
