"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
100 layers = 20 super-blocks of (4 self-attn + 1 gated cross-attn); the
vision frontend is a stub (input_specs supplies patch embeddings).
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=4096,
    optimizer="adafactor",
    notes="vision frontend stubbed: precomputed patch embeddings",
)
