"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attn blocks.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64.
[arXiv:2411.15242; hf]

54 Mamba2 layers in 9 groups of 6, one weight-shared attention+MLP block
applied after each group (simplified from the release's two alternating
shared blocks; noted in DESIGN.md).
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    notes="long_500k runs: SSM state O(1) + shared-attn KV caches",
)
