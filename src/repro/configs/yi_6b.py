"""yi-6b [dense] — llama-arch GQA. 32L d_model=4096 32H (kv=4) d_ff=11008
vocab=64000. [arXiv:2403.04652; hf]"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64000,
    head_dim=128,
    rope_theta=5000000.0,
)
