"""qwen1.5-4b [dense] — QKV bias. 40L d_model=2560 20H (kv=20) d_ff=6912
vocab=151936. [hf:Qwen/Qwen1.5-0.5B; hf]

The QKV bias exercises the paper's fused-bias kernel path natively.
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv=20,
    d_ff=6912,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
)
