"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal. 24L d_model=1024
16H (kv=16) d_ff=8192 vocab=256206. [arXiv:2308.11596; hf]

Audio frontend stubbed: input_specs supplies precomputed frame embeddings.
Decoder length = seq_len // dec_ratio (frames dominate the sequence budget).
Vocab padded 256206 -> 256256 (multiple of 16) for TP sharding, the standard
Megatron-style embedding pad; padded ids are never emitted as labels.
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,          # decoder depth
    n_enc_layers=24,      # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256256,  # 256206 padded to a multiple of 16 (TP divisibility)
    head_dim=64,
    dec_ratio=4,
    notes="audio frontend stubbed: precomputed frame embeddings",
)
