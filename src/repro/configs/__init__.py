"""Assigned-architecture registry.

``get_config(name)`` returns the full published config;
``reduced_config(name)`` returns a structure-preserving small variant for
CPU smoke tests (same family/topology, tiny dims). Full configs are only
exercised through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.base import ArchConfig

ARCH_IDS: List[str] = [
    "llama_3_2_vision_90b",
    "rwkv6_7b",
    "yi_6b",
    "qwen1_5_4b",
    "mistral_large_123b",
    "qwen1_5_110b",
    "phi3_5_moe_42b",
    "kimi_k2_1t",
    "seamless_m4t_large_v2",
    "zamba2_2_7b",
]

# CLI aliases (assignment spelling -> module name)
ALIASES: Dict[str, str] = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "rwkv6-7b": "rwkv6_7b",
    "yi-6b": "yi_6b",
    "qwen1.5-4b": "qwen1_5_4b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen1.5-110b": "qwen1_5_110b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-2.7b": "zamba2_2_7b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)


def reduced_config(name: str) -> ArchConfig:
    """Tiny structure-preserving config of the same family (CPU smoke)."""
    cfg = get_config(name)
    kw = dict(
        n_layers=4, d_model=64, n_heads=4, n_kv=min(cfg.n_kv, 2) or 2,
        d_ff=128, vocab=256, head_dim=16, remat=False, q_chunk=32,
        ssd_chunk=8,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, moe_d_ff=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family == "vlm":
        kw.update(cross_attn_every=2, n_layers=4, n_image_tokens=8)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, n_layers=2, n_kv=4, dec_ratio=2)
    if cfg.family == "ssm":
        kw.update(ssm_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(attn_every=2, n_layers=4, ssm_state=8, ssm_head_dim=16,
                  n_kv=4, head_dim=16)
    return cfg.with_(**kw)
