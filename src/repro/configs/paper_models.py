"""The paper's own evaluation workloads (Table III / V) as selectable
configs for the AIE4ML compiler pipeline.

    from repro.configs.paper_models import build_paper_model, PAPER_MODELS
    model = build_paper_model("mlp_7layer")   # -> EmittedModel
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import CompileConfig, DenseSpec, build_mlp_graph, compile_graph

# name: (batch_rows, f_in, widths, description)
PAPER_MODELS: Dict[str, Tuple[int, int, tuple, str]] = {
    "token_mlp_s16": (512, 196, (256, 196),
                      "MLP-Mixer S/16 token mixing: [B*C,T]=[512,196]"),
    "channel_mlp_s16": (196, 512, (2048, 512),
                        "MLP-Mixer S/16 channel mixing: [B*T,C]=[196,512]"),
    "token_mlp_l16": (1024, 196, (512, 196),
                      "MLP-Mixer L/16 token mixing: [B*C,T]=[1024,196]"),
    "mlp_2layer": (256, 1024, (1024, 1024), "2-layer MLP, hidden 1024"),
    "mlp_7layer": (1, 512, (512,) * 7,
                   "7-layer MLP, hidden 512 (Table V cross-device workload)"),
}


def build_paper_graph(name: str, batch: Optional[int] = None, seed: int = 1):
    rows, f_in, widths, _ = PAPER_MODELS[name]
    rng = np.random.default_rng(seed)
    layers = [
        DenseSpec(w, activation="relu", bias=rng.standard_normal(w) * 0.05)
        for w in widths
    ]
    return build_mlp_graph(batch=batch or min(rows, 128), f_in=f_in,
                           layers=layers, seed=seed)


def build_paper_model(name: str, batch: Optional[int] = None,
                      config: Optional[CompileConfig] = None, seed: int = 1):
    """Compile one of the paper's workloads through the full pipeline."""
    g = build_paper_graph(name, batch, seed)
    # paper-scale parallelization where the array allows it
    cfg = config or CompileConfig()
    try:
        g64 = build_paper_graph(name, batch, seed)
        for node in g64.compute_nodes():
            node.overrides.update({"f_in_slice": 64, "f_out_slice": 64})
        return compile_graph(g64, cfg)
    except ValueError:
        return compile_graph(g, cfg)
