from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FailureInjector, StragglerMonitor
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [
    "CheckpointManager",
    "FailureInjector",
    "StragglerMonitor",
    "Trainer",
    "TrainerConfig",
]
