"""Training loop: checkpoint/restart, straggler monitoring, microbatch
gradient accumulation with optional int8 error-feedback compression.

``Trainer.fit`` is restart-safe: it resumes from the newest checkpoint (the
data pipeline is a pure function of the step, so the token stream continues
bit-identically), which the fault-tolerance tests exercise by killing and
re-running the loop. ``restore_elastic`` re-shards the checkpoint onto a
different mesh (elastic scaling).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import sharding_ctx
from repro.optim.compression import error_feedback_reduce
from repro.optim.optimizers import Optimizer
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FailureInjector, StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    microbatches: int = 1            # gradient-accumulation factor
    compress_grads: bool = False     # int8 error-feedback at the accum boundary
    lr_warmup: int = 0


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,               # loss(params, batch) -> scalar
        optimizer: Optimizer,
        config: TrainerConfig,
        mesh=None,
        rules=None,
    ):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.cfg = config
        self.mesh = mesh
        self.rules = rules
        self.ckpt = CheckpointManager(config.ckpt_dir, keep=config.keep)
        self.monitor = StragglerMonitor()
        self.injector: Optional[FailureInjector] = None
        self._step_fn = None

    # -- step function ---------------------------------------------------------

    def _build_step(self):
        cfg = self.cfg
        loss_fn = self.loss_fn
        optimizer = self.optimizer

        def accum_grads(params, batch):
            if cfg.microbatches == 1:
                return jax.value_and_grad(loss_fn)(params, batch)

            def split(x):
                return x.reshape((cfg.microbatches,
                                  x.shape[0] // cfg.microbatches) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), micro)
            inv = 1.0 / cfg.microbatches
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)

        def step(params, opt_state, residuals, batch):
            loss, grads = accum_grads(params, batch)
            if cfg.compress_grads:
                flat_g, tdef = jax.tree.flatten(grads)
                flat_r = tdef.flatten_up_to(residuals)
                out = [error_feedback_reduce(g, r) for g, r in
                       zip(flat_g, flat_r)]
                grads = tdef.unflatten([o[0] for o in out])
                residuals = tdef.unflatten([o[1] for o in out])
            updates, opt_state, gnorm = optimizer.update(
                grads, opt_state, params)
            params = jax.tree.map(
                lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                params, updates)
            return params, opt_state, residuals, {
                "loss": loss, "grad_norm": gnorm}

        return jax.jit(step, donate_argnums=(0, 1, 2))

    # -- restore ---------------------------------------------------------------

    def init_residuals(self, params):
        if not self.cfg.compress_grads:
            return jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def restore_latest(self, params, opt_state, residuals):
        """Resume from the newest checkpoint if one exists."""
        step = self.ckpt.latest_step()
        if step is None:
            return 0, params, opt_state, residuals
        state = self.ckpt.restore(
            step, {"params": params, "opt": opt_state, "res": residuals})
        return step, state["params"], state["opt"], state["res"]

    def restore_elastic(self, step: int, template: Any, shardings: Any):
        """Restore a checkpoint onto a DIFFERENT mesh (elastic restart)."""
        return self.ckpt.restore(step, template, shardings=shardings)

    # -- loop --------------------------------------------------------------------

    def fit(
        self,
        params,
        opt_state,
        data_iter_factory: Callable[[int], Iterator[Dict]],
        resume: bool = True,
    ):
        """Runs to cfg.steps. ``data_iter_factory(start_step)`` must return a
        stream positioned at start_step (deterministic resume)."""
        cfg = self.cfg
        residuals = self.init_residuals(params)
        start = 0
        if resume:
            start, params, opt_state, residuals = self.restore_latest(
                params, opt_state, residuals)
        if self._step_fn is None:
            self._step_fn = self._build_step()
        data = data_iter_factory(start)
        history = []
        ctx = (
            sharding_ctx(self.mesh, self.rules)
            if self.mesh is not None else _nullctx()
        )
        with ctx:
            for step in range(start, cfg.steps):
                if self.injector is not None:
                    self.injector.check(step)
                batch = {k: jnp.asarray(v) for k, v in next(data).items()}
                # monotonic interval clock: time.time() is wall-clock and
                # jumps under NTP slew/DST, which spoofed the straggler
                # monitor with negative or huge step durations
                t0 = time.perf_counter()
                params, opt_state, residuals, metrics = self._step_fn(
                    params, opt_state, residuals, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                straggle = self.monitor.observe(step, dt)
                history.append({"step": step, "loss": loss, "dt": dt})
                if step % cfg.log_every == 0 or step == cfg.steps - 1:
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"gnorm {float(metrics['grad_norm']):7.3f} "
                          f"{dt*1e3:7.1f}ms"
                          + (" [straggler]" if straggle else ""))
                if (step + 1) % cfg.ckpt_every == 0 or step == cfg.steps - 1:
                    self.ckpt.save(
                        step + 1,
                        {"params": params, "opt": opt_state, "res": residuals},
                        metadata={"loss": loss},
                    )
        return params, opt_state, history


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
