"""Fault-tolerance utilities: failure injection and straggler detection.

On a real pod these hook into the preemption notice / health-check plane;
here the logic is exercised by unit tests and the fault-injection example
(a training job that is killed mid-run and resumes bit-exactly from the
latest checkpoint).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises InjectedFailure at the configured steps (once each)."""

    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker: flags steps slower than ``threshold`` x the
    moving average. On hardware this would trigger hot-spare swap /
    re-sharding; here it records events for the trainer log and tests."""

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3
    ewma: Optional[float] = None
    events: List[dict] = dataclasses.field(default_factory=list)
    _n: int = 0

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (
            self._n > self.warmup and dt > self.threshold * self.ewma
        )
        if is_straggler:
            # "time" is a wall-clock EVENT TIMESTAMP (log correlation
            # only) — interval math must come in through ``dt``, which
            # the trainer measures with time.perf_counter(): wall-clock
            # deltas jump under NTP slew and once spoofed this monitor
            self.events.append(
                {"step": step, "dt": dt, "ewma": self.ewma, "time": time.time()}
            )
        # stragglers don't poison the average
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler
