"""Sharded, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<k>/  shard_<host>.npz  + manifest.json
Writes land in ``step_<k>.tmp`` and are renamed into place only when
complete (a crash mid-save can never corrupt the latest checkpoint).
``restore(..., shardings=...)`` re-device_puts onto ANY mesh shape, so a
job restarted on a different device count resumes from the same state
(elastic scaling). Retention keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, state: Any, metadata: Optional[Dict] = None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        arrays = {}
        for k, v in flat.items():
            a = np.asarray(v)
            if str(a.dtype) == "bfloat16":  # npz can't hold bf16; restore
                a = a.astype(np.float32)    # casts back via the template
            arrays[k] = a
        np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **arrays)
        treedef = jax.tree_util.tree_structure(state)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(
                {
                    "step": step,
                    "keys": sorted(arrays.keys()),
                    "treedef": str(treedef),
                    "n_hosts": self.n_hosts,
                    "metadata": metadata or {},
                },
                f,
            )
        os.replace(tmp, final) if not os.path.exists(final) else None
        if os.path.exists(tmp):  # final existed: overwrite atomically
            shutil.rmtree(final)
            os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``; optionally re-shard
        onto new device layouts (elastic restart)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, f"shard_{self.host_id}.npz"))
        flat_t = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat_t[0]:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = data[key]
            leaves.append(arr)
        restored = jax.tree_util.tree_unflatten(flat_t[1], leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings
            )
        else:
            restored = jax.tree.map(
                lambda a, t: jax.numpy.asarray(a, dtype=t.dtype)
                if hasattr(t, "dtype") else a,
                restored, template,
            )
        return restored

    def metadata(self, step: int) -> Dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f).get("metadata", {})
