"""Normalization layers (fp32 internal math, bf16 storage)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec


def rmsnorm_spec(d: int, dtype=jnp.bfloat16) -> dict:
    return {"scale": ParamSpec((d,), (None,), dtype, init="ones")}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int, dtype=jnp.bfloat16) -> dict:
    return {
        "scale": ParamSpec((d,), (None,), dtype, init="ones"),
        "bias": ParamSpec((d,), (None,), dtype, init="zeros"),
    }


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def groupnorm_heads(x: jnp.ndarray, scale, bias, eps: float = 64e-5):
    """Per-head group norm over the last dim (RWKV wkv output norm).

    x: [..., H, D]; scale/bias: [H, D].
    """
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)
