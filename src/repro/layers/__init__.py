from repro.layers import attention, linear, mlp, moe, norm, rope, rwkv, ssm  # noqa: F401
