"""Attention: GQA self-attention, cross-attention, and KV-cache decode.

Prefill/training use a query-chunked attention (lax.scan over query blocks
with per-chunk rematerialization) so the score matrix never materializes at
[B,H,S,S] — the flash-attention memory behavior expressed in pure JAX. This
is what the multi-pod dry-run lowers; a Pallas flash kernel can replace the
inner block on real TPUs without changing the call signature.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.linear import linear, linear_spec
from repro.layers.rope import apply_rope, rope_freqs

NEG_INF = -1e30

# TPU deployment switch: route the inner attention block through the Pallas
# flash kernel (kernels/flash_attention). Off by default so the CPU dry-run
# lowers the pure-JAX path; see EXPERIMENTS.md §Perf for the roofline delta.
USE_FLASH_KERNEL = False


def attention_spec(
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    mode: str,
    *,
    qkv_bias: bool = False,
    stack: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> dict:
    return {
        "wq": linear_spec(d_model, n_heads * head_dim, "col", mode,
                          use_bias=qkv_bias, stack=stack, dtype=dtype),
        "wk": linear_spec(d_model, n_kv * head_dim, "kv", mode,
                          use_bias=qkv_bias, stack=stack, dtype=dtype),
        "wv": linear_spec(d_model, n_kv * head_dim, "kv", mode,
                          use_bias=qkv_bias, stack=stack, dtype=dtype),
        "wo": linear_spec(n_heads * head_dim, d_model, "row", mode,
                          stack=stack, dtype=dtype),
    }


def _attend_block(
    q: jnp.ndarray,          # [B, Cq, H, hd]
    k: jnp.ndarray,          # [B, Sk, H, hd]  (kv heads already repeated)
    v: jnp.ndarray,          # [B, Sk, H, hd]
    q_pos0,                  # scalar: global position of q[.,0]
    kv_valid: Optional[jnp.ndarray],  # [B, Sk] / [B, Sq, Sk] bool or None
    causal: bool,
    scale: float,
) -> jnp.ndarray:
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    Sq, Sk = q.shape[1], k.shape[1]
    if causal:
        qi = q_pos0 + jnp.arange(Sq)
        si = jnp.arange(Sk)
        mask = si[None, :] <= qi[:, None]          # [Sq, Sk]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    if kv_valid is not None:
        # [B, Sk] masks every query row alike; [B, Sq, Sk] is the
        # per-query form block-verify decode needs (query j of slot b may
        # see one more cache row than query j-1)
        mask = kv_valid[:, None, None, :] if kv_valid.ndim == 2 \
            else kv_valid[:, None]
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhqs,bshd->bqhd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(v.dtype)


def mha(
    q: jnp.ndarray,          # [B, Sq, H, hd]
    k: jnp.ndarray,          # [B, Sk, KV, hd]
    v: jnp.ndarray,          # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    q_start: int | jnp.ndarray = 0,
    kv_valid: Optional[jnp.ndarray] = None,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Grouped-query attention with query chunking.

    GQA is computed in HEAD-REPEAT form: kv heads are broadcast up to the
    full H so every tensor keeps the q-head dim intact. The obvious
    alternative — reshaping q to [B,S,KV,G,hd] — silently BREAKS head
    sharding under GSPMD when neither KV nor G divides the model axis
    (e.g. 96 heads = 8 kv x 12 groups on TP=16), replicating the whole
    score computation on every model shard. Measured on
    mistral-large x train_4k this inflated per-device attention traffic
    ~16x; see EXPERIMENTS.md §Perf iteration 1.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    if kv_valid is not None and kv_valid.ndim == 3:
        # per-query masks ([B, Sq, Sk]) are a short-block decode feature;
        # the q-chunk scan below would need per-chunk mask slices
        assert Sq <= q_chunk or Sq % q_chunk, (Sq, q_chunk)
    G = H // KV
    scale = hd**-0.5
    if G > 1:
        k = jnp.repeat(k, G, axis=2)               # [B, Sk, H, hd]
        v = jnp.repeat(v, G, axis=2)
    # seq first: a seq-sharded KV cache (flash-decoding layout, megatron_sp)
    # takes precedence over head sharding; fit_pspec drops the duplicate.
    k = shard_act(k, "batch", "seq", "act_heads", None)
    v = shard_act(v, "batch", "seq", "act_heads", None)

    if USE_FLASH_KERNEL and kv_valid is None and Sq == k.shape[1] \
            and hd % 8 == 0:
        from repro.kernels.flash_attention import flash_attention

        qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
        o = flash_attention(qf, kf, vf, causal=causal, q_start=int(q_start)
                            if not hasattr(q_start, "shape") else 0)
        return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)

    if Sq <= q_chunk or Sq % q_chunk:
        return _attend_block(q, k, v, q_start, kv_valid, causal, scale)

    n_chunks = Sq // q_chunk
    qc = q.reshape(B, n_chunks, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def step(_, args):
        qblk, idx = args
        o = _attend_block(
            qblk, k, v, q_start + idx * q_chunk, kv_valid, causal, scale
        )
        return None, o

    _, outs = jax.lax.scan(step, None, (qc, jnp.arange(n_chunks)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def self_attention(
    params: dict,
    x: jnp.ndarray,              # [B, S, d]
    positions: jnp.ndarray,      # [B, S]
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    causal: bool = True,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    B, S, _ = x.shape
    q = linear(params["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, S, n_kv, head_dim)
    v = linear(params["wv"], x).reshape(B, S, n_kv, head_dim)
    inv_freq = rope_freqs(head_dim, rope_theta)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    q = shard_act(q, "batch", "seq", "act_heads", None)
    o = mha(q, k, v, causal=causal, q_chunk=q_chunk)
    o = shard_act(o, "batch", "seq", "act_heads", None)
    return linear(params["wo"], o.reshape(B, S, n_heads * head_dim))


def cross_attention(
    params: dict,
    x: jnp.ndarray,              # [B, Sq, d]
    memory: jnp.ndarray,         # [B, Sm, d_mem] (encoder / vision states)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    memory_valid: Optional[jnp.ndarray] = None,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    B, Sq, _ = x.shape
    Sm = memory.shape[1]
    q = linear(params["wq"], x).reshape(B, Sq, n_heads, head_dim)
    k = linear(params["wk"], memory).reshape(B, Sm, n_kv, head_dim)
    v = linear(params["wv"], memory).reshape(B, Sm, n_kv, head_dim)
    q = shard_act(q, "batch", "seq", "act_heads", None)
    o = mha(q, k, v, causal=False, kv_valid=memory_valid, q_chunk=q_chunk)
    return linear(params["wo"], o.reshape(B, Sq, n_heads * head_dim))


# ---------------------------------------------------------------------------
# KV-cache decode (the paper's GEMV regime: one token, resident state)
# ---------------------------------------------------------------------------


def init_cache_spec(
    batch: int, max_len: int, n_kv: int, head_dim: int, n_layers: int,
    dtype=jnp.bfloat16,
) -> dict:
    axes = ("layers", "batch", "seq", "cache_heads", "cache_hd")
    shape = (n_layers, batch, max_len, n_kv, head_dim)
    return {
        "k": ParamSpec(shape, axes, dtype, init="zeros"),
        "v": ParamSpec(shape, axes, dtype, init="zeros"),
    }


def decode_self_attention(
    params: dict,
    x: jnp.ndarray,              # [B, 1, d] current token hidden
    cache_k: jnp.ndarray,        # [B, S, KV, hd] this layer's cache
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,            # [] int32: index of the new token
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    window_start: Optional[jnp.ndarray] = None,   # [B] int32 or None
):
    """One decode step: project, rotate, append to cache, attend over cache.

    ``window_start`` restricts sequence ``b`` to cache positions
    ``[window_start[b], pos]`` — the continuous-batching contract where a
    reused slot's request began at a nonzero global position and must
    never see its predecessor's KV. RoPE scores depend only on relative
    position, so a request windowed at ``s`` attends exactly as it would
    from position 0. ``None`` keeps the classic full-prefix window.

    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    S = cache_k.shape[1]
    q = linear(params["wq"], x).reshape(B, 1, n_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, 1, n_kv, head_dim)
    v = linear(params["wv"], x).reshape(B, 1, n_kv, head_dim)
    posb = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    inv_freq = rope_freqs(head_dim, rope_theta)
    q = apply_rope(q, posb, inv_freq)
    k = apply_rope(k, posb, inv_freq)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    kv_valid = (jnp.arange(S)[None, :] <= pos).astype(bool)
    kv_valid = jnp.broadcast_to(kv_valid, (B, S))
    if window_start is not None:
        kv_valid = kv_valid & (
            jnp.arange(S)[None, :] >= window_start[:, None])
    o = mha(q, cache_k, cache_v, causal=False, kv_valid=kv_valid)
    out = linear(params["wo"], o.reshape(B, 1, n_heads * head_dim))
    return out, cache_k, cache_v


def paged_decode_self_attention(
    params: dict,
    x: jnp.ndarray,              # [B, 1, d] current token hidden
    cache_k: jnp.ndarray,        # [P, ps, KV, hd] this layer's page pool
    cache_v: jnp.ndarray,
    pages,                       # models.base.PageView (table, local_pos, ps)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 10000.0,
):
    """One decode step against the paged KV layout.

    Each slot ``b`` lives in its OWN coordinate system: ``local_pos[b]``
    is its position within its own sequence, page ``j`` of its table
    holds local positions ``[j*ps, (j+1)*ps)``, and RoPE rotates by the
    LOCAL position. That makes a page's contents a pure function of the
    token prefix it encodes — the property the prefix cache relies on to
    map one physical page read-only into many slots (see
    ``docs/memory_model.md``). The dense path instead indexes at global
    position with a ``window_start`` validity floor; both produce the
    same scores because RoPE attention depends only on relative offsets.

    Writes scatter the new K/V row to ``(table[b, local//ps],
    local % ps)``; empty or self-masked lanes carry per-lane scratch
    pages in their tables, so an inactive lane's write lands on a page
    nothing reads. Reads gather the slot's whole table back into
    ``[B, S, KV, hd]`` and mask to ``local_index <= local_pos[b]``.

    Returns (out [B,1,d], new_pool_k, new_pool_v).
    """
    B = x.shape[0]
    ps = pages.page_size
    n_pages = pages.table.shape[1]
    S = n_pages * ps
    q = linear(params["wq"], x).reshape(B, 1, n_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, 1, n_kv, head_dim)
    v = linear(params["wv"], x).reshape(B, 1, n_kv, head_dim)
    local = jnp.clip(pages.local_pos.astype(jnp.int32), 0, S - 1)
    inv_freq = rope_freqs(head_dim, rope_theta)
    q = apply_rope(q, local[:, None], inv_freq)
    k = apply_rope(k, local[:, None], inv_freq)
    page_ids = jnp.take_along_axis(
        pages.table, (local // ps)[:, None], axis=1)[:, 0]
    offs = local % ps
    cache_k = cache_k.at[page_ids, offs].set(k[:, 0])
    cache_v = cache_v.at[page_ids, offs].set(v[:, 0])
    k_all = cache_k[pages.table].reshape(B, S, n_kv, head_dim)
    v_all = cache_v[pages.table].reshape(B, S, n_kv, head_dim)
    kv_valid = jnp.arange(S)[None, :] <= local[:, None]
    o = mha(q, k_all, v_all, causal=False, kv_valid=kv_valid)
    out = linear(params["wo"], o.reshape(B, 1, n_heads * head_dim))
    return out, cache_k, cache_v


def block_decode_self_attention(
    params: dict,
    x: jnp.ndarray,              # [B, m, d] block of token hiddens
    cache_k: jnp.ndarray,        # [B, S, KV, hd] this layer's cache
    cache_v: jnp.ndarray,
    local: jnp.ndarray,          # [B] int32: LOCAL position of x[:, 0]
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 10000.0,
):
    """Decode a block of ``m`` consecutive tokens per slot in ONE pass.

    The dense sibling of the paged path's local-coordinate contract:
    slot ``b``'s token ``j`` lives at cache row ``local[b] + j`` of its
    OWN lane — RoPE rotates by that local index and the per-query
    validity mask admits rows ``<= local[b] + j``, so every row a query
    can see was written by this request's own (teacher-forced or
    accepted) tokens. That is what makes host-side rewind free for
    speculative decoding: rejecting a drafted suffix is just a bump of
    the slot's start cursor — the garbage rows it leaves behind sit at
    locals at-or-above the rewound cursor, where the next block's write
    front overwrites them before any mask ever admits them. The
    global-coordinate dense path cannot do this (its contiguous
    ``[window_start, pos]`` window has no way to mask a rejected hole).

    ``m == 1`` is the draft scan's single-token step; ``m == k`` the
    target's verify pass over a whole micro-run.

    Returns (out [B,m,d], new_cache_k, new_cache_v).
    """
    B, m, _ = x.shape
    S = cache_k.shape[1]
    q = linear(params["wq"], x).reshape(B, m, n_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, m, n_kv, head_dim)
    v = linear(params["wv"], x).reshape(B, m, n_kv, head_dim)
    posb = local[:, None].astype(jnp.int32) + jnp.arange(m, dtype=jnp.int32)
    inv_freq = rope_freqs(head_dim, rope_theta)
    q = apply_rope(q, posb, inv_freq)
    k = apply_rope(k, posb, inv_freq)
    rows = jnp.arange(B)[:, None]
    cache_k = cache_k.at[rows, posb].set(k)
    cache_v = cache_v.at[rows, posb].set(v)
    # query j of slot b sees exactly rows [0, local[b] + j]
    kv_valid = jnp.arange(S)[None, None, :] <= posb[:, :, None]
    o = mha(q, cache_k, cache_v, causal=False, kv_valid=kv_valid)
    out = linear(params["wo"], o.reshape(B, m, n_heads * head_dim))
    return out, cache_k, cache_v


def paged_block_decode_self_attention(
    params: dict,
    x: jnp.ndarray,              # [B, m, d] block of token hiddens
    cache_k: jnp.ndarray,        # [P, ps, KV, hd] this layer's page pool
    cache_v: jnp.ndarray,
    pages,                       # models.base.PageView; local_pos = x[:,0]'s
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float = 10000.0,
):
    """Block decode of ``m`` consecutive tokens against the page pool.

    The paged twin of :func:`block_decode_self_attention`: slot ``b``'s
    token ``j`` lives at local position ``local_pos[b] + j`` of its OWN
    page run — RoPE rotates by the UNCLAMPED local position and the
    per-query validity mask admits local rows ``<= local_pos[b] + j``,
    exactly as the dense block path does, so the two produce the same
    floats. Clamping is applied to INDEXING only, and only on the gather
    side: the dense path's out-of-range writes are dropped by the
    scatter's OOB semantics, so here an out-of-range local is routed to
    page id ``P`` (one past the pool) and dropped the same way — a clamp
    would instead alias it onto a real row and corrupt it.

    Speculative rewind works like the dense block path, per page run:
    rejected draft rows sit at-or-above the rewound cursor, where the
    next micro-run's write front (into fresh draft pages, or back into
    the kept partial page) overwrites them before any mask admits them.

    Returns (out [B,m,d], new_pool_k, new_pool_v).
    """
    B, m, _ = x.shape
    ps = pages.page_size
    n_pages = pages.table.shape[1]
    S = n_pages * ps
    q = linear(params["wq"], x).reshape(B, m, n_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, m, n_kv, head_dim)
    v = linear(params["wv"], x).reshape(B, m, n_kv, head_dim)
    posb = (pages.local_pos.astype(jnp.int32)[:, None]
            + jnp.arange(m, dtype=jnp.int32))
    inv_freq = rope_freqs(head_dim, rope_theta)
    q = apply_rope(q, posb, inv_freq)
    k = apply_rope(k, posb, inv_freq)
    in_range = (posb >= 0) & (posb < S)
    posc = jnp.where(in_range, posb, 0)
    page_ids = jnp.take_along_axis(pages.table, posc // ps, axis=1)
    page_ids = jnp.where(in_range, page_ids, cache_k.shape[0])
    offs = posc % ps
    cache_k = cache_k.at[page_ids, offs].set(k)
    cache_v = cache_v.at[page_ids, offs].set(v)
    k_all = cache_k[pages.table].reshape(B, S, n_kv, head_dim)
    v_all = cache_v[pages.table].reshape(B, S, n_kv, head_dim)
    # query j of slot b sees exactly local rows [0, local_pos[b] + j]
    kv_valid = jnp.arange(S)[None, None, :] <= posb[:, :, None]
    o = mha(q, k_all, v_all, causal=False, kv_valid=kv_valid)
    out = linear(params["wo"], o.reshape(B, m, n_heads * head_dim))
    return out, cache_k, cache_v
