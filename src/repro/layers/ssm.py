"""Mamba2 selective state-space layer (SSD chunked algorithm).

Training/prefill use the chunked SSD formulation (Dao & Gu 2024, "minimal
SSD"): the sequence splits into chunks; within-chunk interactions are a
masked-decay matmul (MXU-friendly), and cross-chunk state flows through a
short lax.scan over chunk states — O(L) work, all in matmuls, no O(L)
sequential scan. Decode is the O(1) recurrent state update, which is the
paper's GEMV regime (state resident, one token in).

Simplifications vs the reference CUDA implementation (documented in
DESIGN.md): ngroups=1 (B/C shared across heads), causal conv applied to the
x-branch only.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.linear import linear_spec, linear
from repro.layers.norm import rmsnorm


def mamba2_spec(
    d_model: int,
    *,
    expand: int = 2,
    head_dim: int = 64,
    d_state: int = 64,
    d_conv: int = 4,
    mode: str = "megatron",
    stack: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim

    def _p(shape, axes, init="normal", scale=None):
        if stack is not None:
            shape = (stack,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, dtype, init=init, scale=scale)

    return {
        "wz": linear_spec(d_model, d_inner, "col", mode, stack=stack, dtype=dtype),
        "wx": linear_spec(d_model, d_inner, "col", mode, stack=stack, dtype=dtype),
        "wBC": linear_spec(d_model, 2 * d_state, "replicated", mode,
                           stack=stack, dtype=dtype),
        "wdt": linear_spec(d_model, n_heads, "replicated", mode,
                           stack=stack, dtype=dtype),
        "conv_w": _p((d_conv, d_inner), ("conv_k", "mlp")),
        "conv_b": _p((d_inner,), ("mlp",), init="zeros"),
        "dt_bias": _p((n_heads,), (None,), init="zeros"),
        "A_log": _p((n_heads,), (None,), init="zeros"),
        "D": _p((n_heads,), (None,), init="ones"),
        "norm_scale": _p((d_inner,), ("mlp",), init="ones"),
        "out": linear_spec(d_inner, d_model, "row", mode, stack=stack, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over seq. x [B,L,D], w [K,D]. If ``state``
    ([B,K-1,D], trailing context) is given, returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)          # [B, L+K-1, D]
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    y = y + b[None, None, :]
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """[..., L] -> [..., L, L] lower-triangular segment sums
    (out[i,j] = sum a[j+1..i], -inf above diagonal)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,     # [B, L, H, P] (dt already folded in)
    dA: jnp.ndarray,    # [B, L, H]   per-step log decay (dt * A, negative)
    Bmat: jnp.ndarray,  # [B, L, N]
    Cmat: jnp.ndarray,  # [B, L, N]
    chunk: int = 128,
    init_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD: y[t] = C_t . h_t, h_t = exp(dA_t) h_{t-1} + B_t x_t."""
    B, L, H, P = x.shape
    N = Bmat.shape[-1]
    if L % chunk:
        chunk = L  # degenerate small-seq case
    nC = L // chunk
    xc = x.reshape(B, nC, chunk, H, P).astype(jnp.float32)
    dAc = dA.reshape(B, nC, chunk, H).transpose(0, 3, 1, 2)  # [B,H,C,Lc]
    dAc = dAc.astype(jnp.float32)
    Bc = Bmat.reshape(B, nC, chunk, N).astype(jnp.float32)
    Cc = Cmat.reshape(B, nC, chunk, N).astype(jnp.float32)

    Acs = jnp.cumsum(dAc, axis=-1)                            # [B,H,C,Lc]
    Lmat = jnp.exp(_segsum(dAc))                              # [B,H,C,Lc,Lc]

    # intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, Lmat, xc)

    # per-chunk final states ('bchpn' order: [B, C, H, P, N])
    decay_states = jnp.exp(Acs[..., -1:] - Acs)               # [B,H,C,Lc]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # inter-chunk recurrence (scan over chunk index)
    chunk_decay = jnp.exp(Acs[..., -1])                       # [B,H,C]
    if init_state is None:
        s0 = jnp.zeros((B, H, P, N), jnp.float32)
    else:
        s0 = init_state.astype(jnp.float32)

    def step(s, inp):
        st, dec = inp                                         # [B,H,P,N],[B,H]
        prev = s
        s = prev * dec[..., None, None] + st
        return s, prev

    states_t = states.transpose(1, 0, 2, 3, 4)                # [C,B,H,P,N]
    decay_t = chunk_decay.transpose(2, 0, 1)                  # [C,B,H]
    final, prev_states = jax.lax.scan(step, s0, (states_t, decay_t))
    prev_states = prev_states.transpose(1, 2, 0, 3, 4)        # [B,H,C,P,N]

    # inter-chunk contribution
    y_off = jnp.einsum(
        "bcln,bhcpn,bhcl->bclhp", Cc, prev_states, jnp.exp(Acs),
    )
    y = (y_diag + y_off).reshape(B, L, H, P)
    return y, final


def mamba2(
    params: dict,
    x: jnp.ndarray,             # [B, L, d_model]
    *,
    head_dim: int = 64,
    d_state: int = 64,
    chunk: int = 128,
) -> jnp.ndarray:
    """Full Mamba2 block (training / prefill path)."""
    B, L, D = x.shape
    z = linear(params["wz"], x)                       # [B,L,d_inner]
    xi = linear(params["wx"], x)
    d_inner = xi.shape[-1]
    H = d_inner // head_dim
    xi, _ = _causal_conv(xi, params["conv_w"], params["conv_b"])
    xi = shard_act(xi, "batch", "seq", "act_mlp")
    BC = linear(params["wBC"], x).astype(jnp.float32)
    Bmat, Cmat = jnp.split(BC, 2, axis=-1)
    dt = jax.nn.softplus(
        linear(params["wdt"], x).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )                                                  # [B,L,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H]
    xh = xi.reshape(B, L, H, head_dim)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    dA = dt * A[None, None, :]
    y, _ = ssd_chunked(xdt, dA, Bmat, Cmat, chunk=chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(
        jnp.float32
    )
    y = y.reshape(B, L, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y)
    return linear(params["out"], y)


def mamba2_state_spec(batch: int, n_layers: int, d_inner: int,
                      head_dim: int, d_state: int, d_conv: int = 4,
                      dtype=jnp.float32) -> dict:
    H = d_inner // head_dim
    return {
        "ssm": ParamSpec((n_layers, batch, H, head_dim, d_state),
                         ("layers", "batch", "mlp", None, None),
                         dtype, init="zeros"),
        "conv": ParamSpec((n_layers, batch, d_conv - 1, d_inner),
                          ("layers", "batch", None, "act_mlp"),
                          dtype, init="zeros"),
    }


def mamba2_decode(
    params: dict,
    x: jnp.ndarray,             # [B, 1, d_model]
    ssm_state: jnp.ndarray,     # [B, H, P, N] fp32
    conv_state: jnp.ndarray,    # [B, K-1, d_inner]
    *,
    head_dim: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One recurrent decode step. Returns (y, new_ssm_state, new_conv_state)."""
    B = x.shape[0]
    z = linear(params["wz"], x)
    xi = linear(params["wx"], x)
    d_inner = xi.shape[-1]
    H = d_inner // head_dim
    xi, conv_state = _causal_conv(
        xi, params["conv_w"], params["conv_b"], state=conv_state.astype(x.dtype)
    )
    BC = linear(params["wBC"], x).astype(jnp.float32)
    Bmat, Cmat = jnp.split(BC, 2, axis=-1)            # [B,1,N]
    dt = jax.nn.softplus(
        linear(params["wdt"], x).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )[:, 0]                                            # [B,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, H, head_dim).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                      # [B,H]
    new_state = (
        ssm_state.astype(jnp.float32) * dA[..., None, None]
        + jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bmat[:, 0])
    )
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cmat[:, 0])
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y)
    return linear(params["out"], y), new_state, conv_state.astype(jnp.float32)
