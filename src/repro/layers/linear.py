"""Dense layers with cascade / megatron sharding roles.

The paper's layer-parallelism decomposition becomes the choice of logical
axes on the weight:

  * ``cascade`` mode (paper-faithful): every weight's *contraction* dim maps
    to the model axis ("cascade_in" -> model) — the west->east cascade
    reduction becomes a psum per linear. The non-contracted dim carries FSDP
    ("cascade_out" -> data).
  * ``megatron`` mode: role "col" shards the output dim on model, role "row"
    shards the input dim — one psum per col+row pair.

An optional int8-quantized path routes through the Pallas qmatmul kernel
(TPU deployment; the pure-JAX path is what the CPU dry-run lowers).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.dist.sharding import ParamSpec


def linear_axes(role: str, mode: str):
    """Logical axes of a (d_in, d_out) weight for a sharding mode."""
    if mode == "cascade":
        return ("cascade_in", "cascade_out")
    table = {
        "col": ("fsdp", "col_out"),
        "row": ("row_in", "fsdp"),
        "replicated": (None, None),
        "kv": ("fsdp", None),  # GQA kv projection: kv_heads < TP, replicate
    }
    return table[role]


def bias_axes(role: str, mode: str):
    if mode == "cascade":
        return ("cascade_out",)
    return {
        "col": ("col_out",),
        "row": (None,),
        "replicated": (None,),
        "kv": (None,),
    }[role]


def linear_spec(
    d_in: int,
    d_out: int,
    role: str,
    mode: str,
    *,
    use_bias: bool = False,
    dtype=jnp.bfloat16,
    stack: Optional[int] = None,
    scale: Optional[float] = None,
) -> dict:
    """ParamSpec dict for one linear; ``stack`` prepends a scan-layer dim."""
    w_axes = linear_axes(role, mode)
    w_shape = (d_in, d_out)
    if stack is not None:
        w_shape = (stack,) + w_shape
        w_axes = ("layers",) + w_axes
    out = {"w": ParamSpec(w_shape, w_axes, dtype, init="normal", scale=scale)}
    if use_bias:
        b_axes = bias_axes(role, mode)
        b_shape = (d_out,)
        if stack is not None:
            b_shape = (stack,) + b_shape
            b_axes = ("layers",) + b_axes
        out["b"] = ParamSpec(b_shape, b_axes, dtype, init="zeros")
    return out


def linear(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """y = x @ w (+ b). bf16 inputs, fp32 accumulation, bf16 out."""
    y = jnp.einsum(
        "...d,df->...f", x, params["w"],
        preferred_element_type=jnp.float32,
    )
    if "b" in params:
        y = y + params["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def quantized_linear(
    params: dict,
    x: jnp.ndarray,
    *,
    x_shift: int = 7,
    w_shift: int = 7,
    out_shift: int = 7,
    relu: bool = False,
    x_dtype: str = "int8",
    out_dtype: str = "int8",
    out_float_dtype=None,
):
    """Paper-faithful integer path: quantize, run the fused Pallas kernel,
    dequantize. Used by the serving configs on TPU (interpret-mode on CPU).

    ``x_dtype`` picks the activation operand width ("int8"/"int16" — the
    kernel's native a16w8 tiling keeps sub-1e-3 activation resolution for
    the quantized MLP path); ``out_dtype`` picks the SRS output width
    ("int8"/"int16" — int16 keeps logit-grade resolution for the serve LM
    head); ``out_float_dtype`` overrides the dequantized dtype (default:
    x.dtype). Dequantization happens in fp32 before the final cast so an
    int16 result is not truncated through bf16's 8-bit mantissa.
    """
    from repro.kernels.qmatmul.ops import qlinear  # lazy: pallas import
    from repro.quant.srs import INT_RANGE

    lo_x, hi_x = INT_RANGE[x_dtype]
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * (2.0**x_shift)),
                  lo_x, hi_x)
    xq = xq.astype(jnp.int16 if x_dtype == "int16" else jnp.int8)
    lo, hi = INT_RANGE["int8"]
    wq = jnp.clip(
        jnp.round(params["w"].astype(jnp.float32) * (2.0**w_shift)), lo, hi
    ).astype(jnp.int8)
    bq = None
    if "b" in params:
        bq = jnp.round(
            params["b"].astype(jnp.float32) * (2.0 ** (x_shift + w_shift))
        ).astype(jnp.int32)
    lead = xq.shape[:-1]
    y = qlinear(
        xq.reshape(-1, xq.shape[-1]), wq, bq,
        shift=x_shift + w_shift - out_shift, relu=relu, out_dtype=out_dtype,
    )
    y = y.reshape(*lead, y.shape[-1])
    y = y.astype(jnp.float32) * (2.0**-out_shift)
    return y.astype(out_float_dtype or x.dtype)
