"""Token embedding and (vocab-sharded) LM head."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act


def embedding_spec(vocab: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    return {
        "table": ParamSpec((vocab, d_model), ("vocab", "embed"), dtype,
                           init="embed"),
    }


def embed(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(params["table"], tokens, axis=0)
    return shard_act(x, "batch", "seq", "act_embed")


def lm_head_spec(d_model: int, vocab: int, dtype=jnp.bfloat16) -> dict:
    return {
        "w": ParamSpec((d_model, vocab), ("embed", "vocab"), dtype,
                       init="normal"),
    }


def lm_head(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("...d,dv->...v", x, params["w"],
                        preferred_element_type=jnp.float32)
    return shard_act(logits, "batch", "seq", "vocab")
