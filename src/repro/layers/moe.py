"""Mixture-of-Experts with sort-based dispatch and expert parallelism.

Experts shard over the model axis (EP) with FSDP on the weight dims; token
dispatch uses the sort + capacity formulation (argsort by expert id, fixed
per-expert capacity, overflow dropped) so the dispatch tensors stay
O(E * C * d) instead of the one-hot O(T * E * C). Expert activations carry
explicit sharding constraints P(experts=model, capacity=data) so the
partitioner materializes the token redistribution as an a2a-style reshard
between the data and model axes — the memory-tile "re-tiling between layers"
role at pod scale.

Routing math follows Mixtral/Phi-3.5: softmax router, top-k, renormalized
gates, plus the switch-style load-balance auxiliary loss.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act


def moe_spec(
    d_model: int,
    d_ff: int,
    n_experts: int,
    mode: str,
    *,
    gated: bool = True,
    stack: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> dict:
    def _w(shape, axes):
        if stack is not None:
            shape = (stack,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, dtype, init="normal",
                         scale=1.0 / (shape[-2] ** 0.5))

    spec = {
        "router": _w((d_model, n_experts), (None, None)),
        "w_up": _w((n_experts, d_model, d_ff), ("experts", "fsdp", None)),
        "w_down": _w((n_experts, d_ff, d_model), ("experts", "fsdp", None)),
    }
    if gated:
        spec["w_gate"] = _w((n_experts, d_model, d_ff),
                            ("experts", "fsdp", None))
    return spec


def _capacity(n_tokens: int, top_k: int, n_experts: int,
              capacity_factor: float) -> int:
    c = int(-(-n_tokens * top_k * capacity_factor // n_experts))
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8 lanes


def _expert_ffn(params, xe, gate, x_dtype, gated, act):
    """Batched expert FFN over [..., E, C, d] dispatch buffers.

    Dots are written as bf16 x bf16 with fp32 accumulation via an explicit
    operand convert (XLA fuses the convert into the MXU dot on TPU; the CPU
    eager path needs it spelled out).
    """
    def dot(a, w, eq):
        return jnp.einsum(eq, a, w.astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    xef = xe.astype(jnp.float32)
    if gated:
        g = dot(xef, params["w_gate"], "...ecd,edf->...ecf")
        u = dot(xef, params["w_up"], "...ecd,edf->...ecf")
        h = (jax.nn.silu(g) * u).astype(x_dtype)
    else:
        h = dot(xef, params["w_up"], "...ecd,edf->...ecf")
        h = (jax.nn.gelu(h) if act == "gelu" else jax.nn.relu(h)).astype(
            x_dtype)
    ye = dot(h.astype(jnp.float32), params["w_down"], "...ecf,efd->...ecd")
    return ye * gate[..., None]


def moe_grouped(
    params: dict,
    x: jnp.ndarray,                  # [B, S, d]
    *,
    n_experts: int,
    top_k: int,
    n_groups: int,
    capacity_factor: float = 1.25,
    gated: bool = True,
    act: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group-limited dispatch (beyond-paper optimization, §Perf iter 3).

    Tokens split into ``n_groups`` groups aligned with the data axis; each
    group routes, sorts, gathers and combines LOCALLY (per-group capacity),
    so the only cross-device traffic is the expert-weight FSDP gather and
    one psum of the combined outputs over the model axis — the global-sort
    formulation's all-gather of every token vanishes. Same routing math as
    GShard/Switch groups.
    """
    B, S, d = x.shape
    T = B * S
    assert T % n_groups == 0
    Tg = T // n_groups
    xf = x.reshape(n_groups, Tg, d)
    xf = shard_act(xf, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [G,Tg,E]
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)        # [G,Tg,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_ids, n_experts,
                                 dtype=jnp.float32), axis=(0, 1, 2))
    aux = n_experts * jnp.sum(me * ce)

    C = _capacity(Tg, top_k, n_experts, capacity_factor)
    flat_e = expert_ids.reshape(n_groups, Tg * top_k)
    order = jnp.argsort(flat_e, axis=-1)                       # [G,Tk]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(n_experts)))(sorted_e)
    slots = starts[:, :, None] + jnp.arange(C)[None, None, :]  # [G,E,C]
    in_range = slots < Tg * top_k
    slots_c = jnp.minimum(slots, Tg * top_k - 1)
    e_at = jnp.take_along_axis(
        sorted_e, slots_c.reshape(n_groups, -1), axis=-1
    ).reshape(n_groups, n_experts, C)
    valid = in_range & (e_at == jnp.arange(n_experts)[None, :, None])
    pair = jnp.take_along_axis(
        order, slots_c.reshape(n_groups, -1), axis=-1
    ).reshape(n_groups, n_experts, C)
    tok = pair // top_k                                        # [G,E,C]
    kk = pair % top_k
    gate = jnp.where(
        valid,
        jnp.take_along_axis(
            gate_vals.reshape(n_groups, -1),
            (tok * top_k + kk).reshape(n_groups, -1), axis=-1
        ).reshape(n_groups, n_experts, C),
        0.0,
    )

    xe = jnp.take_along_axis(
        xf, tok.reshape(n_groups, -1)[..., None], axis=1
    ).reshape(n_groups, n_experts, C, d)
    xe = jnp.where(valid[..., None], xe, 0)
    xe = shard_act(xe, "batch", "experts", None, None)

    ye = _expert_ffn(params, xe, gate, x.dtype, gated, act)    # [G,E,C,d]

    def combine(ye_g, tok_g):
        return jnp.zeros((Tg, d), jnp.float32).at[
            tok_g.reshape(-1)].add(ye_g.reshape(-1, d))

    y = jax.vmap(combine)(ye, tok)                             # [G,Tg,d]
    y = y.reshape(B, S, d).astype(x.dtype)
    y = shard_act(y, "batch", "seq", "act_embed")
    return y, aux


def moe(
    params: dict,
    x: jnp.ndarray,                  # [B, S, d]
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    gated: bool = True,
    act: str = "silu",
    n_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B,S,d], aux load-balance loss scalar)."""
    if n_groups > 1 and (x.shape[0] * x.shape[1]) % n_groups == 0 \
            and (x.shape[0] * x.shape[1]) // n_groups >= top_k:
        return moe_grouped(
            params, x, n_experts=n_experts, top_k=top_k, n_groups=n_groups,
            capacity_factor=capacity_factor, gated=gated, act=act)
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    # ---- routing (fp32) ----
    logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32),
        params["router"].astype(jnp.float32),
    )
    probs = jax.nn.softmax(logits, axis=-1)                   # [T, E]
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)       # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # switch-style aux loss (fraction of tokens vs fraction of prob mass)
    me = jnp.mean(probs, axis=0)                              # [E]
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    aux = n_experts * jnp.sum(me * ce)

    # ---- sort-based dispatch with capacity ----
    C = _capacity(T, top_k, n_experts, capacity_factor)
    flat_e = expert_ids.reshape(-1)                           # [T*k]
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts))  # [E]
    slots = starts[:, None] + jnp.arange(C)[None, :]          # [E, C]
    in_range = slots < T * top_k
    slots_c = jnp.minimum(slots, T * top_k - 1)
    valid = in_range & (sorted_e[slots_c] == jnp.arange(n_experts)[:, None])
    pair = order[slots_c]                                     # [E, C]
    tok = pair // top_k
    kk = pair % top_k
    gate = jnp.where(valid, gate_vals[tok, kk], 0.0)          # [E, C] fp32

    xe = jnp.take(xf, tok.reshape(-1), axis=0).reshape(n_experts, C, d)
    xe = jnp.where(valid[..., None], xe, 0)
    # EP redistribution point: experts on the model axis, capacity on data
    xe = shard_act(xe, "experts", "expert_cap", None)

    # ---- expert FFN (batched over the expert dim) ----
    if gated:
        g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
    else:
        h = jnp.einsum("ecd,edf->ecf", xe, params["w_up"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.gelu(h) if act == "gelu" else jax.nn.relu(h)).astype(x.dtype)
    h = shard_act(h, "experts", "expert_cap", None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                    preferred_element_type=jnp.float32)       # [E, C, d] fp32
    ye = ye * gate[..., None]

    # ---- combine (scatter-add back to token order) ----
    y = jnp.zeros((T, d), jnp.float32)
    y = y.at[tok.reshape(-1)].add(ye.reshape(n_experts * C, d))
    y = y.reshape(B, S, d).astype(x.dtype)
    y = shard_act(y, "batch", "seq", "act_embed")
    return y, aux
