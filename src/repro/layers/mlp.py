"""Feed-forward blocks: SwiGLU (llama family) and classic GELU/ReLU MLP."""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act
from repro.layers.linear import linear, linear_spec, quantized_linear

_CAL = threading.local()


@contextmanager
def swiglu_calibration(record: Dict[str, float]):
    """Observe down-projection ranges for quantization calibration.

    While active, every *eager* float ``swiglu`` call folds the absmax of
    its down-projection input ("act") and output ("out") into ``record``.
    Tracing is unaffected (tracer values are skipped), so the scope costs
    nothing outside the plan's calibration decode.
    """
    prev = getattr(_CAL, "record", None)
    _CAL.record = record
    try:
        yield record
    finally:
        _CAL.record = prev


def _observe(record: Dict[str, float], key: str, x: jnp.ndarray) -> None:
    if isinstance(x, jax.core.Tracer):
        return
    record[key] = max(record.get(key, 0.0), float(jnp.abs(x).max()))


def swiglu_spec(d_model: int, d_ff: int, mode: str, *, stack=None,
                dtype=jnp.bfloat16) -> dict:
    return {
        "gate": linear_spec(d_model, d_ff, "col", mode, stack=stack, dtype=dtype),
        "up": linear_spec(d_model, d_ff, "col", mode, stack=stack, dtype=dtype),
        "down": linear_spec(d_ff, d_model, "row", mode, stack=stack, dtype=dtype),
    }


def swiglu(params: dict, x: jnp.ndarray,
           quant: Optional[Tuple[int, int, int]] = None) -> jnp.ndarray:
    """SwiGLU FFN. ``quant=(x_shift, w_shift, out_shift)`` routes the
    down-projection — the GEMV that dominates a decode-time FFN — through
    the Pallas int8 qmatmul with an int16 SRS output, mirroring the decode
    LM head's quantized path (the gate/up projections stay bf16: their
    silu product is exactly the activation the shifts are calibrated for).
    """
    g = linear(params["gate"], x)
    u = linear(params["up"], x)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_act(h, "batch", "seq", "act_mlp")
    if quant is not None:
        x_shift, w_shift, out_shift = quant
        # a16w8: the kernel's native int16-activation tiling — activation
        # resolution stays below the bf16 mantissa step at these shifts
        return quantized_linear(
            params["down"], h,
            x_shift=x_shift, w_shift=w_shift, out_shift=out_shift,
            x_dtype="int16", out_dtype="int16",
        )
    y = linear(params["down"], h)
    record = getattr(_CAL, "record", None)
    if record is not None:
        _observe(record, "act", h)
        _observe(record, "out", y)
    return y


def mlp_spec(d_model: int, d_ff: int, mode: str, *, stack=None,
             use_bias: bool = True, dtype=jnp.bfloat16) -> dict:
    return {
        "up": linear_spec(d_model, d_ff, "col", mode, stack=stack,
                          use_bias=use_bias, dtype=dtype),
        "down": linear_spec(d_ff, d_model, "row", mode, stack=stack,
                            use_bias=use_bias, dtype=dtype),
    }


def mlp(params: dict, x: jnp.ndarray, act: str = "gelu") -> jnp.ndarray:
    h = linear(params["up"], x)
    hf = h.astype(jnp.float32)
    hf = jax.nn.gelu(hf) if act == "gelu" else jax.nn.relu(hf)
    h = shard_act(hf.astype(x.dtype), "batch", "seq", "act_mlp")
    return linear(params["down"], h)
