"""Feed-forward blocks: SwiGLU (llama family) and classic GELU/ReLU MLP."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act
from repro.layers.linear import linear, linear_spec


def swiglu_spec(d_model: int, d_ff: int, mode: str, *, stack=None,
                dtype=jnp.bfloat16) -> dict:
    return {
        "gate": linear_spec(d_model, d_ff, "col", mode, stack=stack, dtype=dtype),
        "up": linear_spec(d_model, d_ff, "col", mode, stack=stack, dtype=dtype),
        "down": linear_spec(d_ff, d_model, "row", mode, stack=stack, dtype=dtype),
    }


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = linear(params["gate"], x)
    u = linear(params["up"], x)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_act(h, "batch", "seq", "act_mlp")
    return linear(params["down"], h)


def mlp_spec(d_model: int, d_ff: int, mode: str, *, stack=None,
             use_bias: bool = True, dtype=jnp.bfloat16) -> dict:
    return {
        "up": linear_spec(d_model, d_ff, "col", mode, stack=stack,
                          use_bias=use_bias, dtype=dtype),
        "down": linear_spec(d_ff, d_model, "row", mode, stack=stack,
                            use_bias=use_bias, dtype=dtype),
    }


def mlp(params: dict, x: jnp.ndarray, act: str = "gelu") -> jnp.ndarray:
    h = linear(params["up"], x)
    hf = h.astype(jnp.float32)
    hf = jax.nn.gelu(hf) if act == "gelu" else jax.nn.relu(hf)
    h = shard_act(hf.astype(x.dtype), "batch", "seq", "act_mlp")
    return linear(params["down"], h)
