"""RWKV6 ("Finch") attention-free time mixing with data-dependent decay.

State per head is a P x P matrix; training runs a lax.scan over time (the
recurrence is inherently sequential in its exact form), decode is an O(1)
state update — attention-free, so the ``long_500k`` cell runs with constant
memory (no KV cache).

Simplifications vs the full release (noted in DESIGN.md): static token-shift
mixing coefficients (the ddlerp LoRA is collapsed to per-channel mu), and the
decay LoRA is single-layer tanh, matching the paper's published equations.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard_act
from repro.layers.linear import linear, linear_spec
from repro.layers.norm import groupnorm_heads


def rwkv6_spec(
    d_model: int,
    d_ff: int,
    *,
    head_dim: int = 64,
    decay_lora: int = 64,
    mode: str = "megatron",
    stack: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> dict:
    H = d_model // head_dim

    def _p(shape, axes, init="normal", scale=None):
        if stack is not None:
            shape = (stack,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, dtype, init=init, scale=scale)

    return {
        # time mixing
        "mu_r": _p((d_model,), (None,), init="small"),
        "mu_k": _p((d_model,), (None,), init="small"),
        "mu_v": _p((d_model,), (None,), init="small"),
        "mu_w": _p((d_model,), (None,), init="small"),
        "mu_g": _p((d_model,), (None,), init="small"),
        "wr": linear_spec(d_model, d_model, "col", mode, stack=stack, dtype=dtype),
        "wk": linear_spec(d_model, d_model, "col", mode, stack=stack, dtype=dtype),
        "wv": linear_spec(d_model, d_model, "col", mode, stack=stack, dtype=dtype),
        "wg": linear_spec(d_model, d_model, "col", mode, stack=stack, dtype=dtype),
        "w0": _p((d_model,), (None,), init="zeros"),
        "w_lora_a": linear_spec(d_model, decay_lora, "replicated", mode,
                                stack=stack, dtype=dtype),
        "w_lora_b": linear_spec(decay_lora, d_model, "col", mode,
                                stack=stack, dtype=dtype),
        "u": _p((H, head_dim), ("q_heads", None), init="small"),
        "ln_x_scale": _p((H, head_dim), ("q_heads", None), init="ones"),
        "ln_x_bias": _p((H, head_dim), ("q_heads", None), init="zeros"),
        "wo": linear_spec(d_model, d_model, "row", mode, stack=stack, dtype=dtype),
        # channel mixing
        "mu_ck": _p((d_model,), (None,), init="small"),
        "mu_cr": _p((d_model,), (None,), init="small"),
        "ck": linear_spec(d_model, d_ff, "col", mode, stack=stack, dtype=dtype),
        "cv": linear_spec(d_ff, d_model, "row", mode, stack=stack, dtype=dtype),
        "cr": linear_spec(d_model, d_model, "replicated", mode,
                          stack=stack, dtype=dtype),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Previous-token stream: [B,S,D] -> shifted by one (prev fills t=0)."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xprev, mu):
    return x + mu.astype(x.dtype) * (xprev - x)


def _decay(params, xw):
    lora = jnp.tanh(linear(params["w_lora_a"], xw).astype(jnp.float32))
    lora = jnp.einsum("...r,rd->...d", lora,
                      params["w_lora_b"]["w"].astype(jnp.float32))
    w = params["w0"].astype(jnp.float32) + lora
    return jnp.exp(-jnp.exp(w))  # in (0, 1): per-channel decay


def wkv_scan(
    r: jnp.ndarray,  # [B, T, H, P] fp32
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,  # [B, T, H, P] decay in (0,1)
    u: jnp.ndarray,  # [H, P] bonus
    state: Optional[jnp.ndarray] = None,  # [B, H, P, P]
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S + k v^T.

    Two-level scan: the outer scan carries chunk-boundary states (the only
    per-step tensors saved for the backward pass); the inner per-token scan
    is wrapped in jax.checkpoint so its [B,H,P,P] carries are recomputed,
    not stored — without this, training at 4k context would retain
    T x state_size of residuals (~70 GB/device).
    """
    B, T, H, P = r.shape
    if state is None:
        state = jnp.zeros((B, H, P, P), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # each [B,H,P]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    def run(S, seq):
        return jax.lax.scan(step, S, seq)

    if T <= chunk or T % chunk:
        seq = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
        final, ys = run(state, seq)
        return ys.transpose(1, 0, 2, 3), final

    nC = T // chunk

    def to_chunks(a):  # [B,T,H,P] -> [nC, chunk, B, H, P]
        return a.reshape(B, nC, chunk, H, P).transpose(1, 2, 0, 3, 4)

    seq = tuple(to_chunks(a) for a in (r, k, v, w))

    @jax.checkpoint
    def chunk_step(S, inp):
        S, ys = run(S, inp)
        return S, ys

    final, ys = jax.lax.scan(chunk_step, state, seq)
    # ys: [nC, chunk, B, H, P] -> [B, T, H, P]
    ys = ys.reshape(nC * chunk, B, H, P).transpose(1, 0, 2, 3)
    return ys, final


def rwkv6_time_mix(
    params: dict,
    x: jnp.ndarray,                      # [B, S, D]
    *,
    head_dim: int = 64,
    tm_prev: Optional[jnp.ndarray] = None,   # [B, D] carried last token
    wkv_state: Optional[jnp.ndarray] = None,  # [B, H, P, P]
    return_state: bool = False,
):
    B, S, D = x.shape
    H = D // head_dim
    xprev = _token_shift(x, tm_prev)
    xr, xk, xv, xw, xg = (
        _mix(x, xprev, params[f"mu_{n}"]) for n in ("r", "k", "v", "w", "g")
    )
    r = linear(params["wr"], xr).reshape(B, S, H, head_dim)
    k = linear(params["wk"], xk).reshape(B, S, H, head_dim)
    v = linear(params["wv"], xv).reshape(B, S, H, head_dim)
    g = linear(params["wg"], xg)
    w = _decay(params, xw).reshape(B, S, H, head_dim)
    r = shard_act(r, "batch", "seq", "act_heads", None)
    k = shard_act(k, "batch", "seq", "act_heads", None)
    v = shard_act(v, "batch", "seq", "act_heads", None)
    w = shard_act(w, "batch", "seq", "act_heads", None)
    y, new_state = wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), w,
        params["u"].astype(jnp.float32), wkv_state,
    )
    y = groupnorm_heads(
        y.astype(x.dtype), params["ln_x_scale"], params["ln_x_bias"]
    )
    y = y.reshape(B, S, D) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = linear(params["wo"], y)
    if return_state:
        return out, x[:, -1, :], new_state
    return out


def rwkv6_channel_mix(
    params: dict,
    x: jnp.ndarray,
    *,
    cm_prev: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    xprev = _token_shift(x, cm_prev)
    xk = _mix(x, xprev, params["mu_ck"])
    xr = _mix(x, xprev, params["mu_cr"])
    k = linear(params["ck"], xk)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = shard_act(k, "batch", "seq", "act_mlp")
    kv = linear(params["cv"], k)
    out = jax.nn.sigmoid(
        linear(params["cr"], xr).astype(jnp.float32)
    ).astype(x.dtype) * kv
    if return_state:
        return out, x[:, -1, :]
    return out
