"""Rotary position embeddings (rotate-half convention)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies [head_dim // 2], fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(
    x: jnp.ndarray,            # [B, S, H, hd]
    positions: jnp.ndarray,    # [B, S] int32
    inv_freq: jnp.ndarray,     # [hd // 2]
) -> jnp.ndarray:
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
