"""Plan smoke CLI: build an ExecutionPlan, dump ``describe()``, optionally
prove the zero-hot-path-lowerings property.

    PYTHONPATH=src python -m repro.plan --arch yi-6b --debug --warm \\
        --out plan_yi6b.json
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.plan --arch yi-6b --debug \\
        --data 2 --model 4 --stages 2 --warm

``--warm`` builds the plan's ServeBatcher, dispatches two request waves,
and FAILS (exit 1) unless the second wave performs zero new lowerings and
zero new compiles — the acceptance bar the CI plan-smoke job reuses. The
``--out`` JSON is the plan's full pass-decision dump (uploaded as a CI
artifact), written after the warm check so the cache counters are
included.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.models import SHAPES
from repro.plan import MeshSpec, build_plan
from repro.serve import DecodeRequest


def warm_check(plan) -> bool:
    """Two request waves; True iff the second adds no lowerings/compiles."""
    batcher = plan.make_batcher()
    with plan.activate():
        batcher.init_demo_params(seed=0)
        for wave in range(2):
            for i in range(batcher.policy.buckets[0].batch):
                batcher.submit(DecodeRequest(
                    f"w{wave}r{i}", [1 + (i + j) % 7 for j in range(2)],
                    max_new_tokens=4))
            batcher.run()
            if wave == 0:
                warm = dict(plan.stats())
    after = plan.stats()
    ok = (after["lowerings"] == warm["lowerings"]
          and after["compiles"] == warm["compiles"]
          and after["hits"] > warm["hits"])
    print(f"warm check: lowerings {warm['lowerings']} -> "
          f"{after['lowerings']}, compiles {warm['compiles']} -> "
          f"{after['compiles']}, hits {warm['hits']} -> {after['hits']} "
          f"=> {'OK' if ok else 'FAIL'}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Build an ExecutionPlan, dump its pass decisions, and "
                    "optionally assert zero hot-path lowerings after "
                    "warmup.")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="pin a ShapeSpec (default: serve plan, "
                         "per-bucket shapes)")
    ap.add_argument("--mode", default=None,
                    choices=["cascade", "megatron", "megatron_sp"])
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on a debug mesh")
    ap.add_argument("--data", type=int, default=1,
                    help="debug mesh data-axis extent")
    ap.add_argument("--model", type=int, default=1,
                    help="debug mesh model-axis extent")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--warm", action="store_true",
                    help="assert zero new lowerings on the second wave")
    ap.add_argument("--out", default=None,
                    help="write the describe() JSON here")
    args = ap.parse_args()

    mesh_spec = (MeshSpec.debug(args.data, args.model) if args.debug
                 else MeshSpec.production(multi_pod=args.multi_pod))
    plan = build_plan(args.arch, args.shape, mode=args.mode,
                      mesh_spec=mesh_spec, quantized=args.quantized,
                      pipeline_stages=args.stages, debug=args.debug)

    d = plan.describe()
    print(f"{d['arch']} ({d['family']}) mode={d['mode']} "
          f"mesh={d['mesh']} stages={d['pipeline_stages']} "
          f"quantized={d['quantized']}")
    for p in d["passes"]:
        entry = {k: v for k, v in p.items() if k != "pass"}
        print(f"  {p['pass']}: {entry}")

    ok = True
    if args.warm:
        ok = warm_check(plan)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(plan.describe(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
