"""PlanIR: the record every compile-plan pass enriches.

``repro.plan`` is the framework-level analog of ``repro.core.passes``: where
the core pipeline enriches a small-graph IR node by node, the plan pipeline
enriches ONE PlanIR describing how a whole model runs on a device mesh.
Each pass writes what it decided (mesh axes, rule table, per-param
PartitionSpecs, stage placements, quantization shifts, executable keys)
into the IR, and every decision is appended to an ordered ``decisions``
log so ``ExecutionPlan.describe()`` can replay the pipeline verbatim.

Nothing here touches jax device state at import time: ``MeshSpec`` is a
declarative mesh description; devices are only enumerated when the
ResolveMesh pass calls :meth:`MeshSpec.build`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.models.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative mesh description (resolved by the ResolveMesh pass).

    Launchers hand the plan a MeshSpec instead of calling
    ``make_debug_mesh`` / ``make_production_mesh`` themselves — the plan is
    the only component that materializes device meshes. ``from_mesh`` wraps
    an already-built Mesh (tests, embedding the plan in an outer harness).
    """

    kind: str = "debug"                  # "debug" | "production" | "explicit"
    data: int = 1                        # debug: data-axis extent
    model: int = 1                       # debug: model-axis extent
    multi_pod: bool = False              # production: 2x16x16 vs 16x16
    mesh: Optional[Any] = None           # explicit: a prebuilt jax Mesh

    @classmethod
    def debug(cls, data: int = 1, model: int = 1) -> "MeshSpec":
        return cls(kind="debug", data=data, model=model)

    @classmethod
    def production(cls, multi_pod: bool = False) -> "MeshSpec":
        return cls(kind="production", multi_pod=multi_pod)

    @classmethod
    def from_mesh(cls, mesh) -> "MeshSpec":
        return cls(kind="explicit", mesh=mesh)

    def build(self):
        """Materialize the jax Mesh (the only device-touching call)."""
        if self.kind == "explicit":
            if self.mesh is None:
                raise ValueError("explicit MeshSpec needs a mesh")
            return self.mesh
        from repro.launch.mesh import make_debug_mesh, make_production_mesh

        if self.kind == "debug":
            return make_debug_mesh(self.data, self.model)
        if self.kind == "production":
            return make_production_mesh(multi_pod=self.multi_pod)
        raise ValueError(f"unknown MeshSpec kind {self.kind!r}")

    def label(self) -> str:
        if self.kind == "debug":
            return f"debug:{self.data}x{self.model}"
        if self.kind == "production":
            return "production:2x16x16" if self.multi_pod \
                else "production:16x16"
        m = self.mesh
        return "explicit:" + "x".join(str(s) for s in m.devices.shape) \
            if m is not None else "explicit:?"


@dataclasses.dataclass(frozen=True)
class StagePlacement:
    """One pipeline stage's layer range and its mesh-slice rectangle.

    The rectangle lives on the (model, data) grid the PlaceStages pass
    hands to ``core.placement.Placer``: ``col``/``width`` span the model
    axis, ``row``/``height`` span the stage (data) axis.
    """

    index: int
    first_layer: int
    n_layers: int
    col: int
    row: int
    width: int
    height: int

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PlanIR:
    """The one record the plan passes consume and enrich.

    The first block is the *request* (what the caller asked for); every
    field below it is filled in by a pass. ``decisions`` is the ordered
    (pass name, record) log behind ``ExecutionPlan.describe()``.
    """

    # -- request ------------------------------------------------------------
    cfg: ArchConfig
    shape: Optional[ShapeSpec]           # None: serve plan (bucketed shapes)
    mode: str
    mesh_spec: MeshSpec
    quantized: bool = False
    pipeline_stages: int = 1

    # -- ResolveMesh --------------------------------------------------------
    mesh: Optional[Any] = None

    # -- ResolveSharding ----------------------------------------------------
    rules: Optional[Any] = None          # ShardingRules
    param_pspecs: Dict[str, str] = dataclasses.field(default_factory=dict)

    # -- PlaceStages --------------------------------------------------------
    stages: List[StagePlacement] = dataclasses.field(default_factory=list)
    stage_axis: Optional[str] = None     # mesh axis the layers dim shards on
    placement_cost: float = 0.0
    placement_method: str = ""

    # -- Quantize -----------------------------------------------------------
    quant: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- Compile ------------------------------------------------------------
    executables: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)

    # -- audit trail --------------------------------------------------------
    decisions: List[Tuple[str, Dict[str, Any]]] = dataclasses.field(
        default_factory=list)

    def record(self, pass_name: str, **entry: Any) -> None:
        self.decisions.append((pass_name, entry))

    def pass_names(self) -> List[str]:
        return [name for name, _ in self.decisions]
