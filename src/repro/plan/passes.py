"""The compile-plan pass pipeline (framework analog of paper Fig. 2).

    ResolveMesh -> ResolveSharding -> PlaceStages -> Quantize -> Compile

Each pass consumes and enriches one :class:`repro.plan.ir.PlanIR`, exactly
as ``repro.core.passes`` enriches the small-graph IR. Every decision is
appended to ``ir.decisions`` so the resulting plan is fully introspectable
(``ExecutionPlan.describe()``).

* **ResolveMesh** materializes the device mesh from the declarative
  :class:`~repro.plan.ir.MeshSpec` — the only place a plan touches
  ``jax.devices()``.
* **ResolveSharding** builds the mode's logical-axis rule table and records
  the fully fitted PartitionSpec of every parameter.
* **PlaceStages** splits the scan-over-layers stack into contiguous
  pipeline stages, models each stage as a ``core.placement.Block``
  (width = model-parallel extent, height = mesh rows per stage), and
  reuses the branch-and-bound :class:`~repro.core.placement.Placer` /
  Eq. 2 cost model to assign stages to contiguous mesh slices. The chosen
  slices turn into a ``layers -> data`` rule-table override, so the
  stacked layer weights (and decode state) shard across the slice instead
  of replicating everywhere.
* **Quantize** decides the int8 serving paths: the decode LM head (always,
  when ``quantized``) and the MLP down-projection with per-tensor
  calibrated shifts (``calibrate_mlp_shifts`` refines the defaults once
  real weights exist).
* **Compile** registers the executable catalogue; every entry is built AOT
  through ``repro.serve.cache.ExecutableCache`` so train-step, prefill,
  and decode executables are all counted by the same hit/lowering/compile
  counters.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.placement import Block, Placer, PlacementResult
from repro.dist.sharding import rules_for_mode, spec_to_pspec
from repro.models.base import ArchConfig, build_model
from repro.plan.ir import PlanIR, StagePlacement
from repro.quant.qtensor import choose_shift

# Families whose transformer blocks carry a dense SwiGLU "ffn" whose
# down-projection the Quantize pass can route through the qmatmul kernel.
# (encdec is excluded: its decoder uses the gelu ``mlp`` path, which has
# no quantized route — listing it would report calibrated MLP
# quantization while every projection stayed float. moe/ssm likewise.)
MLP_QUANT_FAMILIES = ("dense", "vlm", "hybrid")

# Decode LM-head shifts (PR 2): rmsnorm'd activations (absmax < 4),
# fan-in-scaled head weights (absmax < 0.5), int16 SRS out.
HEAD_SHIFTS = (5, 8, 11)


def _is_spec(x) -> bool:
    from repro.dist.sharding import ParamSpec

    return isinstance(x, ParamSpec)


def stack_depth(cfg: ArchConfig) -> int:
    """Length of the outer scan-over-layers dim (the stage-splittable one).

    The hybrid family scans over layer *groups* (one shared attention block
    per group), so its stackable depth is the group count.
    """
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _resolve_param_pspecs(ir: PlanIR) -> Dict[str, str]:
    """Flat {param path: PartitionSpec} map under the current rule table."""
    specs = build_model(ir.cfg).param_specs()
    leaves, _ = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)
    out = {}
    for path, spec in leaves:
        key = jax.tree_util.keystr(path)
        out[key] = str(spec_to_pspec(spec, ir.mesh, ir.rules))
    return out


# ---------------------------------------------------------------------------
# 1. ResolveMesh
# ---------------------------------------------------------------------------


def resolve_mesh_pass(ir: PlanIR) -> PlanIR:
    ir.mesh = ir.mesh_spec.build()
    axes = dict(zip(ir.mesh.axis_names, ir.mesh.devices.shape))
    ir.record("ResolveMesh", mesh=ir.mesh_spec.label(), axes=axes,
              devices=int(ir.mesh.devices.size))
    return ir


# ---------------------------------------------------------------------------
# 2. ResolveSharding
# ---------------------------------------------------------------------------


def resolve_sharding_pass(ir: PlanIR) -> PlanIR:
    ir.rules = rules_for_mode(ir.mode)
    ir.param_pspecs = _resolve_param_pspecs(ir)
    sharded = {k: v for k, v in ir.param_pspecs.items()
               if v != "PartitionSpec()"}
    ir.record("ResolveSharding", mode=ir.mode,
              params=len(ir.param_pspecs), sharded=len(sharded))
    return ir


# ---------------------------------------------------------------------------
# 3. PlaceStages
# ---------------------------------------------------------------------------


def assign_stage_slices(
    n_cols: int,
    n_rows: int,
    n_stages: int,
    *,
    lam: float = 1.0,
    mu: float = 0.05,
    beam: Optional[int] = 64,
) -> PlacementResult:
    """Assign ``n_stages`` equal stage blocks to mesh slices with the
    paper's Eq. 2 branch-and-bound. The grid is the (model, data) device
    plane: columns = model axis, rows = data axis; each stage is a
    full-width block ``n_rows // n_stages`` rows tall. ``beam=None`` is
    exact; tests pin beam mode against it.

    Because the blocks are identical full-width rectangles, every
    feasible placement is a permutation of the same row bands — the
    search certifies that the banded layout is Eq. 2-optimal (and
    records its cost/expansions in the plan) rather than choosing among
    structurally different layouts. It earns its keep the day stages get
    per-stage widths or user ``fixed`` pins, both of which the Placer
    already supports.
    """
    if n_rows % n_stages:
        raise ValueError(
            f"{n_stages} stages do not divide the {n_rows}-row axis")
    height = n_rows // n_stages
    blocks = [Block(n_cols, height, f"stage{i}") for i in range(n_stages)]
    placer = Placer(n_cols, n_rows, lam=lam, mu=mu, beam=beam)
    return placer.branch_and_bound(blocks, start=(0, 0))


def place_stages_pass(ir: PlanIR) -> PlanIR:
    S = ir.pipeline_stages
    depth = stack_depth(ir.cfg)
    if S < 1:
        raise ValueError(f"pipeline_stages must be >= 1, got {S}")
    if S > depth:
        raise ValueError(
            f"pipeline_stages={S} exceeds the layer stack depth {depth}")
    if S == 1:
        ir.record("PlaceStages", stages=1, stage_axis=None,
                  note="single stage: layers axis replicated")
        return ir

    sizes = dict(zip(ir.mesh.axis_names, ir.mesh.devices.shape))
    n_rows = sizes.get("data", 1)
    n_cols = sizes.get("model", 1)
    fallback = None
    if n_rows < S or n_rows % S:
        fallback = (f"data axis ({n_rows} rows) cannot hold {S} equal "
                    "stages")
    elif depth % n_rows:
        fallback = (f"layer stack ({depth}) does not divide over the "
                    f"data axis ({n_rows} rows)")
    if fallback:
        ir.record("PlaceStages", stages=S, stage_axis=None,
                  fallback=fallback)
        return ir

    result = assign_stage_slices(n_cols, n_rows, S)
    # GSPMD shards the stacked layer dim across the axis in row order, so
    # stage k (layers [k*per, (k+1)*per)) goes to the k-th row band; the
    # sort also canonicalizes any cost-tied permutation the search
    # returns (identical blocks make all permutations cost-equal).
    order = sorted(range(S), key=lambda i: result.positions[i].row)
    per = depth // S
    ir.stages = [
        StagePlacement(k, k * per, per, p.col, p.row, p.width, p.height)
        for k, p in ((k, result.positions[i]) for k, i in enumerate(order))
    ]
    ir.stage_axis = "data"
    ir.placement_cost = result.cost
    ir.placement_method = result.method
    ir.rules = ir.rules.replace(layers="data")
    ir.param_pspecs = _resolve_param_pspecs(ir)
    ir.record(
        "PlaceStages", stages=S, stage_axis="data",
        cost=round(result.cost, 4), method=result.method,
        expanded=result.nodes_expanded,
        slices=[s.as_dict() for s in ir.stages],
    )
    return ir


# ---------------------------------------------------------------------------
# 4. Quantize
# ---------------------------------------------------------------------------


def _observe_mlp_ranges(cfg: ArchConfig, params, model, steps: int,
                        batch: int) -> Dict[str, float]:
    """Short eager greedy decode of the FLOAT model under the swiglu
    calibration scope, returning the observed absmax of the
    down-projection input ("act") and output ("out")."""
    import jax.numpy as jnp

    from repro.dist.sharding import init_params
    from repro.layers.mlp import swiglu_calibration

    record: Dict[str, float] = {}
    max_len = steps + 2
    state = init_params(jax.random.PRNGKey(0),
                        model.decode_state_specs(batch, max_len))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, cfg.vocab, (batch,)), jnp.int32)
    with jax.disable_jit(), swiglu_calibration(record):
        for i in range(steps):
            logits, state = model.decode_step(params, state, tok,
                                              jnp.int32(i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return record


def calibrate_mlp_shifts(
    cfg: ArchConfig,
    params,
    model=None,
    *,
    steps: int = 6,
    batch: int = 2,
) -> Tuple[int, int, int]:
    """Per-tensor calibrated shifts for the a16w8 MLP down-projection.

    ``w_shift`` comes from the observed absmax of every ``ffn/down``
    weight tensor (the per-tensor calibration the core quantize_pass does
    for imported weights). With a float ``model`` the activation/output
    shifts come from the ranges a short calibration decode actually
    observes (one headroom bit reserved for unseen data); without one they
    fall back to the analytic worst case ``|x|_max * max
    column-abs-sum(w)``. The output shift is always capped so the SRS
    shift stays >= 0.
    """
    x_shift = cfg.mlp_x_shift
    w_shift, colsum = None, 0.0
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        if "ffn" not in key or "down" not in key or not key.endswith("'w']"):
            continue
        w = np.asarray(leaf, np.float32)
        s = choose_shift(w, "int8")
        w_shift = s if w_shift is None else min(w_shift, s)
        # stacked [L, d_ff, d_model]: column abs-sum over the contraction dim
        colsum = max(colsum, float(np.abs(w).sum(axis=-2).max()))
    if w_shift is None:
        return (x_shift, cfg.mlp_w_shift, cfg.mlp_out_shift)

    record: Dict[str, float] = {}
    if model is not None:
        record = _observe_mlp_ranges(cfg, params, model, steps, batch)
    if record.get("act"):
        x_shift = choose_shift(np.asarray([record["act"]]), "int16",
                               margin_bits=1)
        out_amax = max(record.get("out", 0.0), 1e-12)
        out_shift = choose_shift(np.asarray([out_amax]), "int16",
                                 margin_bits=1)
    else:
        x_amax = 2.0 ** (15 - x_shift)       # full int16 range at x_shift
        out_shift = choose_shift(
            np.asarray([max(x_amax * colsum, 1e-12)]), "int16")
    out_shift = min(out_shift, x_shift + w_shift)
    return (x_shift, w_shift, out_shift)


def quantize_pass(ir: PlanIR) -> PlanIR:
    if not ir.quantized:
        ir.record("Quantize", enabled=False)
        return ir
    cfg = ir.cfg.with_(quantized=True)
    # MLP quantization is a *serving* decision: only decode-path plans
    # (serve plans have shape=None; dry-runs may pin a decode ShapeSpec)
    # route the down-projection through the qmatmul kernel.
    decode_plan = ir.shape is None or ir.shape.kind == "decode"
    mlp = decode_plan and cfg.family in MLP_QUANT_FAMILIES
    if mlp:
        cfg = cfg.with_(quantized_mlp=True)
    ir.cfg = cfg
    ir.quant = {
        "head_shifts": HEAD_SHIFTS,
        "mlp": mlp,
        "mlp_shifts": (cfg.mlp_x_shift, cfg.mlp_w_shift, cfg.mlp_out_shift),
        "calibrated": False,
    }
    ir.record("Quantize", enabled=True, head_shifts=HEAD_SHIFTS, mlp=mlp,
              mlp_shifts=ir.quant["mlp_shifts"])
    return ir


# ---------------------------------------------------------------------------
# 5. Compile
# ---------------------------------------------------------------------------


def compile_pass(ir: PlanIR) -> PlanIR:
    """Register the executable catalogue (kind -> cache-key template).

    Executables are built lazily through ``ExecutionPlan.executable`` /
    ``serve_executable`` so a plan stays cheap to construct; every build
    goes through the shared ExecutableCache and shows up in its counters.
    """
    cat: Dict[str, Dict[str, object]] = {}
    if ir.shape is not None:
        cat[ir.shape.kind] = {
            "batch": ir.shape.global_batch,
            "seq_len": ir.shape.seq_len,
            "shape": ir.shape.name,
        }
    if ir.shape is None or ir.shape.kind == "decode":
        cat.setdefault("decode", {"batch": "per-bucket",
                                  "seq_len": "per-bucket"})
        cat["prefill"] = {"batch": "per-bucket", "seq_len": "per-bucket",
                          "note": "prefill->decode scan handoff"}
        cat["masked_decode"] = {
            "batch": "per-bucket", "seq_len": "per-bucket",
            "steps_per_dispatch": "per-scheduler",
            "note": "slot-masked continuous-batching micro-run (scans k "
                    "masked steps per call; cache-keyed by k). Variants: "
                    "paged=(page_count, page_size) pooled-KV layout; "
                    "spec=(spec_k, draft_layers) fused speculative "
                    "draft-scan + block-verify — both join the cache key",
        }
    ir.executables = cat
    ir.record("Compile", kinds=sorted(cat), cache="serve.ExecutableCache",
              aot=True)
    return ir


PLAN_PIPELINE: List[Tuple[str, object]] = [
    ("ResolveMesh", resolve_mesh_pass),
    ("ResolveSharding", resolve_sharding_pass),
    ("PlaceStages", place_stages_pass),
    ("Quantize", quantize_pass),
    ("Compile", compile_pass),
]
