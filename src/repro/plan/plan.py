"""``build_plan`` and :class:`ExecutionPlan`: the one compile-plan API.

Every executable in this framework — train step, prefill, decode — is
built by handing ``build_plan`` an architecture, a shape, and a
:class:`~repro.plan.ir.MeshSpec`, and asking the resulting plan for the
executable. Launchers, the serve batcher, benchmarks, and examples are all
thin consumers; none of them touch ``make_*_mesh``, ``rules_for_mode``,
``specs_to_shardings``, or ``lower().compile()`` directly.

    from repro.plan import MeshSpec, build_plan
    plan = build_plan("yi-6b", shape="train_4k",
                      mesh_spec=MeshSpec.production())
    params, opt_state = plan.init_train_state(seed=0)
    step = plan.executable("train")          # AOT, cached, counted
    print(plan.describe())                   # every pass decision

The plan is produced by the ordered pass pipeline in
``repro.plan.passes`` (ResolveMesh -> ResolveSharding -> PlaceStages ->
Quantize -> Compile) over a :class:`~repro.plan.ir.PlanIR`; the IR records
what each pass decided and ``describe()`` dumps it for CI artifacts.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Dict, Optional, Union

import jax

from repro.dist.sharding import (
    abstract_params,
    init_params,
    sharding_ctx,
    specs_to_shardings,
)
from repro.launch.steps import (
    make_masked_decode_step,
    make_prefill_decode_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.base import ArchConfig, SHAPES, ShapeSpec, build_model
from repro.plan.ir import MeshSpec, PlanIR
from repro.plan.passes import PLAN_PIPELINE, calibrate_mlp_shifts
from repro.serve.cache import CacheKey, CachedExecutable, ExecutableCache


class ExecutionPlan:
    """A fully resolved execution recipe: mesh + rules + stages + quant +
    the AOT executable catalogue. Construct via :func:`build_plan`."""

    def __init__(self, ir: PlanIR, cache: Optional[ExecutableCache] = None):
        self.ir = ir
        self.cache = cache or ExecutableCache()
        self._model = None
        self._model_cfg = None
        self._optimizer = None
        self._built_any = False
        self._token_argmax_fns: Dict[Any, Any] = {}

    # -- resolved views -------------------------------------------------------

    @property
    def cfg(self) -> ArchConfig:
        return self.ir.cfg

    @property
    def mesh(self):
        return self.ir.mesh

    @property
    def rules(self):
        return self.ir.rules

    @property
    def mode(self) -> str:
        return self.ir.mode

    @property
    def shape(self) -> Optional[ShapeSpec]:
        return self.ir.shape

    @property
    def model(self):
        if self._model is None or self._model_cfg is not self.ir.cfg:
            self._model = build_model(self.ir.cfg)
            self._model_cfg = self.ir.cfg
        return self._model

    @property
    def optimizer(self):
        if self._optimizer is None:
            from repro.optim.optimizers import make_optimizer

            self._optimizer = make_optimizer(self.cfg.optimizer)
        return self._optimizer

    @contextmanager
    def activate(self):
        """``with mesh, sharding_ctx(...)`` — tracing/eager context."""
        with self.mesh, sharding_ctx(self.mesh, self.rules):
            yield self

    # -- parameters / state ---------------------------------------------------

    def param_specs(self):
        return self.model.param_specs()

    def abstract_params(self):
        return abstract_params(self.param_specs())

    def param_shardings(self):
        return specs_to_shardings(self.param_specs(), self.mesh, self.rules)

    def shard_params(self, params):
        """Place (and stage/mode-shard) an existing parameter pytree."""
        self.calibrate(params)
        return jax.device_put(params, self.param_shardings())

    def init_params(self, seed: int = 0):
        """Random sharded parameters (demos, benchmarks, tests)."""
        return self.shard_params(
            init_params(jax.random.PRNGKey(seed), self.param_specs()))

    def init_train_state(self, seed: int = 0):
        """(sharded params, optimizer state) ready for the train step."""
        params = self.init_params(seed)
        with self.activate():
            opt_state = self.optimizer.init(params)
        return params, opt_state

    def state_shardings(self, batch: int, max_len: int):
        sspecs = self.model.decode_state_specs(batch, max_len)
        return specs_to_shardings(sspecs, self.mesh, self.rules)

    def fresh_decode_state(self, batch: int, max_len: int, paged=None,
                           only: Optional[str] = None, spec=None):
        """A zeroed, sharded decode-state pytree for one bucket shape.

        With ``paged=(page_count, page_size)`` the KV leaves come back in
        the pooled ``[..., page_count, page_size, ...]`` layout produced
        by :func:`repro.models.base.paged_state_specs` (batch-free; the
        page table maps slots onto them) while recurrent/cross leaves
        keep their dense per-slot shape. ``only`` restricts a paged build
        to one half of the split: ``"pool"`` returns just the pooled KV
        leaves (bucket-independent; the StatePool builds them once and
        shares them across buckets), ``"dense"`` just the per-slot
        remainder. With ``spec=(spec_k, draft_layers)`` the tree also
        carries the ``draft_``-prefixed layer-prefix KV leaves the fused
        speculative executable scans (the pool and per-slot wipes treat
        them like any other batch-laned leaf; combined with ``paged``
        the draft KV twins move to the pooled layout too — they ride the
        slot's page table).
        """
        sspecs = self.model.decode_state_specs(batch, max_len)
        if spec is not None:
            from repro.models.base import spec_state_specs

            sspecs = dict(sspecs, **spec_state_specs(sspecs, spec[1]))
        if paged is not None:
            from repro.models.base import is_paged_state_key, paged_state_specs

            sspecs = paged_state_specs(sspecs, *paged)
            if only == "pool":
                sspecs = {k: s for k, s in sspecs.items()
                          if is_paged_state_key(k)}
            elif only == "dense":
                sspecs = {k: s for k, s in sspecs.items()
                          if not is_paged_state_key(k)}
        return jax.device_put(
            init_params(jax.random.PRNGKey(0), sspecs),
            specs_to_shardings(sspecs, self.mesh, self.rules))

    # -- quantization calibration ---------------------------------------------

    def calibrate(self, params) -> "ExecutionPlan":
        """Refine the Quantize pass's MLP shifts from real weights.

        Runs once, before any executable is built (a calibration after
        compilation would silently mismatch the cached executables, so it
        is skipped and recorded instead).
        """
        if not self.cfg.quantized_mlp or self.ir.quant.get("calibrated"):
            return self
        if self._built_any:
            self.ir.record("Quantize", skipped_calibration=(
                "executables already compiled with default shifts"))
            return self
        # fully float: the eager calibration decode must not enter the
        # Pallas kernels (pallas_call can't run under jax.disable_jit)
        float_model = build_model(
            self.cfg.with_(quantized=False, quantized_mlp=False))
        x_s, w_s, o_s = calibrate_mlp_shifts(self.cfg, params,
                                             model=float_model)
        self.ir.cfg = self.cfg.with_(
            mlp_x_shift=x_s, mlp_w_shift=w_s, mlp_out_shift=o_s)
        self.ir.quant.update(mlp_shifts=(x_s, w_s, o_s), calibrated=True)
        self.ir.record("Quantize", calibrated_mlp_shifts=(x_s, w_s, o_s))
        return self

    def _qsig(self):
        cfg = self.cfg
        if not cfg.quantized_mlp:
            return ()
        return (("mlp", cfg.mlp_x_shift, cfg.mlp_w_shift, cfg.mlp_out_shift),)

    # -- executables ----------------------------------------------------------

    def _key(self, kind: str, batch: int, max_len: int,
             prefill_len: int = 0, steps: int = 1,
             paged=(), spec=()) -> CacheKey:
        return CacheKey(
            arch=self.cfg.name, kind=kind, batch=batch, max_len=max_len,
            prefill_len=prefill_len, mode=self.mode,
            mesh_axes=CacheKey.mesh_signature(self.mesh),
            quantized=self.cfg.quantized,
            stages=self.ir.pipeline_stages, qsig=self._qsig(),
            steps=steps, paged=tuple(paged), spec=tuple(spec),
        )

    def executable(self, kind: Optional[str] = None) -> CachedExecutable:
        """The AOT executable for this plan's ShapeSpec (train/prefill/
        decode). Compiled once through the ExecutableCache and counted."""
        shape = self.shape
        if shape is None:
            raise ValueError(
                "this plan has no pinned ShapeSpec (serve plans build "
                "per-bucket executables via serve_executable)")
        kind = kind or shape.kind
        builders = {
            "train": lambda: make_train_step(
                self.cfg, shape, self.mesh, rules=self.rules),
            "prefill": lambda: make_prefill_step(
                self.cfg, shape, self.mesh, rules=self.rules),
            "decode": lambda: make_serve_step(
                self.cfg, shape, self.mesh, rules=self.rules),
        }
        if kind not in builders:
            raise ValueError(f"unknown executable kind {kind!r}")
        key = self._key(kind, shape.global_batch, shape.seq_len)
        self._built_any = True
        return self.cache.get_or_build(key, builders[kind])

    def serve_executable(self, kind: str, *, batch: int, max_len: int,
                         prefill_len: int = 0,
                         steps_per_dispatch: int = 1,
                         paged=None, spec=None) -> CachedExecutable:
        """A bucketed serving executable: ``kind`` is "decode" (single
        token against resident state), "prefill" (the prefill->decode
        scan handoff padded to ``prefill_len``), or "masked_decode" (the
        slot-masked continuous-batching micro-run — per-slot
        active/fresh lane schedules and attention windows, scanning
        ``steps_per_dispatch`` masked steps per call; one shape-stable
        executable per (bucket, k), keyed separately in the cache).
        ``paged=(page_count, page_size)`` (masked_decode only) swaps the
        dense per-slot KV slabs for the pooled paged layout plus a
        per-slot page-table input; requires ``max_len % page_size == 0``.
        ``spec=(spec_k, draft_layers)`` (masked_decode only) builds the
        fused speculative variant: a layer-prefix draft scans the
        micro-run and the full target block-verifies it in the same
        dispatch (see ``make_masked_decode_step``); the draft signature
        joins the cache key so plans differing only in draft depth never
        share an executable. ``spec`` composes with ``paged`` — the key
        carries both fields, so the four layout/schedule combinations
        never collide.
        """
        if steps_per_dispatch < 1:
            raise ValueError(
                f"steps_per_dispatch must be >= 1, got {steps_per_dispatch}")
        if steps_per_dispatch > 1 and kind != "masked_decode":
            raise ValueError(
                "steps_per_dispatch only applies to masked_decode "
                f"executables, not {kind!r}")
        if paged is not None:
            if kind != "masked_decode":
                raise ValueError(
                    "paged KV only applies to masked_decode executables, "
                    f"not {kind!r}")
            page_count, page_size = paged
            if page_size < 1 or page_count < 1:
                raise ValueError(f"bad paged geometry {paged!r}")
            if max_len % page_size:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of page_size "
                    f"{page_size}")
        if spec is not None:
            if kind != "masked_decode":
                raise ValueError(
                    "speculative decode only applies to masked_decode "
                    f"executables, not {kind!r}")
            spec_k, draft_layers = spec
            if spec_k != steps_per_dispatch:
                raise ValueError(
                    f"spec_k ({spec_k}) must equal steps_per_dispatch "
                    f"({steps_per_dispatch})")
            if not 1 <= draft_layers <= self.cfg.n_layers:
                raise ValueError(
                    f"draft_layers must be in [1, {self.cfg.n_layers}], "
                    f"got {draft_layers}")
        if kind == "decode":
            shape = ShapeSpec(f"b{batch}xl{max_len}", max_len, batch,
                              "decode")
            build = lambda: make_serve_step(  # noqa: E731
                self.cfg, shape, self.mesh, rules=self.rules)
        elif kind == "prefill":
            build = lambda: make_prefill_decode_step(  # noqa: E731
                self.cfg, batch, prefill_len, max_len, self.mesh,
                rules=self.rules)
        elif kind == "masked_decode":
            build = lambda: make_masked_decode_step(  # noqa: E731
                self.cfg, batch, max_len, self.mesh, rules=self.rules,
                steps_per_dispatch=steps_per_dispatch, paged=paged, spec=spec)
        else:
            raise ValueError(f"unknown serve executable kind {kind!r}")
        key = self._key(kind, batch, max_len, prefill_len,
                        steps=steps_per_dispatch,
                        paged=paged if paged is not None else (),
                        spec=spec if spec is not None else ())
        self._built_any = True
        return self.cache.get_or_build(key, build)

    def token_argmax(self, tok_sharding):
        """The greedy token-selection helper, compiled by the plan.

        Thin clients (the batcher's legacy dense path) must not call
        ``jax.jit`` themselves — compilation outside the plan is
        invisible to the cache's lowering counters, which is exactly
        what the RA501 layering rule enforces. Cached per output
        sharding, so repeat buckets on the same mesh reuse one
        compilation.
        """
        fn = self._token_argmax_fns.get(tok_sharding)
        if fn is None:
            import jax.numpy as jnp

            fn = jax.jit(lambda l: jnp.argmax(l, -1).astype(jnp.int32),
                         out_shardings=tok_sharding)
            self._token_argmax_fns[tok_sharding] = fn
        return fn

    def make_batcher(self, policy=None, **kw):
        """A ServeBatcher whose executables all come from this plan."""
        from repro.serve.batcher import ServeBatcher

        return ServeBatcher(self, policy=policy, **kw)

    # -- observability --------------------------------------------------------

    def describe(self) -> Dict[str, Any]:
        """JSON-able dump of every pass decision (CI artifact / debugging)."""
        ir = self.ir
        return {
            "arch": self.cfg.name,
            "family": self.cfg.family,
            "shape": ir.shape.name if ir.shape else None,
            "mode": ir.mode,
            "mesh": ir.mesh_spec.label(),
            "mesh_axes": dict(zip(ir.mesh.axis_names,
                                  (int(s) for s in ir.mesh.devices.shape))),
            "quantized": ir.quantized,
            "pipeline_stages": ir.pipeline_stages,
            "stage_axis": ir.stage_axis,
            "stages": [s.as_dict() for s in ir.stages],
            "quant": dict(ir.quant),
            "executables": ir.executables,
            "params": dict(ir.param_pspecs),
            "passes": [{"pass": name, **entry}
                       for name, entry in ir.decisions],
            "cache": self.cache.stats(),
        }

    def stats(self) -> Dict[str, Any]:
        return self.cache.stats()


def build_plan(
    arch: Union[str, ArchConfig],
    shape: Union[str, ShapeSpec, None] = None,
    *,
    mode: Optional[str] = None,
    mesh_spec: Optional[Union[MeshSpec, Any]] = None,
    quantized: bool = False,
    pipeline_stages: int = 1,
    debug: bool = False,
    config_overrides: Optional[Dict[str, Any]] = None,
    cache: Optional[ExecutableCache] = None,
) -> ExecutionPlan:
    """Run the plan pass pipeline and return the ExecutionPlan.

    ``arch`` is an architecture alias ("yi-6b") or an ArchConfig;
    ``shape`` a ShapeSpec / SHAPES name, or None for a serve plan whose
    decode/prefill shapes come per bucket. ``mesh_spec`` is a MeshSpec
    (or an already-built Mesh); defaults to the 1x1 debug mesh under
    ``debug`` and the single-pod production mesh otherwise.
    ``pipeline_stages`` > 1 engages the PlaceStages pass.
    """
    if isinstance(arch, ArchConfig):
        cfg = arch
    else:
        from repro.configs import get_config, reduced_config

        cfg = reduced_config(arch) if debug else get_config(arch)
    if config_overrides:
        cfg = cfg.with_(**config_overrides)
    if mode:
        cfg = cfg.with_(sharding_mode=mode)
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if mesh_spec is None:
        mesh_spec = MeshSpec.debug(1, 1) if debug else MeshSpec.production()
    elif not isinstance(mesh_spec, MeshSpec):
        mesh_spec = MeshSpec.from_mesh(mesh_spec)

    ir = PlanIR(
        cfg=cfg, shape=shape, mode=cfg.sharding_mode, mesh_spec=mesh_spec,
        quantized=quantized, pipeline_stages=pipeline_stages,
    )
    for _name, pass_fn in PLAN_PIPELINE:
        ir = pass_fn(ir)
    return ExecutionPlan(ir, cache)
