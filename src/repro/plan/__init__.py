"""One compile-plan API: the pass pipeline that unifies mesh construction,
sharding resolution, stage placement, quantization, and AOT compilation.

Public surface:

* :func:`~repro.plan.plan.build_plan` — run ResolveMesh -> ResolveSharding
  -> PlaceStages -> Quantize -> Compile over a :class:`~repro.plan.ir.PlanIR`
  and get an :class:`~repro.plan.plan.ExecutionPlan`.
* :class:`~repro.plan.plan.ExecutionPlan` — the only way executables are
  built: params/state sharding, stage-aware rule tables, the AOT
  executable catalogue (train/prefill/decode) behind the shared
  ``ExecutableCache``, and ``describe()`` introspection.
* :class:`~repro.plan.ir.MeshSpec` — declarative mesh description
  (``debug``/``production``/``from_mesh``).
* ``PLAN_PIPELINE`` — the ordered (name, pass) list, introspectable like
  ``repro.core.passes.PIPELINE``.

See docs/compile_plan.md for the pass-by-pass reference.
"""

from repro.plan.ir import MeshSpec, PlanIR, StagePlacement
from repro.plan.passes import (
    PLAN_PIPELINE,
    assign_stage_slices,
    calibrate_mlp_shifts,
    stack_depth,
)
from repro.plan.plan import ExecutionPlan, build_plan

__all__ = [
    "ExecutionPlan",
    "MeshSpec",
    "PLAN_PIPELINE",
    "PlanIR",
    "StagePlacement",
    "assign_stage_slices",
    "build_plan",
    "calibrate_mlp_shifts",
    "stack_depth",
]
