"""Optimizers (pytree-functional, no external deps).

* ``adamw``     — fp32 moments, decoupled weight decay, global-norm clip.
* ``adafactor`` — factored second moment for >=2D params (row/col statistics),
                  no first moment; the memory-frugal choice for the 100B-1T
                  configs (see EXPERIMENTS.md fit analysis).

Each optimizer also exposes ``state_specs(param_specs)`` returning a
ParamSpec pytree for the optimizer state, so the dry-run can derive
NamedShardings for it. Optimizer-state logical axes reuse the parameter's
axes, with the "fsdp" dim additionally spread over the pod axis when present
(ZeRO-1 style: cheaper state, no extra forward/backward comm).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params) -> (updates, state)
    state_specs: Callable[[Any], Any]


def _global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


_IS_SPEC = lambda x: isinstance(x, ParamSpec)  # noqa: E731


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    max_grad_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = _clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(g, m, v, p):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "step": step}, gnorm

    def state_specs(param_specs):
        def f32(s: ParamSpec) -> ParamSpec:
            return ParamSpec(s.shape, s.logical, jnp.float32, "zeros")

        return {
            "m": jax.tree.map(f32, param_specs, is_leaf=_IS_SPEC),
            "v": jax.tree.map(f32, param_specs, is_leaf=_IS_SPEC),
            "step": ParamSpec((), (), jnp.int32, "zeros"),
        }

    return Optimizer(init, update, state_specs)


def adafactor(
    lr: float = 1e-3,
    decay: float = 0.8,       # running-average exponent for v
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    max_grad_norm: float = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), beta1=0."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "f": jax.tree.map(one, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = _clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, f, p):
            g2 = g * g + eps
            if _factored(g.shape):
                vr = beta * f["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * f["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                v_est = (vr[..., :, None] * vc[..., None, :]) / (
                    denom[..., None] + eps
                )
                u = g * jax.lax.rsqrt(v_est + eps)
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                nf = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u), nf

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        outs = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        nf = tdef.unflatten([o[1] for o in outs])
        return updates, {"f": nf, "step": step}, gnorm

    def state_specs(param_specs):
        def one(s: ParamSpec):
            if _factored(s.shape):
                return {
                    "vr": ParamSpec(s.shape[:-1], s.logical[:-1],
                                    jnp.float32, "zeros"),
                    "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                    s.logical[:-2] + s.logical[-1:],
                                    jnp.float32, "zeros"),
                }
            return {"v": ParamSpec(s.shape, s.logical, jnp.float32, "zeros")}

        return {
            "f": jax.tree.map(one, param_specs, is_leaf=_IS_SPEC),
            "step": ParamSpec((), (), jnp.int32, "zeros"),
        }

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, lr: float = 3e-4) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise ValueError(f"unknown optimizer {name}")
