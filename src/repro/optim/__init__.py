from repro.optim.optimizers import adamw, adafactor, make_optimizer, Optimizer
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    error_feedback_reduce,
)

__all__ = [
    "adamw",
    "adafactor",
    "make_optimizer",
    "Optimizer",
    "compress_int8",
    "decompress_int8",
    "error_feedback_reduce",
]
