"""Int8 gradient compression with error feedback.

Distributed-optimization trick for the cross-pod gradient reduction: each
worker quantizes its gradient shard to int8 (per-tensor max-scale), reduces
the int8 payload (8x less DCN/ICI traffic than fp32, 4x less than bf16),
dequantizes, and keeps the quantization residual locally, adding it back
into the next step's gradient (error feedback => unbiased in the long run;
Karimireddy et al. 2019).

In the GSPMD step the pod-axis reduction is partitioner-inserted, so the
compressed path is used by the trainer's gradient-accumulation boundary and
by the explicit shard_map DP wrapper (``error_feedback_reduce``); both are
unit-tested for the error-feedback invariant.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_reduce(
    g: jnp.ndarray,
    residual: jnp.ndarray,
    axis_name: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compress (g + residual), all-reduce the int8 payload over
    ``axis_name`` (mean), return (reduced fp32 grad, new residual).

    Without an axis name it degrades to local quantize/dequantize — used by
    the accumulation loop and by tests.
    """
    corrected = g.astype(jnp.float32) + residual
    if axis_name is not None:
        # agree on one scale across workers (pmax), then quantize, then
        # reduce the int32 payload (int8 sums would overflow)
        amax = jnp.max(jnp.abs(corrected))
        scale = jnp.maximum(jax.lax.pmax(amax, axis_name) / 127.0, 1e-30)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        reduced = qsum.astype(jnp.float32) * scale / n
        local_decoded = q.astype(jnp.float32) * scale
    else:
        q, scale = compress_int8(corrected)
        reduced = decompress_int8(q, scale)
        local_decoded = reduced
    new_residual = corrected - local_decoded
    return reduced, new_residual
