"""Shared-prefix serving through the paged KV cache.

The deployment shape this demonstrates: many concurrent requests that
all open with the same system prompt. Under ``paged=True`` the batcher
swaps its dense per-bucket KV slabs for one shared physical page pool
(``docs/memory_model.md``): the first request to feed a full page of
prompt publishes it under a content hash, and every later request whose
prompt starts with the same tokens maps that page read-only into its own
page table — skipping prefill for the shared span entirely. The first
divergent page is a fresh private allocation (copy-on-write by
allocation), so token streams stay bit-identical to dense serving.

Quantized serving composes with this (``build_plan(quantized=True)``);
it is orthogonal to the memory layout and not shown here.

    PYTHONPATH=src python examples/serve_shared_prefix.py [--waves 3] [--requests 8]
"""

import argparse
import time

from repro.configs import reduced_config
from repro.plan import MeshSpec, build_plan
from repro.serve import Bucket, BucketPolicy, DecodeRequest

# one full 16-token page of "system prompt" shared by every request
SYSTEM_PROMPT = [1 + (5 * j) % 50 for j in range(16)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per wave, all sharing SYSTEM_PROMPT")
    ap.add_argument("--tokens", type=int, default=6)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)     # the registry resolves aliases
    plan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))

    # paged=True auto-sizes the page pool from the bucket policy; pass
    # (page_count, page_size) instead to model a real HBM budget
    batcher = plan.make_batcher(policy=BucketPolicy([Bucket(64, 4)]),
                                schedule="continuous",
                                steps_per_dispatch=4, paged=True)
    with plan.activate():
        batcher.init_demo_params(seed=0)
    print(f"page pool: {batcher.paged[0]} pages x "
          f"{batcher.paged[1]} tokens")

    with plan.activate():
        for wave in range(args.waves):
            for i in range(args.requests):
                tail = [2 + (7 * i + 3 * j) % 50 for j in range(2 + i % 3)]
                batcher.submit(DecodeRequest(
                    f"w{wave}-{i}", SYSTEM_PROMPT + tail,
                    max_new_tokens=args.tokens))
            t0 = time.perf_counter()
            results = batcher.run()
            dt = time.perf_counter() - t0
            p = batcher.stats()["paged"]
            sample = results[sorted(results)[0]]
            print(f"wave {wave}: {len(results)} requests in {dt*1e3:.0f} "
                  f"ms, sample {sample.request_id} -> "
                  f"{sample.tokens[:6]}; pages in use "
                  f"{p['pages_in_use']}/{p['page_count']} "
                  f"(peak {p['peak_pages']}), prefix hits "
                  f"{p['prefix_hits']}, skip rate "
                  f"{p['prefill_skip_rate']:.3f}")

    p = batcher.stats()["paged"]
    skipped = p["skipped_prefill_tokens"]
    print(f"total: {p['prefix_hits']} of {args.waves * args.requests} "
          f"admissions reused the shared prefix, skipping {skipped} "
          f"prompt tokens of prefill ({p['prefill_skip_rate']:.1%} of "
          "all prompt tokens)")
    c = plan.stats()
    print(f"cache: entries={c['entries']} hits={c['hits']} "
          f"lowerings={c['lowerings']} (zero hot-path lowerings after "
          "wave 0)")


if __name__ == "__main__":
    main()
