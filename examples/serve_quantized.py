"""Quantized serving through the plan API (the paper's deployment
scenario at the framework level): ONE ``build_plan`` call decides the
mesh, the sharding rules, and the int8 quantization — the decode LM head
and the a16w8 MLP down-projection both route through the Pallas qmatmul
kernel, with shifts calibrated from the loaded weights by the plan's
Quantize pass — then serves batched requests from AOT-cached executables.

    PYTHONPATH=src python examples/serve_quantized.py [--waves 3] [--tokens 6]
"""

import argparse
import time

from repro.configs import reduced_config
from repro.plan import MeshSpec, build_plan
from repro.serve import DecodeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=6)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)     # the registry resolves aliases

    # float reference plan and quantized plan, side by side
    plan_f = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    plan_q = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1),
                        quantized=True)

    bf = plan_f.make_batcher()
    bq = plan_q.make_batcher()
    with plan_f.activate():
        bf.init_demo_params(seed=0)
    with plan_q.activate():
        bq.init_demo_params(seed=0)       # calibrates the MLP shifts
    q = plan_q.describe()["quant"]
    print(f"quantized plan: head_shifts={q['head_shifts']} "
          f"mlp_shifts={q['mlp_shifts']} calibrated={q['calibrated']}")

    prompts = [[7, 3], [2, 3, 4], [6, 2, 8], [2, 4, 8, 16]]
    agree = total = 0
    for wave in range(args.waves):
        t0 = time.perf_counter()
        for batcher, tag in ((bf, "f"), (bq, "q")):
            with batcher.plan.activate():
                for i, p in enumerate(prompts[:2]):
                    batcher.submit(DecodeRequest(
                        f"{tag}{wave}-{i}", p, max_new_tokens=args.tokens))
        with plan_f.activate():
            rf = bf.run()
        with plan_q.activate():
            rq = bq.run()
        dt = time.perf_counter() - t0
        for i in range(2):
            a = rf[f"f{wave}-{i}"].tokens
            b = rq[f"q{wave}-{i}"].tokens
            agree += sum(x == y for x, y in zip(a, b))
            total += len(a)
        print(f"wave {wave}: {dt*1e3:.0f} ms, sample float {a[:6]} "
              f"vs int8 {b[:6]}")

    print(f"float/quantized argmax agreement: {agree}/{total} tokens")
    cq = plan_q.stats()
    print(f"quantized cache: entries={cq['entries']} hits={cq['hits']} "
          f"lowerings={cq['lowerings']} (zero hot-path lowerings after "
          "wave 0)")


if __name__ == "__main__":
    main()
