"""End-to-end serving driver (the paper's deployment scenario): compile the
paper's 7-layer MLP and serve batched requests, reporting sustained
throughput and per-batch latency in both simulation modes.

    PYTHONPATH=src python examples/serve_quantized.py [--batches 20] [--batch 64]
"""

import argparse
import time

import numpy as np

from repro.core import CompileConfig, DenseSpec, build_mlp_graph, compile_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--width", type=int, default=512)
    ap.add_argument("--depth", type=int, default=7)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    layers = [
        DenseSpec(args.width, activation="relu",
                  bias=rng.standard_normal(args.width) * 0.05)
        for _ in range(args.depth)
    ]
    graph = build_mlp_graph(batch=args.batch, f_in=args.width, layers=layers,
                            seed=11)
    calib = rng.uniform(-1, 1, (args.batch, args.width)).astype(np.float32)
    model = compile_graph(graph, CompileConfig(calib=calib))
    print(f"compiled {args.depth}x{args.width} MLP: {model.tiles_used} tiles, "
          f"J={model.placement_cost:.2f}")

    # modeled AIE-ML steady-state rate for context
    cyc = model.estimated_cycles(batch=args.batch)
    print(f"modeled AIE-ML interval: "
          f"{cyc / 1.25e9 / args.batch * 1e6:.3f} us/sample")

    for mode in ("x86", "aie"):
        # warmup (jit)
        model.predict(calib, mode=mode)
        t0 = time.perf_counter()
        n = 0
        for i in range(args.batches):
            x = rng.uniform(-1, 1, (args.batch, args.width)).astype(np.float32)
            y = model.predict(x, mode=mode)
            n += len(y)
        dt = time.perf_counter() - t0
        print(f"mode={mode:4s}: {n/dt:8.1f} samples/s host-sim "
              f"({dt/args.batches*1e3:.1f} ms/batch)")

    # bit-exactness spot check under serving traffic
    x = rng.uniform(-1, 1, (args.batch, args.width)).astype(np.float32)
    assert np.array_equal(model.predict(x, "x86"), model.predict(x, "aie"))
    print("serving outputs bit-exact across modes: True")


if __name__ == "__main__":
    main()
