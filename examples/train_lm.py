"""End-to-end training driver: train a decoder LM on the synthetic pipeline
with checkpointing, restart, straggler monitoring, and optional failure
injection / gradient compression.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 120
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset tiny --inject-failure 40

The 100m preset is the assignment's ~100M-parameter run (sized for a real
accelerator; on this 1-core CPU container use `tiny`/`small`).
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import make_train_iterator
from repro.dist.sharding import init_params
from repro.models import build_model
from repro.optim.optimizers import adamw
from repro.train.fault import FailureInjector
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    "tiny": (2, 128, 4, 2, 384, 512, 128, 8),      # ~1.7M params
    "small": (4, 256, 8, 4, 768, 2048, 256, 8),    # ~12M params
    "100m": (12, 768, 12, 4, 2048, 16384, 512, 16),  # ~103M params
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a node failure at this step, then resume")
    args = ap.parse_args()

    L, d, h, kv, ff, v, seq, batch = PRESETS[args.preset]
    cfg = get_config("yi_6b").with_(
        n_layers=L, d_model=d, n_heads=h, n_kv=kv, d_ff=ff, vocab=v,
        head_dim=d // h, remat=False, q_chunk=seq,
    )
    model = build_model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_specs())
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"preset={args.preset}: {n_params/1e6:.1f}M params, "
          f"seq={seq} batch={batch}")

    opt = adamw(lr=args.lr)
    opt_state = opt.init(params)
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        log_every=10, microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )
    trainer = Trainer(model.loss, opt, tcfg)

    def iters(start):
        return make_train_iterator(v, seq, batch, seed=0, start_step=start)

    if args.inject_failure is not None:
        trainer.injector = FailureInjector(fail_at_steps=(args.inject_failure,))
        try:
            trainer.fit(params, opt_state, iters)
        except RuntimeError as e:
            print(f"\n!! {e} — restarting from latest checkpoint\n")
        trainer2 = Trainer(model.loss, opt, tcfg)
        params2 = init_params(jax.random.PRNGKey(0), model.param_specs())
        _, _, hist = trainer2.fit(params2, opt.init(params2), iters)
    else:
        _, _, hist = trainer.fit(params, opt_state, iters)

    losses = [h["loss"] for h in hist]
    print(f"\nloss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"min={min(losses):.4f}")
    if trainer.monitor.events:
        print(f"stragglers flagged: {len(trainer.monitor.events)}")


if __name__ == "__main__":
    main()
