"""Quickstart: ONE entry point — ``repro.plan.build_plan`` — takes a model
description to placed, sharded, AOT-compiled executables, exactly like the
paper's Fig. 2 pipeline takes a network to placed firmware.

The plan's pass pipeline (ResolveMesh -> ResolveSharding -> PlaceStages ->
Quantize -> Compile) decides the mesh, the per-parameter PartitionSpecs,
the pipeline-stage placement, and the executable cache keys; launchers and
this example are thin consumers.

    PYTHONPATH=src python examples/quickstart.py

(For the paper's original small-graph compiler — the bit-exact quantized
MLP flow — see examples/roofline_demo.py and examples/placement_explorer.py,
which drive ``repro.core`` directly.)
"""

import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models.base import ShapeSpec
from repro.plan import MeshSpec, build_plan
from repro.serve import DecodeRequest


def main():
    # 1. Describe the run: a reduced decoder LM, a tiny train shape, the
    #    1x1 debug mesh. build_plan runs the whole pass pipeline.
    cfg = reduced_config("yi_6b").with_(n_layers=2, vocab=128)
    plan = build_plan(cfg, ShapeSpec("quickstart", 32, 4, "train"),
                      mesh_spec=MeshSpec.debug(1, 1))

    # 2. Inspect what each pass decided.
    d = plan.describe()
    print(f"plan: {d['arch']} mode={d['mode']} mesh={d['mesh']}")
    for p in d["passes"]:
        print(f"  {p['pass']}: " + ", ".join(
            f"{k}={v}" for k, v in p.items() if k != "pass"))

    # 3. Train: the plan shards params/optimizer state and compiles the
    #    train step AOT through the shared executable cache.
    params, opt_state = plan.init_train_state(seed=0)
    step = plan.executable("train")
    batch = {"tokens": jnp.ones((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    for i in range(3):
        params, opt_state, metrics = step.compiled(params, opt_state, batch)
        print(f"train step {i}: loss {float(metrics['loss']):.4f}")

    # 4. Serve from the SAME plan API: a serve plan builds per-bucket
    #    decode/prefill executables behind the same cache counters.
    splan = build_plan(cfg, None, mesh_spec=MeshSpec.debug(1, 1))
    batcher = splan.make_batcher()
    with splan.activate():
        batcher.init_demo_params(seed=0)
        batcher.submit(DecodeRequest("demo", [1, 2, 3], max_new_tokens=6))
        results = batcher.run()
    print(f"decode: {results['demo'].tokens}")
    print(f"cache counters: {splan.stats()}")


if __name__ == "__main__":
    main()
