"""Quickstart: compile a quantized MLP through the AIE4ML pipeline and run
bit-exact inference in both simulation modes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CompileConfig, DenseSpec, build_mlp_graph, compile_graph


def main():
    rng = np.random.default_rng(0)

    # 1. Describe the network (the hls4ml-frontend role): a small jet-tagging
    #    style MLP with fused ReLU layers.
    layers = [
        DenseSpec(64, activation="relu", bias=rng.standard_normal(64) * 0.1),
        DenseSpec(32, activation="relu", bias=rng.standard_normal(32) * 0.1),
        DenseSpec(5),
    ]
    graph = build_mlp_graph(batch=16, f_in=16, layers=layers, seed=1)

    # 2. Compile: Lower -> Quantize -> Resolve -> Pack -> GraphPlan -> Place
    #    -> Emit. Calibration data drives the activation binary points.
    x = rng.uniform(-1, 1, (16, 16)).astype(np.float32)
    model = compile_graph(graph, CompileConfig(calib=x))

    # 3. Inspect the generated design.
    print(f"tiles used:        {model.tiles_used} / 304")
    print(f"memtile bytes:     {model.memtile_bytes}")
    print(f"placement cost J:  {model.placement_cost:.2f}")
    for name, (c, r, w, h) in model.placements().items():
        print(f"  {name:10s} at col={c:2d} row={r} size {w}x{h}")

    # 4. Run inference: x86 functional sim vs AIE (Pallas kernel) sim.
    y_x86 = model.predict(x, mode="x86")
    y_aie = model.predict(x, mode="aie")
    assert np.array_equal(y_x86, y_aie), "modes must be bit-exact"
    print(f"\npredict() bit-exact across modes: True")
    print(f"outputs[0]: {y_x86[0].round(3)}")


if __name__ == "__main__":
    main()
