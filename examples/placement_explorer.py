"""Placement explorer: reproduce the paper's Fig. 3 comparison on arbitrary
networks and render ASCII layouts of the 2D AIE array.

    PYTHONPATH=src python examples/placement_explorer.py
"""

from repro.core.placement import Block, Placer


def render(n_cols, n_rows, positions, names):
    grid = [["." for _ in range(n_cols)] for _ in range(n_rows)]
    for p, name in zip(positions, names):
        for c in range(p.col, p.col + p.width):
            for r in range(p.row, p.row + p.height):
                grid[r][c] = name
    # row 0 at the bottom (memory-tile row), like the paper's figures
    return "\n".join("".join(row) for row in reversed(grid))


def main():
    blocks = [Block(4, 4, "A"), Block(4, 2, "B"), Block(8, 2, "C"),
              Block(4, 4, "D"), Block(2, 2, "E"), Block(8, 4, "F"),
              Block(4, 2, "G"), Block(2, 1, "H")]
    names = [b.name for b in blocks]
    placer = Placer(38, 8, lam=1.0, mu=0.05, beam=64)

    for label, result in [
        ("branch-and-bound", placer.branch_and_bound(blocks, start=(0, 0))),
        ("greedy-right", placer.greedy_right(blocks)),
        ("greedy-up", placer.greedy_up(blocks)),
    ]:
        print(f"=== {label}: J = {result.cost:.2f} ===")
        print(render(38, 8, result.positions, names))
        print()


if __name__ == "__main__":
    main()
