"""Roofline walkthrough: dry-run one (arch x shape) cell and interpret the
compiled artifact — the assignment's §Roofline methodology on one example.

    PYTHONPATH=src python examples/roofline_demo.py [--arch yi-6b]
    (spawns a subprocess so the 512-device XLA flag stays contained)
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mode", default="megatron_sp")
    args = ap.parse_args()

    out = os.path.join(tempfile.mkdtemp(), "cell.json")
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    print(f"compiling {args.arch} x {args.shape} on the 16x16 production "
          f"mesh ({args.mode}) — ~1-3 min on CPU...")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
         "--shape", args.shape, "--single-pod", "--mode", args.mode,
         "--out", out],
        check=True, env=env, cwd=os.path.dirname(SRC),
    )
    r = json.load(open(out))[0]
    if r["status"] != "ok":
        raise SystemExit(f"cell failed: {r.get('reason') or r.get('error')}")
    ro = r["roofline"]
    mem = r["memory"]
    print(f"\n=== {r['arch']} x {r['shape']} on {r['mesh']} ({r['mode']}) ===")
    print(f"params: {r['params_total']/1e9:.2f}B total, "
          f"{r['params_active']/1e9:.2f}B active")
    print(f"per-device memory: args {mem['argument_bytes']/2**30:.2f} GiB, "
          f"temps {mem['temp_bytes']/2**30:.2f} GiB, "
          f"peak ~{mem['peak_bytes_est']/2**30:.2f} GiB "
          f"({'fits' if mem['peak_bytes_est'] <= 16*2**30 else 'EXCEEDS'} "
          f"16 GiB HBM)")
    print("\nroofline terms (per chip, TPU v5e constants):")
    print(f"  compute    {ro['compute_s']*1e3:10.3f} ms   "
          f"({ro['hlo_flops_per_chip']:.3e} FLOPs @ 197 TF/s)")
    print(f"  memory     {ro['memory_s']*1e3:10.3f} ms   "
          f"({ro['hlo_bytes_per_chip']:.3e} B @ 819 GB/s)")
    print(f"  collective {ro['collective_s']*1e3:10.3f} ms   "
          f"({ro['collective_bytes_per_chip']:.3e} B @ 50 GB/s/link)")
    print(f"  -> dominant: {ro['dominant']}  "
          f"(step bound {ro['step_time_bound_s']*1e3:.2f} ms)")
    print(f"  useful FLOPs: {ro['useful_flops_ratio']*100:.1f}% of compiled "
          f"(MODEL_FLOPS {ro['model_flops_per_chip']:.3e}/chip)")
    print(f"  MFU bound: {ro['roofline_mfu']*100:.2f}%")
    print("\ncollective schedule:")
    for k, v in ro["per_collective_bytes"].items():
        n = ro["collective_op_counts"].get(k, 0)
        print(f"  {k:22s} {v/1e9:10.2f} GB over {n} ops")


if __name__ == "__main__":
    main()
