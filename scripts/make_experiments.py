"""Generate the EXPERIMENTS.md roofline tables from the sweep JSONs.

    PYTHONPATH=src python scripts/make_experiments.py > /tmp/roofline_tables.md
"""

import glob
import json
import sys


def fmt_s(s):
    if s >= 1.0:
        return f"{s:.3g}s"
    if s >= 1e-3:
        return f"{s*1e3:.3g}ms"
    return f"{s*1e6:.3g}us"


def load(d):
    recs = []
    for f in sorted(glob.glob(d + "/*.json")):
        recs.extend(json.load(open(f)))
    return recs


def table(records, title):
    print(f"\n### {title}\n")
    print("| arch | shape | C (s) | M (s) | N (s) | dominant | useful% "
          "| MFU bound | fits 16GiB |")
    print("|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(records, key=lambda r: (r["arch"],
                                            order.get(r["shape"], 9))):
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                  f"skipped (full attention @500k) | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:40]} |")
            continue
        ro = r["roofline"]
        peak = r["memory"]["peak_bytes_est"]
        fits = "yes" if peak <= 16 * 2**30 else f"NO ({peak/2**30:.0f}GiB)"
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"{ro['dominant']} | {ro['useful_flops_ratio']*100:.1f} | "
            f"{ro['roofline_mfu']*100:.2f}% | {fits} |"
        )


def main():
    cas = load("results/sweep_sp_cascade")
    meg = load("results/sweep_sp_megatron")
    mp = load("results/sweep_mp_megatron")
    table(cas, "Single-pod 16x16 — cascade (paper-faithful baseline)")
    table(meg, "Single-pod 16x16 — megatron (optimized default)")
    table(mp, "Multi-pod 2x16x16 — megatron (multi-pod proof)")
    opt = load("results/sweep_sp_optimized")
    if opt:
        table(opt, "Single-pod 16x16 — megatron_sp + grouped MoE "
                   "(beyond-paper, framework-wide)")

    # collective breakdown for the most collective-bound cells
    print("\n### Top collective-bound cells (cascade baseline)\n")
    rows = [r for r in cas if r["status"] == "ok"]
    rows.sort(key=lambda r: -r["roofline"]["collective_s"])
    for r in rows[:6]:
        ro = r["roofline"]
        per = {k: f"{v/1e9:.1f}GB" for k, v in
               ro["per_collective_bytes"].items()}
        print(f"- {r['arch']} x {r['shape']}: N={fmt_s(ro['collective_s'])} "
              f"{per} ops={ro['collective_op_counts']}")


if __name__ == "__main__":
    main()
