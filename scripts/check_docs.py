#!/usr/bin/env python3
"""Doc-integrity gate (CI lint job): keep the docs from drifting.

Checks, over README.md and docs/*.md:

1. every intra-repo markdown link resolves to a file or directory that
   exists (external http(s)/mailto links are ignored);
2. every ``#anchor`` fragment on a markdown target matches a real
   heading in that file, using GitHub's slug rules (lowercase, drop
   punctuation, spaces become hyphens, duplicates get ``-1``/``-2``…);
3. every ```python fenced block in docs/ is valid Python — it must
   survive ``compile(src, file, "exec")``. Docs examples that cannot
   even parse are worse than no examples.

Stdlib only, no repo imports; runs from any cwd. Exit code 1 and a
per-problem listing on failure. ``--json FILE`` writes a report in the
same shape ``python -m repro.analysis --json`` emits (tool/ok/counts/
findings), so CI uploads both gates as one artifact family.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys
from typing import Dict, Iterator, List, Tuple

REPO = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"!?\[([^\]\[]*)\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^\s*(```+|~~~+)\s*([A-Za-z0-9_+-]*)\s*$")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
# GitHub keeps word chars, spaces and hyphens; everything else vanishes
SLUG_DROP_RE = re.compile(r"[^\w\- ]")


def doc_files() -> List[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def walk_lines(text: str) -> Iterator[Tuple[int, str, str]]:
    """Yield (lineno, kind, payload): ``text`` lines outside fences, and
    one ``("code:<lang>", block_src)`` entry per fenced block."""
    fence, lang, buf, start = None, "", [], 0
    for n, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line)
        if fence is None:
            if m:
                fence, lang, buf, start = m.group(1), m.group(2).lower(), [], n
            else:
                yield n, "text", line
        elif m and m.group(1)[0] == fence[0] and len(m.group(1)) >= len(fence):
            yield start, f"code:{lang}", "\n".join(buf)
            fence = None
        else:
            buf.append(line)
    if fence is not None:  # unterminated fence: surface as a code block
        yield start, f"code:{lang}", "\n".join(buf)


def github_slug(heading: str) -> str:
    text = re.sub(r"`([^`]*)`", r"\1", heading)        # unwrap code spans
    text = LINK_RE.sub(lambda m: m.group(1), text)     # [text](url) -> text
    return SLUG_DROP_RE.sub("", text.lower()).replace(" ", "-")


def anchors_of(text: str) -> set:
    slugs: Dict[str, int] = {}
    out = set()
    for _, kind, payload in walk_lines(text):
        if kind != "text":
            continue
        m = HEADING_RE.match(payload)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = slugs.get(slug, 0)
        slugs[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check() -> List[Dict[str, object]]:
    """Structured problems: rule/file/line/message dicts (the same
    finding shape ``repro.analysis`` reports)."""
    problems: List[Dict[str, object]] = []
    anchor_cache: Dict[pathlib.Path, set] = {}

    def anchors(path: pathlib.Path) -> set:
        if path not in anchor_cache:
            anchor_cache[path] = anchors_of(path.read_text(encoding="utf-8"))
        return anchor_cache[path]

    def add(rule: str, rel: pathlib.Path, lineno: int, message: str):
        problems.append({"rule": rule, "file": str(rel), "line": lineno,
                         "message": message})

    for doc in doc_files():
        rel = doc.relative_to(REPO)
        text = doc.read_text(encoding="utf-8")
        for lineno, kind, payload in walk_lines(text):
            if kind == "code:python":
                if rel.parts[0] != "docs":
                    continue
                try:
                    compile(payload, f"{rel}:{lineno}", "exec")
                except SyntaxError as e:
                    add("DOC103", rel, lineno,
                        f"python block does not compile: {e.msg} "
                        f"(block line {e.lineno})")
                continue
            if kind != "text":
                continue
            for m in LINK_RE.finditer(payload):
                target = m.group(2)
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path_part, _, frag = target.partition("#")
                dest = doc if not path_part else (
                    doc.parent / path_part).resolve()
                if not dest.exists():
                    add("DOC101", rel, lineno, f"broken link -> {target}")
                    continue
                if frag and dest.suffix == ".md":
                    if frag.lower() not in anchors(dest):
                        add("DOC102", rel, lineno,
                            f"bad anchor -> {target} (no heading slugs "
                            f"to '{frag}' in {dest.relative_to(REPO)})")
    return problems


def report_json(problems: List[Dict[str, object]],
                n_docs: int) -> Dict[str, object]:
    return {
        "tool": "scripts.check_docs",
        "ok": not problems,
        "counts": {"files": n_docs, "findings": len(problems)},
        "findings": problems,
    }


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="FILE",
                    help="write the JSON report to FILE ('-' for stdout)")
    args = ap.parse_args(argv)

    problems = check()
    n_docs = len(doc_files())
    if args.json:
        payload = json.dumps(report_json(problems, n_docs),
                             indent=2) + "\n"
        if args.json == "-":
            sys.stdout.write(payload)
        else:
            pathlib.Path(args.json).write_text(payload, encoding="utf-8")
    if problems:
        for p in problems:
            print(f"{p['file']}:{p['line']}: {p['message']}")
        print(f"check_docs: {len(problems)} problem(s) across "
              f"{n_docs} file(s)")
        return 1
    print(f"check_docs: OK ({n_docs} files: links, anchors, "
          f"python blocks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
